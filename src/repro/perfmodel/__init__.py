"""Performance model: HLO cost extraction + collective parsing + roofline."""
from .roofline import RooflineTerms, roofline
from .hlo import collective_bytes

__all__ = ["RooflineTerms", "roofline", "collective_bytes"]
