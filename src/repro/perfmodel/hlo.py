"""Collective-byte accounting from compiled HLO text.

``cost_analysis()`` has no collective figures, so we parse the (per-device
SPMD) HLO module: every all-reduce / all-gather / reduce-scatter / all-to-all
/ collective-permute op contributes per-device link traffic per the standard
ring-algorithm conventions:

    all-reduce       2 * B * (n-1)/n     (B = result bytes)
    all-gather       B * (n-1)/n
    reduce-scatter   B * (n-1)            (operand = n*B)
    all-to-all       B * (n-1)/n
    collective-permute  B

where n = collective group size, parsed from replica_groups.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# `%name = bf16[2,16,128]{...} all-reduce(` possibly tuple-typed:
# `%name = (f32[16,128], f32[16,128]) all-reduce(`
_OP_RE = re.compile(
    r"=\s*(?P<type>\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        elems = [e for e in m.group(1).split(",") if e.strip()]
        return max(len(elems), 1)
    return default


@dataclass
class CollectiveStats:
    per_device_bytes: float = 0.0  # link traffic per device (ring model)
    payload_bytes: float = 0.0  # raw result bytes (no algorithm factor)
    op_counts: dict = field(default_factory=lambda: defaultdict(int))
    op_bytes: dict = field(default_factory=lambda: defaultdict(float))

    def summary(self) -> dict:
        return {
            "per_device_bytes": self.per_device_bytes,
            "payload_bytes": self.payload_bytes,
            "op_counts": dict(self.op_counts),
            "op_bytes": {k: float(v) for k, v in self.op_bytes.items()},
        }


_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?(%?[\w\.\-_]+)\s*\(.*\{$")
_WHILE_RE = re.compile(r"\bwhile\(")
_BODY_REF_RE = re.compile(r"body=([%\w\.\-_]+)")
_COND_REF_RE = re.compile(r"condition=([%\w\.\-_]+)")
_CALL_REF_RE = re.compile(r"\b(?:calls|to_apply)=([%\w\.\-_]+)")
_CONST_INT_RE = re.compile(r"constant\((\d+)\)")
_IS_SHAPE_LINE = re.compile(r"^\s*(%[\w\.\-_]+|ROOT\s)")


def _split_computations(hlo_text: str) -> dict[str, list[str]]:
    """computation name -> its body lines (flat HLO text format)."""
    comps: dict[str, list[str]] = {}
    cur: Optional[str] = None
    entry_marked: Optional[str] = None
    for line in hlo_text.splitlines():
        if cur is None:
            m = _COMP_HDR_RE.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = m.group(1)
                if line.lstrip().startswith("ENTRY"):
                    entry_marked = cur
                comps[cur] = []
        else:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line)
    if entry_marked:
        comps["__entry__"] = comps[entry_marked]
    return comps


def _line_collective(line: str, default_group: int):
    """(op, traffic_bytes, payload_bytes) or None for one HLO line."""
    m = _OP_RE.search(line)
    if m is None:
        return None
    if "-done(" in line:  # async pair: count only -start
        return None
    op = m.group("op")
    type_str = m.group("type")
    is_start = f"{op}-start(" in line
    if is_start and type_str.startswith("("):
        # tuple (operand, result): take the largest member
        b = max(
            (_shape_bytes(s) for s in re.findall(r"[a-z0-9]+\[[0-9,]*\]", type_str)),
            default=0,
        )
    else:
        b = _shape_bytes(type_str)
    n = _group_size(line, default_group)
    if op == "all-reduce":
        traffic = 2.0 * b * (n - 1) / max(n, 1)
    elif op in ("all-gather", "all-to-all"):
        traffic = b * (n - 1) / max(n, 1)
    elif op == "reduce-scatter":
        # sync form types the (small) result: operand = n*b ; async largest = operand
        traffic = float(b) * (n - 1) if not is_start else b * (n - 1) / max(n, 1)
    else:  # collective-permute
        traffic = float(b)
    return op, traffic, float(b)


def _trip_count(cond_lines: list[str]) -> int:
    """Trip count of a scan-style while: the max integer constant compared."""
    best = 1
    for line in cond_lines:
        if "compare(" in line or "constant(" in line:
            for c in _CONST_INT_RE.findall(line):
                best = max(best, int(c))
    return best


def collective_bytes(hlo_text: str, default_group: int = 1) -> CollectiveStats:
    """Loop-aware collective accounting: collectives inside while-loop bodies
    are multiplied by the loop trip count (XLA text lists the body once)."""
    comps = _split_computations(hlo_text)
    stats = CollectiveStats()
    if not comps:
        return stats

    def walk(name: str, seen: frozenset) -> tuple[float, float, dict, dict]:
        if name not in comps or name in seen:
            return 0.0, 0.0, {}, {}
        seen = seen | {name}
        traffic = payload = 0.0
        counts: dict = defaultdict(int)
        obytes: dict = defaultdict(float)
        for line in comps[name]:
            lc = _line_collective(line, default_group)
            if lc is not None:
                op, t, b = lc
                traffic += t
                payload += b
                counts[op] += 1
                obytes[op] += t
            if _WHILE_RE.search(line):
                bm = _BODY_REF_RE.search(line)
                cm = _COND_REF_RE.search(line)
                if bm:
                    trips = _trip_count(comps.get(cm.group(1), [])) if cm else 1
                    bt, bp, bc, bb = walk(bm.group(1), seen)
                    traffic += trips * bt
                    payload += trips * bp
                    for k, v in bc.items():
                        counts[k] += trips * v
                    for k, v in bb.items():
                        obytes[k] += trips * v
            else:
                for ref in _CALL_REF_RE.findall(line):
                    bt, bp, bc, bb = walk(ref, seen)
                    traffic += bt
                    payload += bp
                    for k, v in bc.items():
                        counts[k] += v
                    for k, v in bb.items():
                        obytes[k] += v
        return traffic, payload, dict(counts), dict(obytes)

    entry = "__entry__" if "__entry__" in comps else next(iter(comps))
    traffic, payload, counts, obytes = walk(entry, frozenset())
    stats.per_device_bytes = traffic
    stats.payload_bytes = payload
    stats.op_counts = defaultdict(int, counts)
    stats.op_bytes = defaultdict(float, obytes)
    return stats


def count_op(hlo_text: str, opname: str) -> int:
    return len(re.findall(rf"\b{re.escape(opname)}\(", hlo_text))
