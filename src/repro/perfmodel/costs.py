"""Extraction of per-device FLOPs/bytes from compiled executables."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any


@dataclass
class CompiledCosts:
    flops_per_device: float
    bytes_per_device: float
    transcendentals: float
    # memory analysis (per device)
    arg_bytes: int
    out_bytes: int
    temp_bytes: int
    alias_bytes: int
    code_bytes: int

    @property
    def peak_hbm_bytes(self) -> int:
        """Live-at-once estimate: args + outputs + temps - aliased.

        NOTE: on the CPU dry-run backend this OVERESTIMATES bf16-heavy
        footprints — XLA:CPU legalizes bf16 buffers by keeping f32 copies
        (observed as convert()'d duplicate stacks in the HLO).  The analytic
        estimate in the dry-run record is the TPU-expectation counterpart.
        """
        return self.arg_bytes + self.out_bytes + self.temp_bytes - self.alias_bytes

    def summary(self) -> dict:
        return {
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "transcendentals": self.transcendentals,
            "arg_bytes": self.arg_bytes,
            "out_bytes": self.out_bytes,
            "temp_bytes": self.temp_bytes,
            "alias_bytes": self.alias_bytes,
            "peak_hbm_bytes": self.peak_hbm_bytes,
        }


def _as_cost_dict(ca: Any) -> dict:
    """cost_analysis() returns a dict on newer jax, a per-device list of
    dicts on older versions — normalize to the (first-device) dict."""
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}


def extract_costs(compiled: Any) -> CompiledCosts:
    ca = _as_cost_dict(compiled.cost_analysis())
    ma = compiled.memory_analysis()
    return CompiledCosts(
        flops_per_device=float(ca.get("flops", 0.0)),
        bytes_per_device=float(ca.get("bytes accessed", 0.0)),
        transcendentals=float(ca.get("transcendentals", 0.0)),
        arg_bytes=int(getattr(ma, "argument_size_in_bytes", 0)),
        out_bytes=int(getattr(ma, "output_size_in_bytes", 0)),
        temp_bytes=int(getattr(ma, "temp_size_in_bytes", 0)),
        alias_bytes=int(getattr(ma, "alias_size_in_bytes", 0)),
        code_bytes=int(getattr(ma, "generated_code_size_in_bytes", 0)),
    )
