"""Three-term roofline from dry-run artifacts (per assignment §Roofline).

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s_per_chip
    memory term     = HLO_bytes_per_device / HBM_bw_per_chip
    collective term = collective_bytes_per_device / link_bw

(cost_analysis() reports per-device figures for SPMD modules — verified
empirically — so the assignment's "global / chips" division is already done.)
The dominant term is the bottleneck; the roofline fraction reported in §Perf
is MODEL_FLOPS_time / max(term) — how close useful model math runs to the
hardware bound.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.hwmodel import TPU_V5E, HardwareModel

from .costs import CompiledCosts
from .hlo import CollectiveStats


@dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float  # useful math (6ND / 2ND), global
    hlo_flops_global: float
    useful_ratio: float  # model_flops / hlo_flops_global
    roofline_fraction: float  # model compute time / dominant bound
    chips: int

    def summary(self) -> dict:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "model_flops": self.model_flops,
            "hlo_flops_global": self.hlo_flops_global,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
            "chips": self.chips,
        }


def model_flops(kind: str, n_params_active: float, tokens: float) -> float:
    """6ND for training (fwd+bwd), 2ND for inference-only passes."""
    if kind == "train":
        return 6.0 * n_params_active * tokens
    return 2.0 * n_params_active * tokens


def roofline(
    costs: CompiledCosts,
    coll: CollectiveStats,
    chips: int,
    kind: str,
    n_params_active: float,
    tokens: float,
    hw: HardwareModel = TPU_V5E,
    dtype: str = "bfloat16",
) -> RooflineTerms:
    peak = hw.peak(dtype)
    t_c = costs.flops_per_device / peak
    t_m = costs.bytes_per_device / hw.main_memory_Bps
    t_x = coll.per_device_bytes / hw.ici_Bps_per_link
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    dominant = max(terms, key=terms.get)
    mf = model_flops(kind, n_params_active, tokens)
    hlo_global = costs.flops_per_device * chips
    useful = mf / hlo_global if hlo_global else 0.0
    t_model = mf / (chips * peak)
    bound = max(terms.values())
    frac = t_model / bound if bound > 0 else 0.0
    return RooflineTerms(
        compute_s=t_c,
        memory_s=t_m,
        collective_s=t_x,
        dominant=dominant,
        model_flops=mf,
        hlo_flops_global=hlo_global,
        useful_ratio=useful,
        roofline_fraction=frac,
        chips=chips,
    )
