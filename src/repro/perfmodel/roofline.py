"""Three-term roofline from dry-run artifacts (per assignment §Roofline).

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s_per_chip
    memory term     = HLO_bytes_per_device / HBM_bw_per_chip
    collective term = collective_bytes_per_device / link_bw

(cost_analysis() reports per-device figures for SPMD modules — verified
empirically — so the assignment's "global / chips" division is already done.)
The dominant term is the bottleneck; the roofline fraction reported in §Perf
is MODEL_FLOPS_time / max(term) — how close useful model math runs to the
hardware bound.

``hw=`` takes any part registered in the :mod:`repro.hw` spec database (a
name like ``"T4"`` or a ``HardwareModel``); :func:`roofline_across` sweeps
the same workload over several generations at once — the paper's
cross-generation comparison applied to a whole compiled program instead of
a single kernel.  Parts with no published interconnect (single-chip cards
like the T4) get a zero collective term.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, Union

from repro.hw import HardwareModel, resolve as _resolve_hw

from .costs import CompiledCosts
from .hlo import CollectiveStats


@dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float  # useful math (6ND / 2ND), global
    hlo_flops_global: float
    useful_ratio: float  # model_flops / hlo_flops_global
    roofline_fraction: float  # model compute time / dominant bound
    chips: int
    hw: str = ""  # spec-DB part the terms were computed against

    def summary(self) -> dict:
        return {
            "hw": self.hw,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "model_flops": self.model_flops,
            "hlo_flops_global": self.hlo_flops_global,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
            "chips": self.chips,
        }


def model_flops(kind: str, n_params_active: float, tokens: float) -> float:
    """6ND for training (fwd+bwd), 2ND for inference-only passes."""
    if kind == "train":
        return 6.0 * n_params_active * tokens
    return 2.0 * n_params_active * tokens


def roofline(
    costs: CompiledCosts,
    coll: CollectiveStats,
    chips: int,
    kind: str,
    n_params_active: float,
    tokens: float,
    hw: Union[str, HardwareModel] = "tpu-v5e",
    dtype: str = "bfloat16",
) -> RooflineTerms:
    hw = _resolve_hw(hw)
    peak = hw.peak(dtype, fallback=("float16", "float32"))
    t_c = costs.flops_per_device / peak
    t_m = costs.bytes_per_device / hw.main_memory_Bps
    # parts without a published interconnect (single-chip cards) have no
    # collective bound; their collective term is zero by construction
    t_x = (
        coll.per_device_bytes / hw.ici_Bps_per_link if hw.ici_Bps_per_link else 0.0
    )
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    dominant = max(terms, key=terms.get)
    mf = model_flops(kind, n_params_active, tokens)
    hlo_global = costs.flops_per_device * chips
    useful = mf / hlo_global if hlo_global else 0.0
    t_model = mf / (chips * peak)
    bound = max(terms.values())
    frac = t_model / bound if bound > 0 else 0.0
    return RooflineTerms(
        compute_s=t_c,
        memory_s=t_m,
        collective_s=t_x,
        dominant=dominant,
        model_flops=mf,
        hlo_flops_global=hlo_global,
        useful_ratio=useful,
        roofline_fraction=frac,
        chips=chips,
        hw=hw.name,
    )


def roofline_across(
    costs: CompiledCosts,
    coll: CollectiveStats,
    chips: int,
    kind: str,
    n_params_active: float,
    tokens: float,
    hws: Iterable[Union[str, HardwareModel]] = ("tpu-v5e", "T4", "A100", "H100"),
    dtype: str = "bfloat16",
) -> dict:
    """The same workload rooflined against several generations at once.

    Returns ``{part name: RooflineTerms}`` — one cross-generation comparison
    record per part, ordered as given.  This is what ``benchmarks/roofline.py
    --hw`` renders as extra columns.
    """
    out = {}
    for h in hws:
        rt = roofline(costs, coll, chips, kind, n_params_active, tokens,
                      hw=h, dtype=dtype)
        out[rt.hw] = rt
    return out
