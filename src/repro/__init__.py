"""repro — TPU-native reproduction of "Dissecting the NVidia Turing T4 GPU
via Microbenchmarking" (Jia, Maggioni, Smith, Scarpazza; Citadel, 2019).

The paper's contribution — a microbenchmark suite that distills hardware
behavior into a quantitative model which then drives software optimization —
is re-built here as a first-class feature of a JAX training/serving
framework:

- ``repro.core``      the microbenchmark engine + HardwareModel (Table 3.1 analogue)
- ``repro.perfmodel`` roofline + HLO cost extraction driven by the HardwareModel
- ``repro.kernels``   Pallas probe & compute kernels (pchase, membw, axpy, matmul,
                      flash attention, ssm scan)
- ``repro.models``    the 10 assigned architectures
- ``repro.dist``      mesh/sharding/ZeRO/compression/pipeline
- ``repro.train`` / ``repro.serve`` / ``repro.data`` / ``repro.optim``
- ``repro.ckpt`` / ``repro.ft``  fault tolerance: checkpoints, resharding,
                      straggler detection (throttle-model-informed)
- ``repro.launch``    production mesh + multi-pod dry-run
"""

__version__ = "1.0.0"
