"""Heartbeat monitoring: detects dead/hung workers from missing step beats.

In a real deployment each host POSTs beats to the coordinator; here the
monitor is the coordinator-side logic, driven by ``beat()`` calls and a
monotonic clock injectable for tests.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Optional


@dataclass
class HeartbeatMonitor:
    timeout_s: float = 60.0
    clock: Callable[[], float] = time.monotonic
    _last: dict = field(default_factory=dict)  # worker -> (step, t)

    def beat(self, worker: str, step: int):
        self._last[worker] = (step, self.clock())

    def forget(self, worker: str):
        """Stop tracking ``worker`` (it was failed over or decommissioned) —
        otherwise its stale beat keeps it in ``dead_workers()`` forever."""
        self._last.pop(worker, None)

    def dead_workers(self) -> list[str]:
        now = self.clock()
        return sorted(
            w for w, (_, t) in self._last.items() if now - t > self.timeout_s
        )

    def alive_workers(self) -> list[str]:
        now = self.clock()
        return sorted(
            w for w, (_, t) in self._last.items() if now - t <= self.timeout_s
        )

    def min_step(self) -> Optional[int]:
        if not self._last:
            return None
        return min(s for s, _ in self._last.values())
