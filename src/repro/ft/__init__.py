"""Fault tolerance: heartbeats, throttle-aware straggler detection, elastic
restart policy."""
from .heartbeat import HeartbeatMonitor
from .straggler import StragglerDetector
from .elastic import ElasticController

__all__ = ["HeartbeatMonitor", "StragglerDetector", "ElasticController"]
