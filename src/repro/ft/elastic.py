"""Elastic restart policy: dead/straggling workers -> new mesh -> resharded
resume.

This is the coordinator logic a 1000-node deployment runs around the train
loop: on failure, shrink (or re-grow) the mesh to the healthy device set,
reshard the last good checkpoint onto it, and seek the data pipeline to the
checkpointed step.  The mesh math assumes whole-host granularity (you lose
devices in host-sized groups) and preserves the model axis (TP degree is a
property of the model, not the fleet).
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.ckpt import CheckpointManager, load_resharded
from repro.launch.mesh import make_mesh_for


@dataclass
class ElasticController:
    model_parallel: int
    ckpt: CheckpointManager
    devices_total: int
    devices_per_host: int = 4

    def plan_mesh(self, healthy_devices: int, pods: int = 1):
        """Largest valid (pods, data, model) mesh within healthy devices."""
        per_pod = healthy_devices // pods
        data = per_pod // self.model_parallel
        if data < 1:
            raise RuntimeError(
                f"not enough healthy devices ({healthy_devices}) for "
                f"model_parallel={self.model_parallel}"
            )
        usable = pods * data * self.model_parallel
        return make_mesh_for(usable, self.model_parallel, pods)

    def resume(self, like, new_shardings, pipeline=None):
        """Restore last-good checkpoint onto the new mesh; seek data."""
        step, tree = load_resharded(self.ckpt, like, new_shardings)
        if step is None:
            return None, None
        if pipeline is not None:
            pipeline.seek(step)
        return step, tree
