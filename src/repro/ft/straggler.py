"""Straggler detection informed by the paper's throttling model (§4.5).

The paper shows a thermally-throttled T4 settles at a predictable clock
derate (Fig 4.4/4.5).  On a fleet, a chip entering that regime inflates its
step time by ``slowdown_factor`` — a *known* signature.  The detector keeps
an EWMA + median of per-worker step times and flags workers whose inflation
matches or exceeds the throttle signature (or an absolute factor), rather
than using a naive fixed threshold that either misses early throttling or
false-positives on normal jitter.

Mitigations (policy layer): reroute data shards away from flagged workers /
exclude + elastic-reshard (see elastic.py) — both driven by these flags.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from statistics import median
from typing import Optional

from repro.core.throttle import ThrottleParams, V5E_THROTTLE, slowdown_factor


@dataclass
class StragglerDetector:
    throttle: ThrottleParams = V5E_THROTTLE
    utilization: float = 0.9
    ewma_alpha: float = 0.2
    margin: float = 0.5  # flag at (1-margin) of the full throttle signature
    min_samples: int = 5
    _ewma: dict = field(default_factory=dict)
    _history: dict = field(default_factory=dict)
    _signature: Optional[float] = None

    def signature(self) -> float:
        """Step-time inflation of a fully-throttled chip (from the model)."""
        if self._signature is None:
            self._signature = slowdown_factor(self.throttle, self.utilization)
        return self._signature

    def forget(self, worker: str):
        """Drop ``worker``'s samples (failed over / revived): a replica that
        comes back healthy must not be re-flagged on its throttled history."""
        self._ewma.pop(worker, None)
        self._history.pop(worker, None)

    def observe(self, worker: str, step_time_s: float):
        prev = self._ewma.get(worker)
        self._ewma[worker] = (
            step_time_s
            if prev is None
            else self.ewma_alpha * step_time_s + (1 - self.ewma_alpha) * prev
        )
        self._history.setdefault(worker, []).append(step_time_s)

    def fleet_median(self) -> Optional[float]:
        vals = [v for v in self._ewma.values()]
        return median(vals) if vals else None

    def stragglers(self) -> list[tuple[str, float]]:
        """[(worker, inflation)] for workers at/beyond the throttle signature."""
        med = self.fleet_median()
        if med is None or med <= 0:
            return []
        sig = self.signature()
        thresh = 1.0 + (sig - 1.0) * (1.0 - self.margin)
        out = []
        for w, v in self._ewma.items():
            if len(self._history.get(w, ())) < self.min_samples:
                continue
            inflation = v / med
            if inflation >= thresh:
                out.append((w, inflation))
        return sorted(out, key=lambda t: -t[1])

    def likely_thermal(self, worker: str) -> bool:
        """Inflation consistent with the thermal-throttle signature
        specifically (vs. e.g. network slowness, which inflates further)."""
        med = self.fleet_median()
        if med is None or worker not in self._ewma:
            return False
        inflation = self._ewma[worker] / med
        sig = self.signature()
        return 0.8 * sig <= inflation <= 1.3 * sig
