"""Training driver.

Full-scale invocation (real TPU fleet) uses the production mesh; on this CPU
container use ``--reduced`` to train a smoke-size variant of any arch, or
``examples/train_tiny_lm.py`` for the end-to-end ~100M-param run.

    PYTHONPATH=src python -m repro.launch.train --arch gemma-2b --reduced \
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.ckpt import CheckpointManager
from repro.configs import get_config
from repro.data import DataPipeline, SyntheticLM
from repro.models import build_model
from repro.optim import AdamW
from repro.optim.schedule import cosine_with_warmup
from repro.train.loop import FailureInjector, LoopConfig, train_loop
from repro.train.step import TrainState, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fail-at", type=int, default=None, help="inject a failure (FT demo)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    cfg = cfg.replace(train_microbatches=args.microbatches)

    model = build_model(cfg)
    opt = AdamW()
    lr_fn = cosine_with_warmup(args.lr, warmup=max(args.steps // 20, 1), total=args.steps)
    step_fn = jax.jit(
        make_train_step(model.loss_fn, opt, lr_fn, microbatches=cfg.train_microbatches),
        donate_argnums=(0,),
    )

    params = model.init(jax.random.key(args.seed))
    state = TrainState(params=params, opt=opt.init(params))
    n_params = sum(int(x.size) for x in jax.tree.leaves(params))
    print(f"{cfg.name}: {n_params/1e6:.2f}M params")

    source = SyntheticLM(cfg.vocab_size, args.seq, args.batch, seed=args.seed)

    def batch_fn(step):
        b = source.batch_at(step)
        if cfg.family in ("vlm", "encdec"):
            b["frontend"] = np.zeros((args.batch, cfg.frontend_len, cfg.d_model), np.float32)
        return b

    pipeline = DataPipeline(batch_fn, prefetch=2)
    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    injector = FailureInjector(args.fail_at) if args.fail_at else None

    state, history = train_loop(
        step_fn,
        state,
        pipeline,
        ckpt=ckpt,
        cfg=LoopConfig(total_steps=args.steps, ckpt_every=args.ckpt_every),
        injector=injector,
        on_metrics=lambda r: print(
            f"step {r['step']:5d}  loss {r['loss']:.4f}  |g| {r['grad_norm']:.3f}  "
            f"{r['step_time_s']*1e3:.0f} ms"
        ),
    )
    pipeline.close()
    print(f"final loss {history[-1]['loss']:.4f} (first {history[0]['loss']:.4f})")
    return history


if __name__ == "__main__":
    main()
