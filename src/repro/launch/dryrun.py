import os
os.environ["XLA_FLAGS"] = os.environ.get("REPRO_XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell on the
production mesh (16x16 single-pod / 2x16x16 multi-pod) and record per-device
memory, FLOPs, and collective traffic for the roofline report.

The XLA_FLAGS assignment above MUST precede any jax import (device count is
locked at first backend init).  Run as:

    PYTHONPATH=src python -m repro.launch.dryrun [--arch A] [--shape S]
        [--mesh single|multi|both] [--out artifacts/dryrun]

Exit code is non-zero if any requested cell fails to compile.
"""
import argparse  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from pathlib import Path  # noqa: E402


from repro.configs import CONFIGS, SHAPES, get_config, get_shape  # noqa: E402
from repro.launch.cell import Cell, analytic_memory, build_cell, cost_reference  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.perfmodel.costs import extract_costs  # noqa: E402
from repro.perfmodel.hlo import collective_bytes  # noqa: E402
from repro.perfmodel.roofline import roofline  # noqa: E402


def run_cell(
    cell: Cell,
    out_dir: Path,
    save_hlo: bool = False,
    ref: dict | None = None,
    hw: str = "tpu-v5e",
) -> dict:
    """Lower+compile one cell and record costs + roofline terms against
    ``hw`` (any part in the repro.hw spec database; default the TPU target)."""
    t0 = time.time()
    lowered = cell.lower()
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    costs = extract_costs(compiled)
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    chips = cell.mesh.devices.size

    # loop-trip-count correction: XLA cost_analysis counts while bodies once.
    if ref is None:
        ref = cost_reference(cell.cfg, cell.shape)
    if ref.get("global_flops"):
        scanned = max(costs.flops_per_device, 1.0)
        ratio = max((ref["global_flops"] / chips) / scanned, 1.0)
        costs.flops_per_device = ref["global_flops"] / chips
        costs.bytes_per_device = costs.bytes_per_device * ratio
    else:
        ratio = 1.0

    tokens = (
        cell.shape.global_batch
        if cell.shape.kind == "decode"
        else cell.shape.global_batch * cell.shape.seq_len
    )
    rt = roofline(
        costs,
        coll,
        chips=chips,
        kind=cell.shape.kind,
        n_params_active=cell.n_params_active,
        tokens=tokens,
        hw=hw,
    )
    rec = {
        "cell": cell.name,
        "hw": rt.hw,
        "arch": cell.cfg.name,
        "shape": cell.shape.name,
        "mesh": dict(cell.mesh.shape),
        "chips": chips,
        "kind": cell.shape.kind,
        "n_params": cell.n_params,
        "n_params_active": cell.n_params_active,
        "lower_s": t_lower,
        "compile_s": t_compile,
        "loop_correction": ratio,
        "cost_reference": ref,
        "memory": costs.summary(),
        "analytic_memory": analytic_memory(cell),
        "collectives": coll.summary(),
        "roofline": rt.summary(),
        "ok": True,
    }
    if save_hlo:
        (out_dir / f"{cell.name}.hlo.txt").write_text(hlo)
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", action="append", default=None, help="arch id (repeatable)")
    ap.add_argument("--shape", action="append", default=None, help="shape id (repeatable)")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--microbatches", type=int, default=None, help="override per-arch value")
    ap.add_argument("--hw", default="tpu-v5e",
                    help="repro.hw spec-DB part to roofline against (name or alias)")
    args = ap.parse_args(argv)

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    archs = args.arch or sorted(CONFIGS)
    shapes = args.shape or list(SHAPES)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    n_fail = 0
    for arch in archs:
        cfg = get_config(arch)
        if args.microbatches:
            cfg = cfg.replace(train_microbatches=args.microbatches)
        for shape_name in shapes:
            shape = get_shape(shape_name)
            if not cfg.supports_shape(shape):
                rec = {
                    "cell": f"{arch}__{shape_name}",
                    "arch": arch,
                    "shape": shape_name,
                    "ok": True,
                    "skipped": "inapplicable (full-attention arch on long_500k; see DESIGN.md)",
                }
                (out_dir / f"{arch}__{shape_name}__skip.json").write_text(json.dumps(rec, indent=2))
                print(f"[skip] {arch} x {shape_name}: inapplicable")
                continue
            ref = None  # shared across meshes for this (arch, shape)
            for multi in meshes:
                tag = "multi" if multi else "single"
                path = out_dir / f"{arch}__{shape_name}__{tag}.json"
                if args.skip_existing and path.exists():
                    try:
                        if json.loads(path.read_text()).get("ok"):
                            print(f"[keep] {path.name}")
                            continue
                    except Exception:  # noqa: BLE001
                        pass
                mesh = make_production_mesh(multi_pod=multi)
                try:
                    cell = build_cell(cfg, shape, mesh)
                    if ref is None:
                        t0 = time.time()
                        ref = cost_reference(cfg, shape)
                        print(f"[ref]  {arch} x {shape_name}: "
                              f"{ref['global_flops']/1e12:.1f} TF global ({time.time()-t0:.0f}s)")
                    rec = run_cell(cell, out_dir, save_hlo=args.save_hlo, ref=ref,
                                   hw=args.hw)
                    mem_gib = rec["memory"]["peak_hbm_bytes"] / 2**30
                    an_gib = rec["analytic_memory"]["analytic_peak_bytes"] / 2**30
                    print(
                        f"[ok]   {rec['cell']}: compile {rec['compile_s']:.1f}s, "
                        f"mem/dev {mem_gib:.2f} GiB (analytic {an_gib:.2f}), "
                        f"dominant {rec['roofline']['dominant']}, "
                        f"roofline {rec['roofline']['roofline_fraction']:.3f}"
                    )
                except Exception as e:  # noqa: BLE001
                    n_fail += 1
                    rec = {
                        "cell": f"{arch}__{shape_name}__{tag}",
                        "arch": arch,
                        "shape": shape_name,
                        "mesh_tag": tag,
                        "ok": False,
                        "error": f"{type(e).__name__}: {e}",
                        "traceback": traceback.format_exc()[-4000:],
                    }
                    print(f"[FAIL] {arch} x {shape_name} x {tag}: {type(e).__name__}: {e}")
                path.write_text(json.dumps(rec, indent=2))
    print(f"done; failures: {n_fail}")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
