"""Serving driver: batched requests through the continuous-batching engine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-14b --reduced \
        --requests 6 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serve import ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.family == "encdec":
        raise SystemExit("serve driver targets decoder-only archs; whisper uses examples/")

    model = build_model(cfg)
    params = model.init(jax.random.key(args.seed))
    engine = ServeEngine(model, params, n_slots=args.slots, max_len=args.max_len)

    rng = np.random.default_rng(args.seed)
    t0 = time.perf_counter()
    reqs = [
        engine.submit(list(rng.integers(1, cfg.vocab_size, args.prompt_len)), args.max_new)
        for _ in range(args.requests)
    ]
    finished = engine.run()
    dt = time.perf_counter() - t0
    tokens = sum(len(r.out) for r in finished)
    print(f"served {len(finished)}/{len(reqs)} requests, {tokens} tokens in {dt:.2f}s "
          f"({tokens/dt:.1f} tok/s)")
    for r in finished[:4]:
        print(f"  req {r.rid}: {r.out[:10]}{'...' if len(r.out) > 10 else ''}")
    return finished


if __name__ == "__main__":
    main()
