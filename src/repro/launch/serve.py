"""Serving driver: batched requests through the pluggable serving engine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-14b --reduced \
        --requests 6 --max-new 16 --scheduler priority --backend xla

Paged KV + shared prefix (see docs/serving.md):

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --reduced \
        --requests 8 --slots 6 --page-size 16 --n-pages 48 --shared-prefix 12

Cluster scale-out (see docs/scaling.md) — data-parallel replicas, optional
tensor-parallel decode per replica (``--tp > 1`` wants multiple devices;
force fake ones with XLA_FLAGS=--xla_force_host_platform_device_count=8):

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --reduced \
        --requests 12 --replicas 2 --tp 2 --router least_loaded

Chaos drill (see docs/robustness.md) — seeded fault schedule against a
health-monitored cluster; deadlines bound per-request latency:

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --reduced \
        --requests 12 --replicas 2 --health --chaos --chaos-seed 7 \
        --deadline-s 30
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serve import (
    SCHEDULERS,
    ClusterConfig,
    ClusterRouter,
    EngineConfig,
    FaultInjector,
    FaultPlan,
    HealthConfig,
    ServeEngine,
    UnsupportedFamilyError,
    make_router,
)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--prefill-chunk", type=int, default=16)
    ap.add_argument("--scheduler", choices=sorted(SCHEDULERS), default="fcfs")
    ap.add_argument("--backend", choices=("pallas", "interpret", "xla"), default=None,
                    help="kernel_policy backend for the engine's compiled steps")
    ap.add_argument("--page-size", type=int, default=None,
                    help="KV slots per page; enables the paged KV pool "
                         "(default: dense per-slot regions)")
    ap.add_argument("--n-pages", type=int, default=None,
                    help="page-pool size (default: worst case, "
                         "slots * ceil(max_len/page_size)); set lower to "
                         "oversubscribe slots against real KV memory")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="length of a common prefix prepended to every "
                         "prompt and registered once via register_prefix "
                         "(paged mode only)")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--replicas", type=int, default=1,
                    help="data-parallel engine replicas behind one router "
                         "(>1 selects the ClusterRouter path)")
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel degree per replica (devices per "
                         "engine mesh; >1 selects the ClusterRouter path)")
    ap.add_argument("--router", default="least_loaded",
                    help="replica placement policy (cluster path only): any "
                         "built-in or register_router()-registered name")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request deadline in seconds; expired requests "
                         "finish with finish_reason='deadline'")
    ap.add_argument("--health", action="store_true",
                    help="enable health monitoring on the cluster path "
                         "(heartbeat + straggler failover, circuit breaker)")
    ap.add_argument("--chaos", action="store_true",
                    help="drive the run through a FaultInjector with a "
                         "seeded random fault schedule")
    ap.add_argument("--chaos-seed", type=int, default=0,
                    help="seed for FaultPlan.random (with --chaos)")
    ap.add_argument("--chaos-faults", type=int, default=4,
                    help="number of scheduled faults (with --chaos)")
    args = ap.parse_args(argv)
    try:  # fail fast on a bad router name; the error lists registered names
        make_router(args.router)
    except ValueError as e:
        raise SystemExit(str(e)) from None

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()

    model = build_model(cfg)
    params = model.init(jax.random.key(args.seed))
    engine_cfg = EngineConfig(
        n_slots=args.slots,
        max_len=args.max_len,
        prefill_chunk=args.prefill_chunk,
        page_size=args.page_size,
        n_pages=args.n_pages,
        backend=args.backend,
        scheduler=args.scheduler,
    )
    clustered = args.replicas > 1 or args.tp > 1
    if args.tp > cfg.max_useful_tp():
        print(
            f"note: --tp {args.tp} exceeds {args.arch}'s max useful TP "
            f"{cfg.max_useful_tp()} (n_heads={cfg.n_heads}, "
            f"n_kv_heads={cfg.n_kv_heads}); extra devices stay replicated"
        )
    try:
        if clustered:
            engine = ClusterRouter(model, params, ClusterConfig(
                engine=engine_cfg, n_replicas=args.replicas, tp=args.tp,
                router=args.router,
                health=HealthConfig() if args.health else None))
        else:
            engine = ServeEngine(model, params, engine_cfg)
    except UnsupportedFamilyError as e:
        raise SystemExit(str(e)) from None

    rng = np.random.default_rng(args.seed)
    prefix = []
    if args.shared_prefix:
        if args.page_size is None:
            raise SystemExit("--shared-prefix requires --page-size (paged KV)")
        prefix = [int(t) for t in rng.integers(1, cfg.vocab_size, args.shared_prefix)]
        engine.register_prefix(prefix)
    try:
        sessions = [
            engine.submit(
                prefix + list(rng.integers(1, cfg.vocab_size, args.prompt_len)),
                args.max_new,
                priority=i % 3,  # exercise the priority axis under --scheduler priority
                deadline_s=args.deadline_s,
            )
            for i in range(args.requests)
        ]
    except UnsupportedFamilyError as e:  # cluster replicas build lazily here
        raise SystemExit(str(e)) from None
    injector = None
    if args.chaos:
        plan = FaultPlan.random(
            args.chaos_seed, n_faults=args.chaos_faults,
            n_replicas=args.replicas if clustered else 1)
        injector = FaultInjector(plan, engine)
        finished = injector.run()
    else:
        finished = engine.run()
    s = engine.summary()
    if clustered:
        per = s["per_replica"]
        print(
            f"cluster: {s['replicas']} replica(s) x tp={s['tp']} "
            f"({args.router}); requests per replica: "
            f"{[r['requests'] for r in per]}"
        )
    print(
        f"served {len(finished)}/{len(sessions)} requests, "
        f"{s['generated_tokens']} tokens in {s['total_s']:.2f}s "
        f"({s['throughput_tok_s']:.1f} tok/s, prefill {s['prefill_tok_s']:.1f} tok/s)"
    )
    print(
        f"ttft {s['ttft_ms_mean']:.1f}ms mean / {s['ttft_ms_p95']:.1f}ms p95; "
        f"per-token p50 {s['tok_latency_ms_p50']:.2f}ms p95 "
        f"{s['tok_latency_ms_p95']:.2f}ms; occupancy {s['occupancy']:.0%}"
    )
    if args.page_size is not None:
        n_pages = (sum(r.engine.n_pages for r in engine.replicas) if clustered
                   else engine.n_pages)
        print(
            f"paged KV: {n_pages} pages x {args.page_size} slots, "
            f"peak {s['pages_peak']} used ({s['page_occupancy']:.0%} mean), "
            f"{s['preemptions']} preemptions, "
            f"{s['prefix_tokens_reused']} prefix tokens reused "
            f"({s['prefix_hits']} hits)"
        )
    if injector is not None:
        inj = injector.summary()
        applied = {k: v for k, v in inj["applied"].items() if v}
        print(
            f"chaos: {inj['plan_faults']} scheduled fault(s), "
            f"applied {applied}, {inj['skipped']} skipped, "
            f"{inj['crash_ticks']} crashed tick(s)"
        )
    if injector is not None or args.deadline_s is not None or args.health:
        line = (
            f"robustness: goodput {s['goodput_tok_s']:.1f} tok/s, "
            f"{s['deadline_expired']} deadline-expired, "
            f"{s['requeues']} requeues, {s['quarantines']} quarantines, "
            f"{s['degradations']} degradations"
        )
        if clustered:
            line += (f", availability {s['availability']:.0%}, "
                     f"failovers {s['failovers']}")
        print(line)
    for sess in finished[:4]:
        print(f"  req {sess.rid} [{sess.finish_reason}]: "
              f"{sess.out[:10]}{'...' if len(sess.out) > 10 else ''}")
    return finished


if __name__ == "__main__":
    main()
