"""Production mesh construction.

Single pod: 256 chips as (data=16, model=16) — ``model`` maps to the 16
ICI-adjacent chips of a v5e torus row (TP wants the fastest links); ``data``
carries gradient reduction.  Multi-pod: a leading ``pod`` axis (DCI links;
gradient-only traffic, compressible via dist.compress).

Functions, not module constants: importing this module must never touch jax
device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

try:  # jax >= 0.5: explicit axis types (Auto keeps the pre-0.5 behavior)
    from jax.sharding import AxisType
except ImportError:  # older jax: make_mesh has no axis_types parameter
    AxisType = None


def _mk(shape, axes) -> Mesh:
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_test_mesh(*, multi_pod: bool = False) -> Mesh:
    """Small mesh for CI-size integration tests (needs 8 fake devices)."""
    shape = (2, 2, 2) if multi_pod else (2, 4)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_mesh_for(devices: int, model_parallel: int, pods: int = 1) -> Mesh:
    """Elastic-scaling helper: any (pods, data, model) factorization."""
    per_pod = devices // pods
    data = per_pod // model_parallel
    assert pods * data * model_parallel == devices, (devices, model_parallel, pods)
    if pods > 1:
        return _mk((pods, data, model_parallel), ("pod", "data", "model"))
    return _mk((data, model_parallel), ("data", "model"))
