"""Dry-run cell construction: (arch x shape x mesh) -> a lowerable jit'd
step function with full input/output shardings and donation.

This is the single source of truth used by the dry-run, the roofline
report (``benchmarks/roofline.py``), and the §Perf hillclimb
(``benchmarks/hillclimb.py``, which imports this module to re-lower cells
under modified configs — the dependency runs from that entry point into
here, never the reverse).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.dist.sharding import (
    activation_sharding,
    batch_shardings,
    cache_shardings,
    logits_sharding,
    param_specs,
)
from repro.dist.zero import zero1_state_specs
from repro.models import build_model
from repro.models.api import input_specs
from repro.optim import AdamW
from repro.optim.schedule import cosine_with_warmup
from repro.train.step import TrainState, make_train_step, state_shapes


@dataclass
class Cell:
    cfg: ModelConfig
    shape: ShapeConfig
    mesh: Mesh
    fn: Callable
    args: tuple
    in_shardings: Any
    out_shardings: Any
    donate: tuple
    n_params: float
    n_params_active: float

    @property
    def name(self) -> str:
        pods = self.mesh.shape.get("pod", 1)
        return f"{self.cfg.name}__{self.shape.name}__{'multi' if pods > 1 else 'single'}"

    def lower(self):
        with activation_sharding(self.mesh):
            jfn = jax.jit(
                self.fn,
                in_shardings=self.in_shardings,
                out_shardings=self.out_shardings,
                donate_argnums=self.donate,
            )
            return jfn.lower(*self.args)


# ---------------------------------------------------------------------------
def count_params_shapes(tree) -> float:
    return float(sum(int(l.size) for l in jax.tree.leaves(tree)))


def count_active_params(cfg: ModelConfig, tree) -> float:
    """MoE: experts count at k/E weight; everything else fully."""
    total = count_params_shapes(tree)
    if cfg.family != "moe" or not cfg.n_experts:
        return total
    expert = 0.0

    def walk(path, leaf):
        nonlocal expert
        pstr = "/".join(str(getattr(p, "key", p)) for p in path)
        if "moe/wi_gate" in pstr or "moe/wi_up" in pstr or "moe/wo" in pstr:
            expert += float(leaf.size)
        return leaf

    jax.tree_util.tree_map_with_path(walk, tree)
    frac = cfg.experts_per_token / cfg.n_experts
    return total - expert * (1.0 - frac)


def _rep(mesh: Mesh):
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# cost reference: XLA's cost_analysis does NOT multiply while-loop bodies by
# trip count, so scanned programs under-report FLOPs.  The reference lowers a
# fully-unrolled, scan-free variant (layers unrolled, naive attention, whole-
# sequence SSD chunk, no microbatching) WITHOUT sharding or compilation and
# reads global FLOPs off the lowered module.  Remat is kept, so backward
# recompute is counted (that is real work the TPU performs).
# ---------------------------------------------------------------------------
def cost_reference(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    ref_cfg = cfg.replace(
        scan_layers=False,
        attn_impl="naive",
        ssm_chunk=max(shape.seq_len, cfg.ssm_chunk),
        train_microbatches=1,
    )
    model = build_model(ref_cfg)
    if shape.kind == "train":
        opt = AdamW()
        lr_fn = cosine_with_warmup(3e-4, warmup=2000, total=100_000)
        step = make_train_step(model.loss_fn, opt, lr_fn, microbatches=1)
        state_sh = state_shapes(model.init, opt)
        batch = input_specs(ref_cfg, shape)
        lowered = jax.jit(step).lower(state_sh, batch)
    elif shape.kind == "prefill":
        batch = input_specs(ref_cfg, shape)
        params_sh = jax.eval_shape(lambda: model.init(jax.random.key(0)))
        lowered = jax.jit(lambda p, b: model.prefill(p, b, shape.seq_len)).lower(
            params_sh, batch
        )
    else:
        specs = input_specs(ref_cfg, shape)
        params_sh = jax.eval_shape(lambda: model.init(jax.random.key(0)))
        lowered = jax.jit(model.decode_step).lower(
            params_sh, specs["cache"], specs["tokens"], specs["pos"]
        )
    from repro.perfmodel.costs import _as_cost_dict

    ca = _as_cost_dict(lowered.cost_analysis())
    return {
        "global_flops": float(ca.get("flops", 0.0)),
        "global_bytes_prefusion": float(ca.get("bytes accessed", 0.0)),
        "transcendentals": float(ca.get("transcendentals", 0.0)),
    }


# ---------------------------------------------------------------------------
def _local_bytes(tree_shapes, tree_shardings) -> int:
    """Exact per-device bytes of a sharded tree."""
    total = 0
    for leaf, sh in zip(jax.tree.leaves(tree_shapes), jax.tree.leaves(tree_shardings)):
        div = 1
        if isinstance(sh, NamedSharding):
            mesh = sh.mesh
            for ax in sh.spec:
                if ax is None:
                    continue
                for a in (ax if isinstance(ax, tuple) else (ax,)):
                    div *= mesh.shape[a]
        total += leaf.size * leaf.dtype.itemsize // div
    return int(total)


def analytic_memory(cell: "Cell") -> dict:
    """TPU-expectation HBM footprint (the CPU-compiled memory_analysis keeps
    f32 copies of bf16 buffers — see perfmodel.costs).  Exact for state/cache
    bytes (from the actual shardings); formulaic for live activations."""
    cfg, shape, mesh = cell.cfg, cell.shape, cell.mesh
    amap_dp = 1
    for n in ("pod", "data"):
        if n in mesh.shape:
            amap_dp *= mesh.shape[n]
    tp = mesh.shape.get("model", 1)

    out = {}
    if shape.kind == "train":
        state_sh, batch = cell.args
        state_bytes = _local_bytes(state_sh, cell.in_shardings[0])
        grads = _local_bytes(state_sh.params, cell.in_shardings[0].params)
        b_local = max(shape.global_batch // (amap_dp * cfg.train_microbatches), 1)
        s_local = max(shape.seq_len // tp, 1)  # sp-sharded saves
        layers = cfg.n_layers
        saves = layers * b_local * shape.seq_len // tp * cfg.d_model * 2
        logits = b_local * shape.seq_len * max(cfg.padded_vocab // tp, 1) * 6
        act_live = int(2.5 * b_local * shape.seq_len * cfg.d_model * 4)  # one-layer bwd
        out = {
            "state_bytes": state_bytes,
            "grad_bytes": grads,
            "saves_bytes": saves,
            "logits_bytes": logits,
            "act_live_bytes": act_live,
            "analytic_peak_bytes": state_bytes + grads + saves + logits + act_live,
        }
    elif shape.kind == "prefill":
        params_sh, batch = cell.args
        pbytes = _local_bytes(params_sh, cell.in_shardings[0])
        cache_sd = jax.eval_shape(cell.fn, *cell.args)[1]
        cbytes = _local_bytes(cache_sd, cell.out_shardings[1])
        b_local = max(shape.global_batch // amap_dp, 1)
        act = int(3 * b_local * shape.seq_len // tp * cfg.d_model * 2 * 4)
        out = {
            "param_bytes": pbytes,
            "cache_bytes": cbytes,
            "act_live_bytes": act,
            "analytic_peak_bytes": pbytes + 2 * cbytes + act,
        }
    else:  # decode
        params_sh = cell.args[0]
        pbytes = _local_bytes(params_sh, cell.in_shardings[0])
        cbytes = _local_bytes(cell.args[1], cell.in_shardings[1])
        out = {
            "param_bytes": pbytes,
            "cache_bytes": cbytes,
            "analytic_peak_bytes": pbytes + cbytes + (cbytes // 4),
        }
    return out


def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh) -> Cell:
    model = build_model(cfg)
    if shape.kind == "train":
        return _build_train(cfg, shape, mesh, model)
    if shape.kind == "prefill":
        return _build_prefill(cfg, shape, mesh, model)
    return _build_decode(cfg, shape, mesh, model)


def _build_train(cfg, shape, mesh, model) -> Cell:
    opt = AdamW()
    lr_fn = cosine_with_warmup(3e-4, warmup=2000, total=100_000)

    state_sh = state_shapes(model.init, opt)
    pspecs = param_specs(state_sh.params, cfg, mesh)
    zspecs = zero1_state_specs(state_sh.params, pspecs, mesh)
    # ZeRO staging: 1 = optimizer state sharded over data; 2 = +grad
    # accumulation sharded; 3 = +fp32 master params sharded (FSDP storage;
    # XLA all-gathers per-layer slices inside the scan for compute)
    mspecs = zspecs if cfg.zero_stage >= 1 else pspecs
    gspecs = zspecs if cfg.zero_stage >= 2 else None
    pstore = zspecs if cfg.zero_stage >= 3 else pspecs
    opt_sh = type(state_sh.opt)(step=_rep(mesh), mu=mspecs, nu=mspecs)
    state_shardings = TrainState(params=pstore, opt=opt_sh)

    step = make_train_step(
        model.loss_fn,
        opt,
        lr_fn,
        microbatches=cfg.train_microbatches,
        grad_shardings=gspecs,
    )

    batch = input_specs(cfg, shape)
    bshard = batch_shardings(batch, mesh)
    metrics_sh = {"loss": _rep(mesh), "grad_norm": _rep(mesh), "lr": _rep(mesh)}

    return Cell(
        cfg=cfg,
        shape=shape,
        mesh=mesh,
        fn=step,
        args=(state_sh, batch),
        in_shardings=(state_shardings, bshard),
        out_shardings=(state_shardings, metrics_sh),
        donate=(0,),
        n_params=count_params_shapes(state_sh.params),
        n_params_active=count_active_params(cfg, state_sh.params),
    )


def _serving_params(model):
    """Serving holds bf16 weights (the training fp32 master stays on the
    trainer); float leaves are served in bf16."""
    sd = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    return jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(
            l.shape, jnp.bfloat16 if jnp.issubdtype(l.dtype, jnp.floating) else l.dtype
        ),
        sd,
    )


def _serving_pspecs(params_sh, cfg, mesh):
    pspecs = param_specs(params_sh, cfg, mesh)
    if cfg.serve_param_fsdp:
        pspecs = zero1_state_specs(params_sh, pspecs, mesh)
    return pspecs


def _build_prefill(cfg, shape, mesh, model) -> Cell:
    batch = input_specs(cfg, shape)
    params_sh = _serving_params(model)
    pspecs = _serving_pspecs(params_sh, cfg, mesh)
    bshard = batch_shardings(batch, mesh)

    def fn(params, batch):
        return model.prefill(params, batch, shape.seq_len)

    out_sd = jax.eval_shape(fn, params_sh, batch)  # (logits, cache)
    lsh = logits_sharding(shape.global_batch, cfg.vocab_size, mesh)
    cshard = cache_shardings(out_sd[1], cfg, mesh)

    return Cell(
        cfg=cfg,
        shape=shape,
        mesh=mesh,
        fn=fn,
        args=(params_sh, batch),
        in_shardings=(pspecs, bshard),
        out_shardings=(lsh, cshard),
        donate=(),
        n_params=count_params_shapes(params_sh),
        n_params_active=count_active_params(cfg, params_sh),
    )


def _build_decode(cfg, shape, mesh, model) -> Cell:
    specs = input_specs(cfg, shape)
    params_sh = _serving_params(model)
    pspecs = _serving_pspecs(params_sh, cfg, mesh)
    cshard = cache_shardings(specs["cache"], cfg, mesh)
    tp_sh = batch_shardings(
        {"tokens": specs["tokens"], "pos": specs["pos"]}, mesh
    )
    lsh = logits_sharding(shape.global_batch, cfg.vocab_size, mesh)

    def fn(params, cache, tokens, pos):
        return model.decode_step(params, cache, tokens, pos)

    return Cell(
        cfg=cfg,
        shape=shape,
        mesh=mesh,
        fn=fn,
        args=(params_sh, specs["cache"], specs["tokens"], specs["pos"]),
        in_shardings=(pspecs, cshard, tp_sh["tokens"], tp_sh["pos"]),
        out_shardings=(lsh, cshard),
        donate=(1,),
        n_params=count_params_shapes(params_sh),
        n_params_active=count_active_params(cfg, params_sh),
    )
