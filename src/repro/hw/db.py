"""The hardware spec database: register / get / query / compare.

The paper frames its T4 findings as one column of a cross-generation table
(T4 vs P4 vs V100), and its sequels (Volta, Ampere, Hopper, Blackwell
dissections) extend the same table over time.  This module is that table as
a queryable registry:

    repro.hw.get("T4").peak("int8")
    repro.hw.query(dtype="int8", min_peak=500e12)
    repro.hw.compare("T4", "P4")["peak_ratio"]["int8"]

Names are normalized (case-insensitive, ``_``/space -> ``-``) and every part
can carry aliases, so ``get("T4")``, ``get("t4")``, and the canonical
``get("nvidia-t4-paper")`` resolve to the same record.  ``resolve`` accepts
either a name or an existing :class:`HardwareModel`, which is how every
consumer (roofline, dissect, autotune) takes its ``hw=`` argument.
"""
from __future__ import annotations

from typing import Callable, Iterable, Optional, Union

from .model import HardwareModel

_DB: dict[str, HardwareModel] = {}
_ALIASES: dict[str, str] = {}  # normalized alias -> canonical name


def _norm(name: str) -> str:
    return name.strip().lower().replace("_", "-").replace(" ", "-")


def register(
    model: HardwareModel,
    aliases: Iterable[str] = (),
    overwrite: bool = False,
) -> HardwareModel:
    """Add ``model`` to the database under its canonical name plus aliases."""
    key = _norm(model.name)
    if not overwrite and key in _DB:
        raise ValueError(f"hardware model {model.name!r} already registered")
    _DB[key] = model
    for a in aliases:
        na = _norm(a)
        owner = _ALIASES.get(na)
        if na in _DB and na != key:
            raise ValueError(f"alias {a!r} shadows registered part {na!r}")
        if not overwrite and owner not in (None, key):
            raise ValueError(f"alias {a!r} already taken by {owner!r}")
        _ALIASES[na] = key
    return model


def unregister(name: str) -> None:
    """Remove a registration and its aliases (test helper)."""
    key = _ALIASES.get(_norm(name), _norm(name))
    _DB.pop(key, None)
    for a in [a for a, k in _ALIASES.items() if k == key]:
        del _ALIASES[a]


def get(name: str) -> HardwareModel:
    key = _norm(name)
    key = _ALIASES.get(key, key)
    try:
        return _DB[key]
    except KeyError:
        raise KeyError(
            f"unknown hardware {name!r}; registered: {', '.join(names())}"
        ) from None


def resolve(hw: Union[str, HardwareModel]) -> HardwareModel:
    """Name-or-model -> model; the contract behind every ``hw=`` argument."""
    if isinstance(hw, HardwareModel):
        return hw
    if isinstance(hw, str):
        return get(hw)
    raise TypeError(f"hw must be a name or HardwareModel, got {type(hw).__name__}")


def names() -> list:
    return sorted(_DB)


def models() -> list:
    return [_DB[n] for n in names()]


def query(
    dtype: Optional[str] = None,
    min_peak: float = 0.0,
    vendor: Optional[str] = None,
    arch: Optional[str] = None,
    min_memory_bytes: int = 0,
    min_memory_Bps: float = 0.0,
    max_power_w: float = 0.0,
    predicate: Optional[Callable[[HardwareModel], bool]] = None,
) -> list:
    """Parts matching every given filter, fastest-first on the queried dtype.

    ``dtype`` restricts to parts that publish that precision; ``min_peak``
    (FLOP/s) applies to that dtype's peak (requires ``dtype``).  Results are
    sorted by the dtype peak when given, else by name.
    """
    if min_peak and not dtype:
        raise ValueError("min_peak requires dtype= (which peak to gate on)")
    out = []
    for hw in _DB.values():
        if dtype is not None and not hw.supports(dtype):
            continue
        if dtype is not None and hw.peak(dtype) < min_peak:
            continue
        if vendor is not None and _norm(hw.vendor) != _norm(vendor):
            continue
        if arch is not None and _norm(hw.arch) != _norm(arch):
            continue
        if hw.main_memory_bytes < min_memory_bytes:
            continue
        if hw.main_memory_Bps < min_memory_Bps:
            continue
        if max_power_w and hw.power_limit_w > max_power_w:
            continue
        if predicate is not None and not predicate(hw):
            continue
        out.append(hw)
    if dtype is not None:
        out.sort(key=lambda h: h.peak(dtype), reverse=True)
    else:
        out.sort(key=lambda h: h.name)
    return out


def _ratio(a: float, b: float) -> float:
    return a / b if b else 0.0


def compare(
    a: Union[str, HardwareModel],
    b: Union[str, HardwareModel],
    dtypes: Optional[Iterable[str]] = None,
) -> dict:
    """Cross-generation comparison record for two parts (a relative to b).

    The shape of the paper's Tables 3.1/4.3 columns, as data: per-dtype
    peaks and their a/b ratios (over the shared dtypes unless ``dtypes``
    pins the list), memory capacity/bandwidth/clock/core/power ratios, and
    the two memory hierarchies side by side.
    """
    ha, hb = resolve(a), resolve(b)
    shared = [d for d in ha.dtypes() if hb.supports(d)]
    dts = list(dtypes) if dtypes is not None else shared
    return {
        "a": ha.name,
        "b": hb.name,
        "dtypes": dts,
        "peaks": {
            "a": {d: ha.peak(d) for d in dts if ha.supports(d)},
            "b": {d: hb.peak(d) for d in dts if hb.supports(d)},
        },
        "peak_ratio": {
            d: _ratio(ha.peak(d), hb.peak(d))
            for d in dts
            if ha.supports(d) and hb.supports(d)
        },
        "main_memory_Bps_ratio": _ratio(ha.main_memory_Bps, hb.main_memory_Bps),
        "main_memory_bytes_ratio": _ratio(ha.main_memory_bytes, hb.main_memory_bytes),
        "clock_ratio": _ratio(ha.clock_hz, hb.clock_hz),
        "num_cores_ratio": _ratio(ha.num_cores, hb.num_cores),
        "power_ratio": _ratio(ha.power_limit_w, hb.power_limit_w),
        "levels": {
            "a": [(l.name, l.size_bytes, l.latency_ns) for l in ha.levels],
            "b": [(l.name, l.size_bytes, l.latency_ns) for l in hb.levels],
        },
    }
