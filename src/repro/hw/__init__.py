"""repro.hw — the multi-generation hardware spec database.

The paper's quantitative hardware model, generalized from one part (the T4)
to a queryable registry of parts spanning the paper's own comparison
columns (P4, T4, V100), their successors tracked by the sequel dissections
(A100, H100, B200), and the TPU dry-run target (v5e):

    import repro.hw as hw

    hw.get("T4").peak("int8")                  # Tab 4.3, as data
    hw.query(dtype="int8", min_peak=500e12)    # parts fast enough for a job
    hw.compare("T4", "P4")["peak_ratio"]       # the paper's generation story
    hw.names()                                 # everything registered

Consumers (``perfmodel.roofline``, ``core.dissect``, ``core.autotune``)
accept ``hw=`` as a name or a :class:`HardwareModel`; ``resolve`` is that
contract.  ``fit_from_probes`` registers measured parts into the same
database, so a dissected host is comparable against the paper presets.
The legacy import path ``repro.core.hwmodel`` re-exports this package.

See docs/hardware.md for the schema and how to add a part.
"""
from .db import (
    compare,
    get,
    models,
    names,
    query,
    register,
    resolve,
    unregister,
)
from .model import (
    HardwareModel,
    MemoryLevel,
    UnknownDtypeError,
    fit_from_probes,
)
from .specs import (
    A100,
    B200,
    H100,
    P4,
    T4_PAPER,
    TPU_V5E,
    V100,
)

__all__ = [
    "A100",
    "B200",
    "H100",
    "HardwareModel",
    "MemoryLevel",
    "P4",
    "T4_PAPER",
    "TPU_V5E",
    "UnknownDtypeError",
    "V100",
    "compare",
    "fit_from_probes",
    "get",
    "models",
    "names",
    "query",
    "register",
    "resolve",
    "unregister",
]
