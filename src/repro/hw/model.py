"""`HardwareModel` — the machine-readable analogue of the paper's Table 3.1.

The paper's meta-contribution is a *quantitative hardware model distilled
from microbenchmarks*, presented as a cross-generation comparison (T4 vs P4
vs V100).  ``HardwareModel`` is that object: every consumer (roofline,
autotuner, straggler detector, modeled benchmarks) reads hardware facts from
here, never from scattered constants.  Instances are registered in the
:mod:`repro.hw.db` spec database and looked up by name or alias.

``peak_flops`` is per-dtype (FLOP/s per chip) because the paper's headline
TensorCore result (Table 4.3) *is* a per-dtype table: fp16 runs ~5.8x fp32
on T4, int8 ~1.8x fp16.  ``peak()`` takes an optional ``fallback=`` dtype
(or chain of dtypes) for parts that don't expose the requested precision —
the autotuner uses it so bf16/fp8 tile costing degrades to the nearest
supported precision instead of crashing.
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass
from typing import Iterable, Optional, Union


class UnknownDtypeError(KeyError):
    """Requested a per-dtype peak a part does not publish.

    Subclasses ``KeyError`` for backwards compatibility with callers that
    caught the old bare ``KeyError`` from ``HardwareModel.peak``.
    """

    def __init__(self, part: str, dtype: str, available: Iterable[str]):
        self.part = part
        self.dtype = dtype
        self.available = tuple(sorted(available))
        super().__init__(
            f"{part}: no peak for dtype {dtype!r}; available: "
            f"{', '.join(self.available) or '(none)'} — pass fallback=<dtype> "
            f"to cost against the nearest supported precision"
        )

    def __str__(self) -> str:  # KeyError str() quotes its arg; keep it readable
        return self.args[0]


@dataclass(frozen=True)
class MemoryLevel:
    name: str
    size_bytes: int  # capacity (0 = unbounded, e.g. DRAM/HBM)
    latency_ns: float  # dependent-load latency
    bandwidth_Bps: float  # sustained streaming bandwidth
    line_bytes: int = 0
    shared: bool = False  # shared across cores/SMs or private


@dataclass(frozen=True)
class HardwareModel:
    name: str
    # compute
    peak_flops: dict  # dtype name -> FLOP/s (per chip)
    clock_hz: float
    num_cores: int
    # memory
    levels: tuple  # tuple[MemoryLevel, ...] fastest-first
    main_memory_Bps: float
    main_memory_bytes: int
    # on-chip staging (VMEM on TPU, smem+L1 on GPU)
    staging_bytes: int
    staging_Bps: float
    # interconnect
    ici_Bps_per_link: float = 0.0
    ici_links: int = 0
    dci_Bps: float = 0.0  # cross-pod (data-center interconnect)
    # power/thermal envelope (throttle model inputs, paper §4.5)
    power_limit_w: float = 0.0
    max_temp_c: float = 0.0
    idle_power_w: float = 0.0
    # identity/provenance (spec-database axes)
    vendor: str = ""  # "nvidia" | "google" | ...
    arch: str = ""  # microarchitecture family: "turing", "hopper", ...
    year: int = 0  # launch year (cross-generation ordering)
    source: str = ""  # where the numbers come from (paper table, datasheet)

    def peak(
        self,
        dtype: str,
        fallback: Optional[Union[str, Iterable[str]]] = None,
    ) -> float:
        """Per-chip peak FLOP/s for ``dtype``.

        ``fallback`` is a dtype name (or an ordered chain of names) tried
        when ``dtype`` itself is not published for this part.  With no
        usable fallback, raises :class:`UnknownDtypeError` listing the
        dtypes the part does support.
        """
        if dtype in self.peak_flops:
            return self.peak_flops[dtype]
        if fallback is not None:
            chain = (fallback,) if isinstance(fallback, str) else tuple(fallback)
            for fb in chain:
                if fb in self.peak_flops:
                    return self.peak_flops[fb]
        raise UnknownDtypeError(self.name, dtype, self.peak_flops)

    def supports(self, dtype: str) -> bool:
        return dtype in self.peak_flops

    def dtypes(self) -> tuple:
        """Published peak dtypes, fastest first."""
        return tuple(sorted(self.peak_flops, key=self.peak_flops.get, reverse=True))

    def level(self, name: str) -> MemoryLevel:
        for lvl in self.levels:
            if lvl.name == name:
                return lvl
        raise KeyError(
            f"{self.name}: no memory level {name!r}; "
            f"levels: {', '.join(l.name for l in self.levels)}"
        )

    def mxu_align(self) -> int:
        return 128

    def to_json(self) -> str:
        d = asdict(self)
        d["levels"] = [asdict(l) for l in self.levels]
        return json.dumps(d, indent=2)

    @staticmethod
    def from_json(s: str) -> "HardwareModel":
        d = json.loads(s)
        d["levels"] = tuple(MemoryLevel(**l) for l in d["levels"])
        d["peak_flops"] = dict(d["peak_flops"])
        return HardwareModel(**d)


def fit_from_probes(
    name: str,
    plateau_levels: list,  # [(latency_ns, size_bytes_boundary_or_None), ...]
    stream_Bps: float,
    matmul_flops: dict,
    clock_hz: float = 0.0,
    register: bool = True,
) -> HardwareModel:
    """Build a HardwareModel from dissect.py probe output (measure mode).

    With ``register=True`` (default) the fitted model is registered into the
    spec database under ``name`` (overwriting any previous fit), so measured
    parts are queryable/comparable exactly like the paper presets:
    ``repro.hw.compare("measured-host", "T4")``.
    """
    levels = []
    for i, (lat, size) in enumerate(plateau_levels):
        levels.append(
            MemoryLevel(
                name=f"level{i}",
                size_bytes=int(size) if size else 0,
                latency_ns=float(lat),
                bandwidth_Bps=stream_Bps,
            )
        )
    hw = HardwareModel(
        name=name,
        peak_flops=dict(matmul_flops),
        clock_hz=clock_hz,
        num_cores=1,
        levels=tuple(levels),
        main_memory_Bps=stream_Bps,
        main_memory_bytes=0,
        staging_bytes=levels[0].size_bytes if levels else 0,
        staging_Bps=stream_Bps,
        source="fit_from_probes",
    )
    if register:
        from . import db

        db.register(hw, overwrite=True)
    return hw
