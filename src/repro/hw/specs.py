"""Registered presets: the paper's generations and their successors.

Three provenance classes, recorded per-part in ``source``:

- **paper-measured** — the T4 record holds the paper's own *measured*
  numbers (Table 3.1 memory hierarchy, Table 4.3 matmul throughput), so
  validation tests can assert against published results.
- **datasheet** — P4/V100 (the paper's comparison columns) and the
  successor parts (A100/H100/B200, tracked by the sequel dissection papers
  in PAPERS.md) use vendor datasheet peaks (dense, no sparsity) with
  cache latencies from the respective microbenchmark papers where
  available; treat them as modeled anchors, not measurements.
- **assignment constants** — TPU v5e, the dry-run/roofline target.

Latency entries are dependent-load latencies converted to ns at the part's
boost clock.  ``peak_flops`` keys use jnp dtype names (plus ``int4``/``int1``
for the paper's sub-byte TensorCore modes and ``tf32`` where a part has a
distinct TF32 path).
"""
from __future__ import annotations

from .db import register
from .model import HardwareModel, MemoryLevel

# ---------------------------------------------------------------------------
# TPU v5e — the roofline/dry-run target
# ---------------------------------------------------------------------------
TPU_V5E = register(
    HardwareModel(
        name="tpu-v5e",
        peak_flops={
            "bfloat16": 197e12,
            "float32": 49.25e12,  # MXU f32 path ~ bf16/4
            "int8": 394e12,
        },
        clock_hz=1.70e9,  # ~940 MHz x2 issue equivalent; per-chip effective
        num_cores=1,  # v5e is single-TensorCore per chip
        levels=(
            MemoryLevel("vreg", 512 * 1024, 0.6, 0.0, line_bytes=4 * 128),
            MemoryLevel("vmem", 128 * 1024 * 1024, 12.0, 3.3e12, line_bytes=4 * 8 * 128),
            MemoryLevel("hbm", 16 * 1024**3, 450.0, 819e9, line_bytes=512, shared=True),
        ),
        main_memory_Bps=819e9,
        main_memory_bytes=16 * 1024**3,
        staging_bytes=128 * 1024 * 1024,
        staging_Bps=3.3e12,
        ici_Bps_per_link=50e9,  # per the assignment: ~50 GB/s/link
        ici_links=4,  # 2D torus
        dci_Bps=25e9,  # cross-pod effective per-chip share (assumption, see DESIGN)
        power_limit_w=170.0,
        max_temp_c=90.0,
        idle_power_w=60.0,
        vendor="google",
        arch="tpu-v5e",
        year=2023,
        source="assignment constants",
    ),
    aliases=("v5e", "tpu_v5e", "tpuv5e"),
)


# ---------------------------------------------------------------------------
# The paper's T4 (Table 3.1 / 4.3, converted to SI) — validation anchor
# ---------------------------------------------------------------------------
_T4_CLK = 1.59e9  # 1590 MHz max graphics clock

T4_PAPER = register(
    HardwareModel(
        name="nvidia-t4-paper",
        peak_flops={
            # paper Table 4.3 measured matmul throughput (not theoretical peaks)
            "float64": 253e9,
            "float32": 7.174e12,
            "float16": 41.616e12,
            "int8": 74.934e12,
            "int4": 114.384e12,
            "int1": 552.230e12,
        },
        clock_hz=_T4_CLK,
        num_cores=40,  # SMs
        levels=(
            # latency_ns = cycles / 1.59 GHz; sizes from Table 3.1
            MemoryLevel("L1", 64 * 1024, 32 / _T4_CLK * 1e9, 58.8 * 40 * _T4_CLK, 32),
            MemoryLevel("L2", 4096 * 1024, 188 / _T4_CLK * 1e9, 1.27e12, 64, shared=True),
            MemoryLevel("global", 15 * 1024**3, 616 / _T4_CLK * 1e9, 220e9, 512, shared=True),
        ),
        main_memory_Bps=220e9,  # measured (theoretical 320; ratio 68.8%, Tab 3.1)
        main_memory_bytes=15 * 1024**3,
        staging_bytes=64 * 1024 * 40,  # shared memory per chip
        staging_Bps=3.662e12,  # Tab 3.1 actual shared bw
        power_limit_w=70.0,
        max_temp_c=85.0,
        idle_power_w=20.0,
        vendor="nvidia",
        arch="turing",
        year=2018,
        source="paper Tab 3.1 / Tab 4.3 (measured)",
    ),
    aliases=("t4", "t4-paper", "tesla-t4"),
)


# ---------------------------------------------------------------------------
# The paper's comparison columns: P4 (Pascal) and V100 (Volta)
# ---------------------------------------------------------------------------
_P4_CLK = 1.114e9  # Tesla P4 boost

P4 = register(
    HardwareModel(
        name="nvidia-p4",
        peak_flops={
            # GP104 datasheet, dense: fp32 2*2560*clk; int8 via dp4a = 4x fp32;
            # Pascal Tesla fp16 runs at the crippled 1/64 fp32 rate — keeping
            # it in the table is the point: the T4/P4 fp16 ratio is ~467x,
            # the TensorCore story the paper opens with.
            "float64": 0.178e12,
            "float32": 5.704e12,
            "float16": 0.089e12,
            "int8": 22.8e12,
        },
        clock_hz=_P4_CLK,
        num_cores=20,  # SMs
        levels=(
            MemoryLevel("L1", 24 * 1024, 82 / _P4_CLK * 1e9, 0.0, 32),
            MemoryLevel("L2", 2048 * 1024, 216 / _P4_CLK * 1e9, 0.0, 32, shared=True),
            MemoryLevel("global", 8 * 1024**3, 545 / _P4_CLK * 1e9, 192e9, 32, shared=True),
        ),
        main_memory_Bps=192e9,  # GDDR5 theoretical
        main_memory_bytes=8 * 1024**3,
        staging_bytes=96 * 1024 * 20,
        staging_Bps=1.6e12,
        power_limit_w=75.0,
        max_temp_c=85.0,
        idle_power_w=15.0,
        vendor="nvidia",
        arch="pascal",
        year=2016,
        source="datasheet + paper Ch.3 comparison",
    ),
    aliases=("p4", "tesla-p4"),
)

_V100_CLK = 1.38e9  # V100 PCIe boost

V100 = register(
    HardwareModel(
        name="nvidia-v100",
        peak_flops={
            # GV100 datasheet, dense: fp16 on 1st-gen TensorCores (8x fp32),
            # int8 via dp4a (no int8 TC mode on Volta)
            "float64": 7.066e12,
            "float32": 14.13e12,
            "float16": 113.0e12,
            "int8": 56.5e12,
        },
        clock_hz=_V100_CLK,
        num_cores=80,  # SMs
        levels=(
            MemoryLevel("L1", 128 * 1024, 28 / _V100_CLK * 1e9, 0.0, 32),
            MemoryLevel("L2", 6144 * 1024, 193 / _V100_CLK * 1e9, 2.2e12, 64, shared=True),
            MemoryLevel("global", 16 * 1024**3, 1029 / _V100_CLK * 1e9, 750e9, 64, shared=True),
        ),
        main_memory_Bps=750e9,  # HBM2, measured ~83% of the 900 GB/s theoretical
        main_memory_bytes=16 * 1024**3,
        staging_bytes=96 * 1024 * 80,
        staging_Bps=12.0e12,
        power_limit_w=250.0,
        max_temp_c=85.0,
        idle_power_w=25.0,
        vendor="nvidia",
        arch="volta",
        year=2017,
        source="datasheet + Volta dissection (arXiv:1804.06826)",
    ),
    aliases=("v100", "tesla-v100"),
)


# ---------------------------------------------------------------------------
# Successors tracked by the sequel dissections (Ampere / Hopper / Blackwell)
# ---------------------------------------------------------------------------
_A100_CLK = 1.41e9

A100 = register(
    HardwareModel(
        name="nvidia-a100-sxm",
        peak_flops={
            # dense (no 2:4 sparsity), A100 SXM 80GB datasheet
            "float64": 9.7e12,
            "float32": 19.5e12,
            "tf32": 156e12,
            "bfloat16": 312e12,
            "float16": 312e12,
            "int8": 624e12,
            "int4": 1248e12,
        },
        clock_hz=_A100_CLK,
        num_cores=108,  # SMs
        levels=(
            MemoryLevel("L1", 192 * 1024, 33 / _A100_CLK * 1e9, 0.0, 32),
            MemoryLevel("L2", 40 * 1024**2, 200 / _A100_CLK * 1e9, 5.1e12, 64, shared=True),
            MemoryLevel("global", 80 * 1024**3, 404 / _A100_CLK * 1e9, 2.039e12, 64, shared=True),
        ),
        main_memory_Bps=2.039e12,
        main_memory_bytes=80 * 1024**3,
        staging_bytes=164 * 1024 * 108,
        staging_Bps=19.5e12,
        ici_Bps_per_link=50e9,  # NVLink3: 12 links x 50 GB/s
        ici_links=12,
        power_limit_w=400.0,
        max_temp_c=90.0,
        idle_power_w=55.0,
        vendor="nvidia",
        arch="ampere",
        year=2020,
        source="datasheet + Ampere dissection (arXiv:1808.00734 lineage)",
    ),
    aliases=("a100", "a100-sxm"),
)

_H100_CLK = 1.83e9

H100 = register(
    HardwareModel(
        name="nvidia-h100-sxm",
        peak_flops={
            # dense, H100 SXM datasheet; fp8 on 4th-gen TensorCores
            "float64": 34e12,
            "float32": 67e12,
            "tf32": 494.5e12,
            "bfloat16": 989e12,
            "float16": 989e12,
            "float8_e4m3fn": 1979e12,
            "int8": 1979e12,
        },
        clock_hz=_H100_CLK,
        num_cores=132,  # SMs
        levels=(
            MemoryLevel("L1", 256 * 1024, 32 / _H100_CLK * 1e9, 0.0, 32),
            MemoryLevel("L2", 50 * 1024**2, 273 / _H100_CLK * 1e9, 7.5e12, 64, shared=True),
            MemoryLevel("global", 80 * 1024**3, 650 / _H100_CLK * 1e9, 3.35e12, 64, shared=True),
        ),
        main_memory_Bps=3.35e12,
        main_memory_bytes=80 * 1024**3,
        staging_bytes=228 * 1024 * 132,
        staging_Bps=33e12,
        ici_Bps_per_link=50e9,  # NVLink4: 18 links x 50 GB/s
        ici_links=18,
        power_limit_w=700.0,
        max_temp_c=90.0,
        idle_power_w=70.0,
        vendor="nvidia",
        arch="hopper",
        year=2022,
        source="datasheet + Hopper dissection (arXiv:2402.13499)",
    ),
    aliases=("h100", "h100-sxm"),
)

_B200_CLK = 1.965e9

B200 = register(
    HardwareModel(
        name="nvidia-b200",
        peak_flops={
            # dense, B200 datasheet; fp4 is the new Blackwell TC mode — the
            # paper's int4/int1 sub-byte story continued two generations on
            "float64": 40e12,
            "float32": 80e12,
            "tf32": 1.1e15,
            "bfloat16": 2.25e15,
            "float16": 2.25e15,
            "float8_e4m3fn": 4.5e15,
            "int8": 4.5e15,
            "fp4": 9.0e15,
        },
        clock_hz=_B200_CLK,
        num_cores=148,  # SMs
        levels=(
            MemoryLevel("L1", 256 * 1024, 33 / _B200_CLK * 1e9, 0.0, 32),
            MemoryLevel("L2", 126 * 1024**2, 290 / _B200_CLK * 1e9, 14e12, 64, shared=True),
            MemoryLevel("global", 192 * 1024**3, 700 / _B200_CLK * 1e9, 8e12, 64, shared=True),
        ),
        main_memory_Bps=8e12,
        main_memory_bytes=192 * 1024**3,
        staging_bytes=228 * 1024 * 148,
        staging_Bps=40e12,
        ici_Bps_per_link=100e9,  # NVLink5: 18 links x 100 GB/s
        ici_links=18,
        power_limit_w=1000.0,
        max_temp_c=90.0,
        idle_power_w=90.0,
        vendor="nvidia",
        arch="blackwell",
        year=2024,
        source="datasheet + Blackwell dissection (arXiv:2507.10789)",
    ),
    aliases=("b200",),
)

# back-compat: the old core.hwmodel module-level dtype table for T4
TPU_LIKE_DTYPES_T4 = dict(T4_PAPER.peak_flops)
