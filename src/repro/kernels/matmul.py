"""MXU-tiled matmul kernel — the §4.4 arithmetic-throughput probe and the
block-shape autotuning target.

Grid (M/bm, N/bn, K/bk), K innermost, fp32 accumulation in a VMEM scratch
(the MXU-native pattern).  Block dims should be multiples of 128 to align
with the 128x128 systolic array (cf. the paper's finding that >=128
threads/block are required to fill a Turing SM — the TPU analogue is
128-aligned MXU tiles).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

#: |out| within this factor of finfo.max counts as saturated for float dtypes
_SATURATION_MARGIN = 0.99


def saturation_check(args, out):
    """Guard sentinel: fraction of the matmul output lost to overflow or
    saturation, plus a human-readable detail (see ``repro.kernels.guard``).

    Integer outputs need the bound computed from the *inputs*: a low-precision
    accumulate that overflows int8/int16 range wraps silently on cast, so
    inspecting ``out`` alone has false negatives.  ``|a| @ |b|`` in int64 is a
    triangle-inequality upper bound — every entry it clears is provably safe,
    every entry past the dtype max is counted saturated (conservative, zero
    false negatives).  Float outputs saturate visibly: count non-finite
    entries plus magnitudes within ``_SATURATION_MARGIN`` of ``finfo.max``
    for the narrow dtypes (fp16/bf16); fp32+ counts non-finite only.
    """
    o = np.asarray(out)
    if o.size == 0:
        return 0.0, "empty output"
    if np.issubdtype(o.dtype, np.integer):
        a = np.abs(np.asarray(args[0]).astype(np.int64))
        b = np.abs(np.asarray(args[1]).astype(np.int64))
        bound = a @ b
        limit = np.iinfo(o.dtype).max
        frac = float(np.mean(bound > limit))
        return frac, (
            f"|a|@|b| accumulation bound exceeds {o.dtype} max ({limit}) on "
            f"{frac:.1%} of entries"
        )
    of = o.astype(np.float64)
    bad = ~np.isfinite(of)
    detail = "non-finite entries"
    if o.dtype in (np.dtype(np.float16), np.dtype(jnp.bfloat16)):
        limit = _SATURATION_MARGIN * float(jnp.finfo(o.dtype).max)
        bad |= np.abs(of) >= limit
        detail = f"non-finite or |out| >= {_SATURATION_MARGIN:g}*finfo.max"
    return float(np.mean(bad)), detail


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref):
    k = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul_pallas(
    a: jax.Array,
    b: jax.Array,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    out_dtype=None,
    interpret: bool = True,
) -> jax.Array:
    """a (M,K) @ b (K,N); dims must divide by the block sizes."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, ((m, k, n), (bm, bk, bn))
    out_dtype = out_dtype or a.dtype
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _matmul_kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)
