"""Unified kernel dispatch API: op registry, backends, and kernel policy.

The paper's core method is running the *same* operation through different
hardware paths and comparing them quantitatively.  This module gives the
reproduction that axis as a first-class API:

- Every kernel is a registered :class:`KernelOp` with named **backends**:

  * ``"pallas"``    the Pallas kernel on its native path (compiled on TPU;
                    automatically interpret-mode off-TPU, where no Mosaic
                    compiler exists),
  * ``"interpret"`` the same Pallas kernel forced through interpret mode on
                    every platform (the cross-checking path),
  * ``"xla"``       the pure-jnp oracle from :mod:`repro.kernels.ref`, bound
                    to the *same natural argument layout* — the "library
                    implementation" the paper benchmarks against.

- A context-local :func:`kernel_policy` replaces the scattered ``interpret=``
  booleans and hand-fixed block sizes::

      with kernel_policy(backend="pallas", autotune=True):
          y = api.matmul(a, b)          # tiles from core.autotune, cached

  Policies nest; unspecified fields inherit from the enclosing policy and the
  previous policy is restored on exit.

- With ``autotune=True``, tile kwargs not pinned by the caller or the policy
  are chosen by :mod:`repro.core.autotune` (``choose_matmul_tiles``,
  ``choose_attention_chunk``, ``choose_ssm_chunk``) and memoized in the
  persisted :class:`repro.core.tuning.TuningCache` keyed on
  ``(op, shapes, dtype, backend)``.

- With ``guard="sample"`` or ``guard="shadow"``, eager calls are verified by
  :mod:`repro.kernels.guard`: a seed-deterministic sample (or every call)
  re-executes on the ``xla`` oracle and compares under the per-dtype
  tolerance ladder; drifting or faulting ops are quarantined to the oracle
  per-op with breaker-style cooldown.  ``op.bound()`` stays guard-free by
  design — timing loops measure the native path only.
"""
from __future__ import annotations

import inspect
from contextlib import contextmanager
from contextvars import ContextVar
from dataclasses import dataclass, field
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import tuning
from repro.core.autotune import (
    choose_attention_chunk,
    choose_matmul_tiles,
    choose_ssm_chunk,
    dtype_name,
)

import numpy as np

from . import axpy as _axpy
from . import flash_attention as _fa
from . import guard as _guard
from . import matmul as _mm
from . import membw as _bw
from . import pchase as _pc
from . import ref
from ._util import (
    default_interpret,
    fit_block,
    flatten_heads,
    flatten_ssm,
    pad_to_multiple,
    unflatten_heads,
)

BACKENDS = ("pallas", "interpret", "xla")
_PALLAS_LIKE = ("pallas", "interpret")  # backends that run the Pallas impl


def default_backend() -> str:
    """The backend used when neither the call nor the policy names one."""
    return "pallas"


# ---------------------------------------------------------------------------
# policy
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class KernelPolicy:
    """Context-local kernel dispatch settings.

    ``backend`` of None defers to :func:`default_backend`; ``tiles`` maps op
    name -> tile-kwarg overrides (e.g. ``{"matmul": {"bm": 256}}``) and is
    merged across nested policies.  ``guard`` of None inherits (defaulting to
    ``"off"`` at the root); ``"sample"``/``"shadow"`` enable runtime
    verification via :mod:`repro.kernels.guard`.
    """

    backend: Optional[str] = None
    autotune: bool = False
    tiles: dict = field(default_factory=dict)
    guard: Optional[str] = None


_POLICY: ContextVar[KernelPolicy] = ContextVar("kernel_policy", default=KernelPolicy())


def current_policy() -> KernelPolicy:
    return _POLICY.get()


@contextmanager
def kernel_policy(backend: Optional[str] = None, autotune: Optional[bool] = None,
                  tiles: Optional[dict] = None, guard: Optional[str] = None):
    """Scoped policy override; unspecified fields inherit from the enclosing
    policy, and the previous policy is restored on exit (exception-safe)."""
    if backend is not None and backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; expected one of {BACKENDS}")
    if guard is not None and guard not in _guard.GUARD_MODES:
        raise ValueError(
            f"unknown guard mode {guard!r}; expected one of {_guard.GUARD_MODES}"
        )
    outer = _POLICY.get()
    merged_tiles = dict(outer.tiles)
    for op_name, ov in (tiles or {}).items():
        op = _OPS.get(op_name)
        if op is None:
            raise ValueError(
                f"tiles override for unknown op {op_name!r}; registered: {op_names()}"
            )
        bad = sorted(set(ov) - set(op.tile_args))
        if bad:
            raise ValueError(
                f"op {op_name!r} has no tile kwarg(s) {bad}; tile args: {list(op.tile_args)}"
            )
        merged_tiles[op_name] = {**merged_tiles.get(op_name, {}), **ov}
    pol = KernelPolicy(
        backend=outer.backend if backend is None else backend,
        autotune=outer.autotune if autotune is None else autotune,
        tiles=merged_tiles,
        guard=outer.guard if guard is None else guard,
    )
    token = _POLICY.set(pol)
    try:
        yield pol
    finally:
        _POLICY.reset(token)


def resolve_backend(requested: Optional[str] = None) -> str:
    """The backend a call would dispatch to under the current policy."""
    return requested or current_policy().backend or default_backend()


# ---------------------------------------------------------------------------
# op registry
# ---------------------------------------------------------------------------
class KernelOp:
    """One registered operation with per-backend implementations.

    Calling the op dispatches through the current :class:`KernelPolicy`;
    ``backend=`` overrides the policy for a single call.  Tile kwargs are
    resolved as: explicit kwarg > policy.tiles[op] > autotune (when the
    policy enables it) > the implementation's defaults.
    """

    def __init__(self, name: str, backends: tuple, tile_args: tuple = (),
                 autotuner: Optional[Callable] = None, doc: str = ""):
        self.name = name
        self.backends = tuple(backends)
        self.tile_args = tuple(tile_args)
        self.autotuner = autotuner  # (args tuple) -> {tile kwarg: value}
        self.__doc__ = doc
        self._impls: dict = {}
        self._accepts: dict = {}  # backend -> frozenset of kwarg names
        self._all_accepts: frozenset = frozenset()  # union across backends

    def bind(self, backend: str, fn: Callable) -> None:
        if backend not in self.backends:
            raise ValueError(f"op {self.name!r} does not declare backend {backend!r}")
        self._impls[backend] = fn
        sig = inspect.signature(fn)
        self._accepts[backend] = frozenset(
            p.name for p in sig.parameters.values()
            if p.kind in (p.KEYWORD_ONLY, p.POSITIONAL_OR_KEYWORD)
        )
        self._all_accepts = self._all_accepts | self._accepts[backend]

    def defbackend(self, backend: str):
        """Decorator registering ``fn`` as this op's ``backend`` impl."""

        def deco(fn: Callable) -> Callable:
            self.bind(backend, fn)
            return fn

        return deco

    def impl(self, backend: str) -> Callable:
        try:
            return self._impls[backend]
        except KeyError:
            bound = sorted(self._impls)
            raise KeyError(
                f"op {self.name!r} has no backend {backend!r} (bound: {bound})"
            ) from None

    # -- dispatch -----------------------------------------------------------
    def _resolve_tiles(self, pol: KernelPolicy, backend: str, args, kwargs) -> dict:
        out = dict(kwargs)
        for k, v in pol.tiles.get(self.name, {}).items():
            if k in self.tile_args:
                out.setdefault(k, v)
        if pol.autotune and self.autotuner is not None:
            if any(t not in out for t in self.tile_args):
                cache = tuning.get_cache()
                key = tuning.make_key(self.name, args, backend)
                tuned = cache.lookup(key)
                if tuned is None:
                    tuned = self.autotuner(args)
                    cache.store(key, tuned)
                for t, v in tuned.items():
                    out.setdefault(t, v)
        return out

    def bound(self, *args, backend: Optional[str] = None, **kwargs) -> Callable:
        """Resolve dispatch (backend, tiles, kwarg filtering) for these
        ``args`` once and return the impl partially applied with the final
        kwargs — timing loops call the result directly, keeping Python
        dispatch out of the measured path."""
        pol = current_policy()
        be = backend or pol.backend or default_backend()
        if be not in BACKENDS:
            raise ValueError(f"unknown backend {be!r}; expected one of {BACKENDS}")
        impl = self.impl(be)
        # a kwarg no backend understands is a caller bug, not a backend
        # difference — raise instead of silently running with defaults
        unknown = sorted(set(kwargs) - self._all_accepts)
        if unknown:
            raise TypeError(
                f"op {self.name!r} got unexpected keyword argument(s) {unknown}; "
                f"accepted across backends: {sorted(self._all_accepts)}"
            )
        if be in _PALLAS_LIKE:
            kwargs = self._resolve_tiles(pol, be, args, kwargs)
            kwargs.setdefault("interpret", True if be == "interpret" else default_interpret())
        accepts = self._accepts[be]
        kwargs = {k: v for k, v in kwargs.items() if k in accepts}
        return partial(impl, **kwargs)

    def __call__(self, *args, backend: Optional[str] = None, **kwargs):
        pol = current_policy()
        mode = pol.guard
        if mode is None or mode == "off":
            return self.bound(*args, backend=backend, **kwargs)(*args)
        be = backend or pol.backend or default_backend()
        if be not in _PALLAS_LIKE or "xla" not in self._impls or _guard.tracing(args):
            # nothing to shadow against (xla already *is* the oracle, or the
            # op has no oracle binding), or we are inside a jit trace where
            # concrete comparison is impossible — quarantine routing still
            # applies so traced closures re-read breaker state when re-jitted
            if (be in _PALLAS_LIKE and "xla" in self._impls
                    and _guard.is_quarantined(self.name)):
                _guard.state().metrics.degraded_calls += 1
                be = "xla"
            return self.bound(*args, backend=be, **kwargs)(*args)
        return _guard.state().guarded_call(self, args, kwargs, be, mode)

    def __repr__(self) -> str:
        return f"KernelOp({self.name!r}, backends={sorted(self._impls)})"


_OPS: dict[str, KernelOp] = {}


def kernel_op(name: str, *, backends: tuple = BACKENDS, tile_args: tuple = (),
              autotuner: Optional[Callable] = None):
    """Register the decorated function as op ``name``'s Pallas implementation
    (serving both the ``pallas`` and ``interpret`` backends — the latter is a
    forced ``interpret=True``) and return the :class:`KernelOp` dispatcher.
    Bind further backends with ``@<op>.defbackend("xla")``."""

    def deco(pallas_fn: Callable) -> KernelOp:
        if name in _OPS:
            raise ValueError(f"kernel op {name!r} already registered")
        op = KernelOp(name, backends, tile_args, autotuner,
                      doc=(pallas_fn.__doc__ or ""))
        for be in _PALLAS_LIKE:
            if be in backends:
                op.bind(be, pallas_fn)
        _OPS[name] = op
        return op

    return deco


def get_op(name: str) -> KernelOp:
    try:
        return _OPS[name]
    except KeyError:
        raise KeyError(
            f"unknown kernel op {name!r}; registered: {', '.join(op_names())}"
        ) from None


def op_names() -> list:
    return sorted(_OPS)


# ---------------------------------------------------------------------------
# autotuners (core.autotune glue)
# ---------------------------------------------------------------------------
def _matmul_autotuner(args) -> dict:
    a, b = args[0], args[1]
    (m, k), n = a.shape, b.shape[1]
    tc = choose_matmul_tiles(m, k, n, dtype_name(a.dtype))
    return {"bm": tc.bm, "bk": tc.bk, "bn": tc.bn}


def _attention_autotuner(args) -> dict:
    q, k = args[0], args[1]
    _, sq, h, hd = q.shape
    chunk = choose_attention_chunk(k.shape[1], hd, h, dtype_name(q.dtype))
    return {"bq": fit_block(128, sq), "bk": chunk}


def _ssm_autotuner(args) -> dict:
    u, b = args[0], args[2]
    return {
        "chunk": choose_ssm_chunk(u.shape[1], u.shape[-1], b.shape[-1],
                                  dtype_name(u.dtype))
    }


# ---------------------------------------------------------------------------
# ops — Pallas impls own padding/reshaping so callers pass natural layouts;
# the xla bindings accept the *same* layouts (backend interchangeability).
# ---------------------------------------------------------------------------
@kernel_op("axpy", tile_args=("block_rows", "block_cols"))
@partial(jax.jit, static_argnames=("block_rows", "block_cols", "interpret"))
def axpy(x, y, alpha, *, block_rows=8, block_cols=512, interpret=True):
    """alpha*x + y over (R, C) tiles — the Ch.1 access-width example."""
    return _axpy.axpy_pallas(
        x, y, alpha, block_rows=block_rows, block_cols=block_cols, interpret=interpret
    )


@axpy.defbackend("xla")
@jax.jit
def _axpy_xla(x, y, alpha):
    return ref.axpy_ref(x, y, alpha)


@kernel_op("stream_copy", tile_args=("block_rows", "block_cols"))
@partial(jax.jit, static_argnames=("block_rows", "block_cols", "interpret"))
def stream_copy(x, *, block_rows=8, block_cols=512, interpret=True):
    """HBM->VMEM->HBM round-trip bandwidth probe."""
    return _bw.stream_copy(x, block_rows=block_rows, block_cols=block_cols, interpret=interpret)


@stream_copy.defbackend("xla")
@jax.jit
def _stream_copy_xla(x):
    return ref.copy_ref(x)


@kernel_op("stream_reduce", tile_args=("block_rows", "block_cols"))
@partial(jax.jit, static_argnames=("block_rows", "block_cols", "interpret"))
def stream_reduce(x, *, block_rows=8, block_cols=512, interpret=True):
    """Read-bandwidth probe: (1,1) fp32 checksum of the streamed array."""
    return _bw.stream_reduce(x, block_rows=block_rows, block_cols=block_cols, interpret=interpret)


@stream_reduce.defbackend("xla")
@jax.jit
def _stream_reduce_xla(x):
    return ref.reduce_ref(x)


@kernel_op("strided_reduce", tile_args=("block_rows",))
@partial(jax.jit, static_argnames=("stride", "block_rows", "interpret"))
def strided_reduce(x, *, stride, block_rows=64, interpret=True):
    """Sparse-access reduce probing load granularity (paper Tab 3.1)."""
    return _bw.strided_reduce(x, stride=stride, block_rows=block_rows, interpret=interpret)


@strided_reduce.defbackend("xla")
@partial(jax.jit, static_argnames=("stride",))
def _strided_reduce_xla(x, *, stride):
    return ref.strided_reduce_ref(x, stride)


@kernel_op("pchase")
@partial(jax.jit, static_argnames=("steps", "interpret"))
def pchase(perm, steps, *, interpret=True):
    """Dependent-load pointer chase; returns the final index as (1,1) int32."""
    return _pc.pchase_pallas(perm, steps, interpret=interpret)


@pchase.defbackend("xla")
@partial(jax.jit, static_argnames=("steps",))
def _pchase_xla(perm, steps):
    def body(_, idx):
        return perm[idx]

    return jax.lax.fori_loop(0, steps, body, jnp.int32(0)).reshape(1, 1)


@kernel_op("matmul", tile_args=("bm", "bn", "bk"), autotuner=_matmul_autotuner)
@partial(jax.jit, static_argnames=("bm", "bn", "bk", "out_dtype", "interpret"))
def matmul(a, b, *, bm=128, bn=128, bk=128, out_dtype=None, interpret=True):
    """MXU-tiled matmul (the §4.4 GEMM-throughput probe target)."""
    m, k = a.shape
    _, n = b.shape
    bm, bk, bn = fit_block(bm, m), fit_block(bk, k), fit_block(bn, n)
    a = pad_to_multiple(pad_to_multiple(a, bm, 0), bk, 1)
    b = pad_to_multiple(pad_to_multiple(b, bk, 0), bn, 1)
    out = _mm.matmul_pallas(a, b, bm=bm, bn=bn, bk=bk, out_dtype=out_dtype, interpret=interpret)
    return out[:m, :n]


@matmul.defbackend("xla")
@partial(jax.jit, static_argnames=("out_dtype",))
def _matmul_xla(a, b, *, out_dtype=None):
    return ref.matmul_ref(a, b, out_dtype)


@kernel_op("flash_attention", tile_args=("bq", "bk"), autotuner=_attention_autotuner)
@partial(jax.jit, static_argnames=("causal", "q_offset", "bq", "bk", "interpret"))
def flash_attention(q, k, v, *, causal=True, q_offset=0, bq=128, bk=128, interpret=True):
    """Blockwise-softmax attention; q/k/v in model layout (B, S, H, hd)."""
    b, sq, h, hd = q.shape
    skv = k.shape[1]
    qf, kf, vf = flatten_heads(q), flatten_heads(k), flatten_heads(v)
    bq_, bk_ = fit_block(bq, sq), fit_block(bk, skv)
    qf = pad_to_multiple(qf, bq_, 1)
    kf = pad_to_multiple(kf, bk_, 1)
    vf = pad_to_multiple(vf, bk_, 1)
    out = _fa.flash_attention_pallas(
        qf, kf, vf, causal=causal, q_offset=q_offset,
        bq=bq_, bk=bk_, kv_len=skv, interpret=interpret,
    )
    return unflatten_heads(out[:, :sq], b)


@flash_attention.defbackend("xla")
@partial(jax.jit, static_argnames=("causal", "q_offset"))
def _flash_attention_xla(q, k, v, *, causal=True, q_offset=0):
    out = ref.flash_attention_ref(
        flatten_heads(q), flatten_heads(k), flatten_heads(v),
        causal=causal, q_offset=q_offset,
    )
    return unflatten_heads(out, q.shape[0])


@kernel_op("ssm_scan", tile_args=("chunk",), autotuner=_ssm_autotuner)
@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssm_scan(u, a_log, b, c, *, chunk=256, interpret=True):
    """Chunked SSD scan; u (B,S,H,P), a_log (B,S,H), b/c (B,S,N) head-shared."""
    from . import ssm_scan as _ssd

    bsz, s, _, _ = u.shape
    chunk = fit_block(chunk, s)
    u = pad_to_multiple(u, chunk, 1)
    a_log = pad_to_multiple(a_log, chunk, 1)
    b = pad_to_multiple(b, chunk, 1)
    c = pad_to_multiple(c, chunk, 1)
    y = _ssd.ssm_scan_pallas(*flatten_ssm(u, a_log, b, c), chunk=chunk, interpret=interpret)
    return unflatten_heads(y, bsz)[:, :s]


@ssm_scan.defbackend("xla")
@jax.jit
def _ssm_scan_xla(u, a_log, b, c):
    y = ref.ssm_scan_ref(*flatten_ssm(u, a_log, b, c))
    return unflatten_heads(y, u.shape[0])


# ---------------------------------------------------------------------------
# guard hooks: saturation sentinels + canonical probe inputs.  The sentinel
# fns live beside their kernels (matmul/flash_attention own the accumulation
# semantics); registration lives here so guard.py never imports kernels.
# ---------------------------------------------------------------------------
_guard.register_sentinel("matmul", _mm.saturation_check)
_guard.register_sentinel("flash_attention", _fa.saturation_check)


def _matmul_probe():
    rng = np.random.default_rng(0)
    a = rng.standard_normal((16, 16)).astype(np.float32)
    b = rng.standard_normal((16, 16)).astype(np.float32)
    return (a, b), {}


def _flash_attention_probe():
    rng = np.random.default_rng(0)
    shape = (1, 16, 2, 8)  # (B, S, H, hd)
    q = rng.standard_normal(shape).astype(np.float32)
    k = rng.standard_normal(shape).astype(np.float32)
    v = rng.standard_normal(shape).astype(np.float32)
    return (q, k, v), {}


def _axpy_probe():
    # (8, 512): divisible by axpy's default (block_rows, block_cols) tiles
    rng = np.random.default_rng(0)
    x = rng.standard_normal((8, 512)).astype(np.float32)
    y = rng.standard_normal((8, 512)).astype(np.float32)
    return (x, y, 1.5), {}


_guard.register_probe("matmul", _matmul_probe)
_guard.register_probe("flash_attention", _flash_attention_probe)
_guard.register_probe("axpy", _axpy_probe)


__all__ = [
    "BACKENDS",
    "KernelOp",
    "KernelPolicy",
    "axpy",
    "current_policy",
    "default_backend",
    "default_interpret",
    "flash_attention",
    "get_op",
    "kernel_op",
    "kernel_policy",
    "matmul",
    "op_names",
    "pchase",
    "resolve_backend",
    "ssm_scan",
    "stream_copy",
    "stream_reduce",
    "strided_reduce",
]
