"""axpy kernel — the paper's Chapter-1 example, TPU-native.

The paper showed cublasSaxpy's 64-bit global loads leave ~2x bandwidth on the
table vs. 128-bit vectorized loads.  The TPU restatement: an ``y += a*x``
kernel is bandwidth-bound, so the VMEM block shape (how many (8,128) native
tiles each grid step streams) controls achieved HBM bandwidth.  The benchmark
sweeps ``block_cols`` the way Fig 1.1 sweeps access width.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _axpy_kernel(alpha_ref, x_ref, y_ref, o_ref):
    o_ref[...] = x_ref[...] * alpha_ref[0, 0] + y_ref[...]


def axpy_pallas(
    x: jax.Array,
    y: jax.Array,
    alpha: jax.Array | float,
    *,
    block_rows: int = 8,
    block_cols: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """x, y: (R, C) with R % block_rows == 0 and C % block_cols == 0."""
    r, c = x.shape
    assert r % block_rows == 0 and c % block_cols == 0, (x.shape, block_rows, block_cols)
    grid = (r // block_rows, c // block_cols)
    alpha_arr = jnp.asarray(alpha, x.dtype).reshape(1, 1)
    return pl.pallas_call(
        _axpy_kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((block_rows, block_cols), lambda i, j: (i, j)),
            pl.BlockSpec((block_rows, block_cols), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((block_rows, block_cols), lambda i, j: (i, j)),
        interpret=interpret,
    )(alpha_arr, x, y)
