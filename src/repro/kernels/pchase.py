"""Pointer-chase kernel — the paper's core §3 methodology (Mei & Chu [9]).

A permutation array is walked with fully dependent loads: ``idx = perm[idx]``.
Wall-clock / steps = dependent-load latency at the hierarchy level holding the
array.  On TPU the interesting transition is VMEM-resident vs. HBM-streamed;
on the CPU host (measure mode) the same kernel traces out L1/L2/L3/DRAM —
which is how we validate the methodology end-to-end (core/dissect.py).

The index lives in SMEM-like scalar space (a (1,1) block) — the TPU analogue
of the paper's §3.5.2 "uniform datapath" observation: index math stays off
the vector path.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pchase_kernel(perm_ref, o_ref, *, steps: int):
    def body(_, idx):
        return perm_ref[idx, 0]

    idx = jax.lax.fori_loop(0, steps, body, jnp.int32(0))
    o_ref[0, 0] = idx


def pchase_pallas(perm: jax.Array, steps: int, *, interpret: bool = True) -> jax.Array:
    """perm: (N,) int32 permutation of range(N).  Returns final index (1,1)."""
    n = perm.shape[0]
    perm2 = perm.reshape(n, 1)
    return pl.pallas_call(
        partial(_pchase_kernel, steps=steps),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.int32),
        grid=(1,),
        in_specs=[pl.BlockSpec((n, 1), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        interpret=interpret,
    )(perm2)
