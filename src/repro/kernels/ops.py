"""DEPRECATED jit'd wrappers — thin shims over :mod:`repro.kernels.api`.

This module predates the unified dispatch API.  The old per-function
``interpret=`` boolean maps onto the backend axis:

    ops.axpy(x, y, a)                   -> api.axpy(x, y, a)     (policy backend)
    ops.axpy(x, y, a, interpret=True)   -> backend="interpret"
    ops.axpy(x, y, a, interpret=False)  -> backend="pallas"

New code should call the ops in :mod:`repro.kernels.api` directly (optionally
under a :func:`repro.kernels.api.kernel_policy`).  These shims stay importable
for one deprecation cycle and emit :class:`DeprecationWarning`.
"""
from __future__ import annotations

import warnings

from . import api


def _warn(name: str) -> None:
    warnings.warn(
        f"repro.kernels.ops.{name} is deprecated; use repro.kernels.api.{name} "
        f"(dispatch via kernel_policy instead of interpret=)",
        DeprecationWarning,
        stacklevel=3,
    )


def _dispatch(interpret) -> dict:
    """None preserves the old default (policy/auto); an explicit boolean pins
    the matching Pallas backend AND the interpret flag itself, so
    ``interpret=False`` still demands the compiled path (failing loudly
    off-TPU) exactly as the pre-dispatch wrappers did."""
    if interpret is None:
        return {"backend": None}
    return {"backend": "interpret" if interpret else "pallas", "interpret": interpret}


def axpy(x, y, alpha, *, block_rows=8, block_cols=512, interpret=None):
    _warn("axpy")
    return api.axpy(x, y, alpha, block_rows=block_rows, block_cols=block_cols,
                    **_dispatch(interpret))


def stream_copy(x, *, block_rows=8, block_cols=512, interpret=None):
    _warn("stream_copy")
    return api.stream_copy(x, block_rows=block_rows, block_cols=block_cols,
                           **_dispatch(interpret))


def stream_reduce(x, *, block_rows=8, block_cols=512, interpret=None):
    _warn("stream_reduce")
    return api.stream_reduce(x, block_rows=block_rows, block_cols=block_cols,
                             **_dispatch(interpret))


def strided_reduce(x, *, stride, block_rows=64, interpret=None):
    _warn("strided_reduce")
    return api.strided_reduce(x, stride=stride, block_rows=block_rows,
                              **_dispatch(interpret))


def pchase(perm, steps, *, interpret=None):
    _warn("pchase")
    return api.pchase(perm, steps, **_dispatch(interpret))


def matmul(a, b, *, bm=128, bn=128, bk=128, out_dtype=None, interpret=None):
    _warn("matmul")
    return api.matmul(a, b, bm=bm, bn=bn, bk=bk, out_dtype=out_dtype,
                      **_dispatch(interpret))


def flash_attention(q, k, v, *, causal=True, q_offset=0, bq=128, bk=128, interpret=None):
    """q/k/v in model layout (B, S, H, hd) with matching head counts."""
    _warn("flash_attention")
    return api.flash_attention(q, k, v, causal=causal, q_offset=q_offset, bq=bq, bk=bk,
                               **_dispatch(interpret))


def ssm_scan(u, a_log, b, c, *, chunk=256, interpret=None):
    """u (B,S,H,P); a_log (B,S,H); b/c (B,S,N) (head-shared) -> y (B,S,H,P)."""
    _warn("ssm_scan")
    return api.ssm_scan(u, a_log, b, c, chunk=chunk, **_dispatch(interpret))
