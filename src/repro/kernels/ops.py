"""Public jit'd wrappers around the Pallas kernels.

``interpret`` defaults to True off-TPU (CPU validation per the assignment)
and False on real TPU backends.  Wrappers own padding/reshaping so callers
pass natural model layouts.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import axpy as _axpy
from . import flash_attention as _fa
from . import matmul as _mm
from . import membw as _bw
from . import pchase as _pc


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
@partial(jax.jit, static_argnames=("block_rows", "block_cols", "interpret"))
def axpy(x, y, alpha, *, block_rows=8, block_cols=512, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _axpy.axpy_pallas(
        x, y, alpha, block_rows=block_rows, block_cols=block_cols, interpret=interpret
    )


@partial(jax.jit, static_argnames=("block_rows", "block_cols", "interpret"))
def stream_copy(x, *, block_rows=8, block_cols=512, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _bw.stream_copy(x, block_rows=block_rows, block_cols=block_cols, interpret=interpret)


@partial(jax.jit, static_argnames=("block_rows", "block_cols", "interpret"))
def stream_reduce(x, *, block_rows=8, block_cols=512, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _bw.stream_reduce(x, block_rows=block_rows, block_cols=block_cols, interpret=interpret)


@partial(jax.jit, static_argnames=("stride", "block_rows", "interpret"))
def strided_reduce(x, *, stride, block_rows=64, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _bw.strided_reduce(x, stride=stride, block_rows=block_rows, interpret=interpret)


@partial(jax.jit, static_argnames=("steps", "interpret"))
def pchase(perm, steps, *, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _pc.pchase_pallas(perm, steps, interpret=interpret)


@partial(jax.jit, static_argnames=("bm", "bn", "bk", "out_dtype", "interpret"))
def matmul(a, b, *, bm=128, bn=128, bk=128, out_dtype=None, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    m, k = a.shape
    k2, n = b.shape
    bm, bk, bn = min(bm, m), min(bk, k), min(bn, n)
    pm, pk, pn = (-m) % bm, (-k) % bk, (-n) % bn
    if pm or pk:
        a = jnp.pad(a, ((0, pm), (0, pk)))
    if pk or pn:
        b = jnp.pad(b, ((0, pk), (0, pn)))
    out = _mm.matmul_pallas(a, b, bm=bm, bn=bn, bk=bk, out_dtype=out_dtype, interpret=interpret)
    return out[:m, :n]


@partial(jax.jit, static_argnames=("causal", "q_offset", "bq", "bk", "interpret"))
def flash_attention(q, k, v, *, causal=True, q_offset=0, bq=128, bk=128, interpret=None):
    """q/k/v in model layout (B, S, H, hd) with matching head counts."""
    interpret = _default_interpret() if interpret is None else interpret
    b, sq, h, hd = q.shape
    skv = k.shape[1]

    def flat(x):  # (B,S,H,hd) -> (B*H, S, hd)
        return x.transpose(0, 2, 1, 3).reshape(b * h, x.shape[1], hd)

    qf, kf, vf = flat(q), flat(k), flat(v)
    bq_, bk_ = min(bq, sq), min(bk, skv)
    pq, pk_ = (-sq) % bq_, (-skv) % bk_
    if pq:
        qf = jnp.pad(qf, ((0, 0), (0, pq), (0, 0)))
    if pk_:
        kf = jnp.pad(kf, ((0, 0), (0, pk_), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pk_), (0, 0)))
    out = _fa.flash_attention_pallas(
        qf, kf, vf, causal=causal, q_offset=q_offset,
        bq=bq_, bk=bk_, kv_len=skv, interpret=interpret,
    )
    out = out[:, :sq]
    return out.reshape(b, h, sq, hd).transpose(0, 2, 1, 3)


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssm_scan(u, a_log, b, c, *, chunk=256, interpret=None):
    """u (B,S,H,P); a_log (B,S,H); b/c (B,S,N) (head-shared) -> y (B,S,H,P)."""
    interpret = _default_interpret() if interpret is None else interpret
    from . import ssm_scan as _ssd

    bsz, s, h, p = u.shape
    n = b.shape[-1]
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        u = jnp.pad(u, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a_log = jnp.pad(a_log, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    sp = s + pad
    uf = u.transpose(0, 2, 1, 3).reshape(bsz * h, sp, p)
    af = a_log.transpose(0, 2, 1).reshape(bsz * h, sp)
    bf = jnp.repeat(b[:, None], h, axis=1).reshape(bsz * h, sp, n)
    cf = jnp.repeat(c[:, None], h, axis=1).reshape(bsz * h, sp, n)
    y = _ssd.ssm_scan_pallas(uf, af, bf, cf, chunk=chunk, interpret=interpret)
    return y.reshape(bsz, h, sp, p).transpose(0, 2, 1, 3)[:, :s]
