"""Pure-jnp oracles for every Pallas kernel (ground truth for allclose tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def axpy_ref(x: jax.Array, y: jax.Array, alpha) -> jax.Array:
    return jnp.asarray(alpha, x.dtype) * x + y


def copy_ref(x: jax.Array) -> jax.Array:
    return x


def reduce_ref(x: jax.Array) -> jax.Array:
    return jnp.sum(x.astype(jnp.float32)).reshape(1, 1)


def strided_reduce_ref(x: jax.Array, stride: int) -> jax.Array:
    return jnp.sum(x[::stride, :].astype(jnp.float32)).reshape(1, 1)


def pchase_ref(perm: np.ndarray, steps: int) -> int:
    idx = 0
    arr = np.asarray(perm)
    for _ in range(steps):
        idx = int(arr[idx])
    return idx


def matmul_ref(a: jax.Array, b: jax.Array, out_dtype=None) -> jax.Array:
    out_dtype = out_dtype or a.dtype
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(out_dtype)


def flash_attention_ref(
    q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True, q_offset: int = 0
) -> jax.Array:
    """q (BH, Sq, hd); k/v (BH, Skv, hd)."""
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bqh,bkh->bqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    if causal:
        sq, skv = s.shape[-2], s.shape[-1]
        qi = q_offset + jnp.arange(sq)[:, None]
        ki = jnp.arange(skv)[None, :]
        s = jnp.where(ki <= qi, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkh->bqh", p, v.astype(jnp.float32)).astype(q.dtype)


def ssm_scan_ref(u, a_log, b, c):
    """Sequential SSD recurrence.  u (BH,S,P); a_log (BH,S); b/c (BH,S,N)."""
    bh, s, p = u.shape
    n = b.shape[-1]

    def per_seq(u1, a1, b1, c1):
        def step(h, inp):
            u_t, a_t, b_t, c_t = inp
            h = h * jnp.exp(a_t) + jnp.outer(u_t, b_t)
            y = h @ c_t
            return h, y

        h0 = jnp.zeros((p, n), jnp.float32)
        _, ys = jax.lax.scan(
            step,
            h0,
            (u1.astype(jnp.float32), a1.astype(jnp.float32),
             b1.astype(jnp.float32), c1.astype(jnp.float32)),
        )
        return ys

    return jax.vmap(per_seq)(u, a_log, b, c).astype(u.dtype)
