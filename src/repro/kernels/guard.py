"""Kernel-layer numerics guard: shadow-oracle checks, saturation sentinels,
per-op degradation.

PR 9's robustness layer sits entirely *above* kernel dispatch: a pallas
kernel that returns plausible-but-wrong values, or an int8/fp16 accumulation
that saturates (the low-precision regime the paper's Table 4.3 ladder
exists to exploit), is invisible until it corrupts tokens.  This module
makes the kernel layer verify itself at runtime, scoped by the context-local
policy (``kernel_policy(guard="off" | "sample" | "shadow")``):

- **shadow-oracle checking** — a seed-deterministic sample of eager
  :class:`~repro.kernels.api.KernelOp` calls (every call under ``"shadow"``,
  every ``sample_stride``-th under ``"sample"``) re-executes on the ``xla``
  oracle backend and compares under the per-dtype tolerance ladder of
  :func:`tolerance`.  A mismatch raises a typed :class:`KernelDriftError`
  carrying op, backend, shapes, and a max-ulp report.
- **overflow/saturation sentinels** — per-op hooks (registered for
  ``matmul`` / ``flash_attention`` by ``kernels.api``) bound the saturated
  fraction of low-precision accumulation outputs; past
  ``GuardConfig.saturation_threshold`` they raise :class:`SaturationError`.
  Saturation is an *input-regime* property — the xla oracle saturates
  identically — so the sentinel raises without quarantining the op.
- **per-op degradation** — a drifting or faulting op is quarantined to the
  ``xla`` backend *for that op only*, with breaker-style exponential
  cooldown and half-open re-probe (mirroring the replica breaker in
  ``serve/cluster.py``), replacing the whole-engine one-shot fallback as
  the first line of defense.  Quarantine routing also applies at jit trace
  time (tracers cannot be concretely compared, so shadow checks skip under
  tracing — the serving engine runs its own compiled-output shadow twins,
  see ``serve/engine.py``).

Guard activity accumulates in :class:`GuardMetrics` (checks run, drift
events, saturation fraction, ops degraded/revived) and emits schema-v1
records so chaos and serving suites can assert on it.  See
docs/robustness.md#numerics-guard.
"""
from __future__ import annotations

import warnings
from contextlib import contextmanager
from dataclasses import dataclass, field, replace
from typing import Callable, Optional

import jax
import numpy as np

from repro import hw as hwdb
from repro.core.autotune import dtype_name

GUARD_MODES = ("off", "sample", "shadow")

# breaker states (mirrors serve/cluster.py's replica breaker)
BREAKER_CLOSED = "closed"
BREAKER_OPEN = "open"
BREAKER_HALF_OPEN = "half_open"


# ---------------------------------------------------------------------------
# tolerance ladder (repro.hw precision resolution -> ulp budgets)
# ---------------------------------------------------------------------------
#: mantissa bits per compute precision (ulp = 2**-mantissa relative)
_MANTISSA = {
    "float64": 52,
    "float32": 23,
    "tf32": 10,
    "float16": 10,
    "bfloat16": 7,
    "float8_e4m3fn": 3,
    "float8_e5m2": 2,
}

#: default ulp budget per resolved precision.  High precisions get a wide
#: budget (accumulation-order differences dominate, each ulp is tiny); low
#: precisions get a narrow one (a single ulp is already coarse — bf16's is
#: ~0.8% relative — and a wide budget would mask real drift).
_ULP_BUDGET = {
    "float64": 1024.0,
    "float32": 256.0,
    "tf32": 64.0,
    "float16": 32.0,
    "bfloat16": 4.0,
    "float8_e4m3fn": 2.0,
    "float8_e5m2": 2.0,
}

#: float-only restriction of ``core.autotune._PEAK_FALLBACK``: the chain a
#: requested dtype walks to find the precision the part actually computes in
#: (a float dtype must never resolve to an integer peak — the int entries in
#: the autotuner's chains cost *throughput*, not rounding behaviour).
_GUARD_FALLBACK = {
    "float64": ("float32",),
    "bfloat16": ("float16", "float32"),
    "float16": ("bfloat16", "float32"),
    "tf32": ("float32",),
    "float8_e4m3fn": ("bfloat16", "float16", "float32"),
    "float8_e5m2": ("bfloat16", "float16", "float32"),
}


@dataclass(frozen=True)
class Tolerance:
    """Per-dtype comparison tolerance derived from the hw precision ladder.

    ``resolved`` is the precision the comparison is costed in: the requested
    dtype when the part publishes a peak for it, else the first float in its
    fallback chain the part does publish (a part with no relevant published
    precision keeps the requested dtype).  ``exact`` marks integer/bool
    dtypes, which must match bit-for-bit.
    """

    dtype: str
    resolved: str
    ulps: float
    rtol: float
    atol: float
    exact: bool = False
    hw: str = "T4"


def _is_exact(name: str) -> bool:
    return name.startswith(("int", "uint")) or name == "bool"


def tolerance(dtype, hw: str = "T4", ulps: Optional[float] = None) -> Tolerance:
    """Tolerance for comparing a kernel result of ``dtype`` against the
    oracle, on part ``hw`` (a ``repro.hw`` DB name or model).

    The dtype resolves through the part's published peaks via the float
    fallback chains (Table 4.3 ladder semantics: T4 publishes fp16 but not
    bf16, so a bf16 result is compared at fp16 precision); the resolved
    precision's ulp (``2**-mantissa``) times the per-precision budget gives
    ``rtol``, with an equal absolute floor for near-zero entries.
    """
    # np.dtype() normalizes strings, np.dtype instances, and raw scalar
    # types (np.int8, jnp.bfloat16) alike before the name lookup
    name = dtype_name(np.dtype(dtype))
    if _is_exact(name):
        return Tolerance(dtype=name, resolved=name, ulps=0.0, rtol=0.0,
                         atol=0.0, exact=True, hw=str(hw))
    part = hwdb.resolve(hw)
    resolved = name
    if not part.supports(name):
        for fb in _GUARD_FALLBACK.get(name, ()):
            if part.supports(fb):
                resolved = fb
                break
    if resolved not in _MANTISSA:
        resolved = "float32"
    eps = 2.0 ** -_MANTISSA[resolved]
    budget = float(ulps) if ulps is not None else _ULP_BUDGET[resolved]
    return Tolerance(dtype=name, resolved=resolved, ulps=budget,
                     rtol=budget * eps, atol=budget * eps, hw=part.name)


# ---------------------------------------------------------------------------
# drift comparison
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class DriftReport:
    """One shadow-oracle comparison: max abs/rel/ulp distances vs the
    tolerance that judged them (``max_ulp`` is in ulps of
    ``tol.resolved``)."""

    op: str
    backend: str
    shapes: tuple
    dtype: str
    ok: bool
    max_abs: float
    max_rel: float
    max_ulp: float
    checked: int
    tol: Tolerance
    error: str = ""  # set when the native path raised instead of drifting

    def describe(self) -> str:
        if self.error:
            return (f"op {self.op!r} [{self.backend}] shapes={self.shapes} "
                    f"raised: {self.error}")
        return (
            f"op {self.op!r} [{self.backend}] shapes={self.shapes} "
            f"dtype={self.dtype}: max_abs={self.max_abs:.3e} "
            f"max_rel={self.max_rel:.3e} max_ulp={self.max_ulp:.1f} over "
            f"{self.checked} elements (tolerance: {self.tol.ulps:g} ulp of "
            f"{self.tol.resolved} on {self.tol.hw}"
            + (", exact)" if self.tol.exact else ")")
        )


def compare(got, want, tol: Tolerance, *, op: str = "?",
            backend: str = "?") -> DriftReport:
    """Judge a native result against the oracle under ``tol``.

    Integer dtypes must match exactly; floats must agree on finiteness
    everywhere and sit within ``atol + rtol*|want|`` elementwise.
    """
    g = np.asarray(got)
    w = np.asarray(want)
    shapes = (tuple(g.shape),)
    if tol.exact:
        same = bool(np.array_equal(g, w))
        max_abs = float(np.max(np.abs(g.astype(np.int64) - w.astype(np.int64)))) \
            if g.size and not same else 0.0
        return DriftReport(op=op, backend=backend, shapes=shapes,
                           dtype=tol.dtype, ok=same, max_abs=max_abs,
                           max_rel=max_abs, max_ulp=max_abs,
                           checked=int(g.size), tol=tol)
    g64 = g.astype(np.float64)
    w64 = w.astype(np.float64)
    fin_g, fin_w = np.isfinite(g64), np.isfinite(w64)
    finite_ok = bool(np.array_equal(fin_g, fin_w))
    both = fin_g & fin_w
    diff = np.abs(g64[both] - w64[both])
    ref = np.abs(w64[both])
    max_abs = float(diff.max()) if diff.size else 0.0
    max_rel = float((diff / np.maximum(ref, 1e-300)).max()) if diff.size else 0.0
    eps = 2.0 ** -_MANTISSA[tol.resolved]
    ulp = diff / (eps * np.maximum(ref, 1.0))
    max_ulp = float(ulp.max()) if ulp.size else 0.0
    within = bool(np.all(diff <= tol.atol + tol.rtol * ref)) if diff.size else True
    ok = finite_ok and within
    if not finite_ok:
        max_ulp = float("inf")
    return DriftReport(op=op, backend=backend, shapes=shapes, dtype=tol.dtype,
                       ok=ok, max_abs=max_abs, max_rel=max_rel,
                       max_ulp=max_ulp, checked=int(g.size), tol=tol)


def trees_match(got, want, hw: str = "T4") -> tuple:
    """Compare two pytrees (e.g. compiled serving-step outputs) leaf by leaf
    under the per-dtype tolerance ladder; returns ``(ok, detail)`` where
    ``detail`` describes the worst-drifting leaf ('' when ok)."""
    g_leaves = jax.tree_util.tree_leaves(got)
    w_leaves = jax.tree_util.tree_leaves(want)
    if len(g_leaves) != len(w_leaves):
        return False, (f"tree structure differs: {len(g_leaves)} vs "
                       f"{len(w_leaves)} leaves")
    worst = None
    for i, (g, w) in enumerate(zip(g_leaves, w_leaves)):
        tol = tolerance(np.asarray(g).dtype, hw=hw)
        rep = compare(g, w, tol, op=f"leaf[{i}]")
        if not rep.ok and (worst is None or rep.max_ulp > worst.max_ulp):
            worst = rep
    if worst is None:
        return True, ""
    return False, worst.describe()


# ---------------------------------------------------------------------------
# typed errors
# ---------------------------------------------------------------------------
class KernelGuardError(RuntimeError):
    """Base class for guard-raised failures."""


class KernelDriftError(KernelGuardError):
    """A sampled kernel call disagreed with the xla oracle past tolerance.

    ``report`` is the full :class:`DriftReport` (op, backend, shapes, dtype,
    max abs/rel/ulp distances, and the :class:`Tolerance` that judged them).
    """

    def __init__(self, report: DriftReport):
        self.report = report
        self.op = report.op
        self.backend = report.backend
        self.shapes = report.shapes
        super().__init__("kernel drift: " + report.describe())


class SaturationError(KernelGuardError):
    """A low-precision accumulation saturated past the guard threshold.

    ``fraction`` is the saturated share of output entries, ``detail`` the
    sentinel's description of the bound that tripped.
    """

    def __init__(self, op: str, dtype: str, fraction: float, detail: str,
                 threshold: float):
        self.op = op
        self.dtype = dtype
        self.fraction = fraction
        super().__init__(
            f"op {op!r} saturated {fraction:.1%} of its {dtype} output "
            f"(threshold {threshold:.1%}): {detail}"
        )


# ---------------------------------------------------------------------------
# config / metrics / breaker state
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class GuardConfig:
    """Process-level guard settings (the *mode* lives on the kernel policy).

    - ``sample_stride`` / ``seed`` — under ``guard="sample"``, the n-th call
      of an op is shadow-checked when ``(n + seed) % sample_stride == 0``
      (seed-deterministic: the same call sequence checks the same calls).
    - ``hw`` — spec-DB part whose precision ladder derives the tolerances.
    - ``saturation_threshold`` — saturated output fraction past which the
      sentinel raises :class:`SaturationError`.
    - ``sentinels`` — enable the per-op saturation hooks.
    - ``degrade`` — quarantine a faulting op and serve it from the oracle
      (False: re-raise the native failure).
    - ``on_drift`` — ``"raise"`` (typed :class:`KernelDriftError`) or
      ``"oracle"`` (warn, quarantine, and return the oracle result).
    - ``cooldown`` / ``max_cooldown_doublings`` / ``probe_checks`` — breaker
      shape: an open op waits ``cooldown * 2**min(fails-1, doublings)``
      guard-clock ticks, then half-opens; ``probe_checks`` consecutive clean
      live checks close it again.
    """

    sample_stride: int = 8
    seed: int = 0
    hw: str = "T4"
    saturation_threshold: float = 1.0 / 64.0
    sentinels: bool = True
    degrade: bool = True
    on_drift: str = "raise"
    cooldown: int = 16
    max_cooldown_doublings: int = 4
    probe_checks: int = 2

    def __post_init__(self):
        if self.sample_stride < 1:
            raise ValueError("sample_stride must be >= 1")
        if not 0.0 <= self.saturation_threshold <= 1.0:
            raise ValueError("saturation_threshold must be in [0, 1]")
        if self.on_drift not in ("raise", "oracle"):
            raise ValueError('on_drift must be "raise" or "oracle"')
        if self.cooldown < 1:
            raise ValueError("cooldown must be >= 1")
        if self.max_cooldown_doublings < 0:
            raise ValueError("max_cooldown_doublings must be >= 0")
        if self.probe_checks < 1:
            raise ValueError("probe_checks must be >= 1")


@dataclass
class OpBreaker:
    """Per-op circuit breaker (closed -> open -> half_open -> closed)."""

    state: str = BREAKER_CLOSED
    fail_count: int = 0
    opened_at: int = 0  # guard-clock tick of the last trip
    probe_ok: int = 0
    reason: str = ""


class GuardMetrics:
    """Guard activity counters; ``to_records`` emits schema-v1 rows."""

    def __init__(self):
        self.checks = 0  # shadow-oracle comparisons run (incl. probes)
        self.drift_events = 0  # comparisons that failed tolerance
        self.sentinel_checks = 0  # saturation sentinel evaluations
        self.saturation_events = 0  # sentinel trips past threshold
        self.max_saturation_fraction = 0.0
        self.faults = 0  # native-path exceptions caught by the guard
        self.quarantines = 0  # breaker trips (op -> xla)
        self.half_opens = 0  # cooled-down ops re-probed
        self.revivals = 0  # half-open probes that closed the breaker
        self.degraded_calls = 0  # calls served by the oracle while open
        self.quarantined_ops: set = set()  # every op ever tripped

    def events(self) -> int:
        return self.drift_events + self.saturation_events + self.faults

    def summary(self) -> dict:
        return {
            "checks": self.checks,
            "drift_events": self.drift_events,
            "sentinel_checks": self.sentinel_checks,
            "saturation_events": self.saturation_events,
            "max_saturation_fraction": self.max_saturation_fraction,
            "faults": self.faults,
            "quarantines": self.quarantines,
            "half_opens": self.half_opens,
            "revivals": self.revivals,
            "degraded_calls": self.degraded_calls,
            "quarantined_ops": sorted(self.quarantined_ops),
        }

    def to_records(self, benchmark: str, prefix: str, x=None) -> list:
        """Schema-v1 rows: checks run, detection events, breaker activity."""
        from repro.bench.schema import BenchRecord

        s = self.summary()
        shared = {"checks": s["checks"], "sentinel_checks": s["sentinel_checks"]}
        return [
            BenchRecord(
                name=f"{prefix}_checks",
                benchmark=benchmark,
                x=x,
                value=float(s["checks"]),
                unit="count",
                better="info",
                metrics={**shared, "degraded_calls": s["degraded_calls"]},
                info="shadow-oracle comparisons run",
            ),
            BenchRecord(
                name=f"{prefix}_events",
                benchmark=benchmark,
                x=x,
                value=float(self.events()),
                unit="count",
                better="info",
                metrics={
                    **shared,
                    "drift_events": s["drift_events"],
                    "saturation_events": s["saturation_events"],
                    "max_saturation_fraction": s["max_saturation_fraction"],
                    "faults": s["faults"],
                },
                info="guard detections (drift + saturation + native faults)",
            ),
            BenchRecord(
                name=f"{prefix}_degraded_ops",
                benchmark=benchmark,
                x=x,
                value=float(len(s["quarantined_ops"])),
                unit="count",
                better="info",
                metrics={
                    **shared,
                    "quarantines": s["quarantines"],
                    "half_opens": s["half_opens"],
                    "revivals": s["revivals"],
                    "degraded_calls": s["degraded_calls"],
                },
                info="distinct ops ever quarantined to the xla backend",
            ),
        ]


# ---------------------------------------------------------------------------
# sentinel / probe registries (populated by kernels.api at import time)
# ---------------------------------------------------------------------------
_SENTINELS: dict = {}  # op name -> fn(args, out) -> (fraction, detail)
_PROBES: dict = {}  # op name -> fn() -> (args tuple, kwargs dict)


def register_sentinel(op_name: str, fn: Callable) -> None:
    """Register a saturation sentinel: ``fn(args, out)`` returns the
    saturated output fraction in [0, 1] plus a human-readable detail."""
    _SENTINELS[op_name] = fn


def register_probe(op_name: str, factory: Callable) -> None:
    """Register a canonical-input factory used by :func:`attribute` /
    :func:`probe` to re-test an op outside any live call: ``factory()``
    returns ``(args, kwargs)`` for a small deterministic invocation."""
    _PROBES[op_name] = factory


def probe_ops() -> list:
    # probes register when kernels.api imports; force it so a bare
    # `guard.verify_ops()` (e.g. the bench runner's --guard sweep) is never
    # vacuously empty
    from repro.kernels import api  # noqa: F401

    return sorted(_PROBES)


# ---------------------------------------------------------------------------
# guard state
# ---------------------------------------------------------------------------
class GuardState:
    """Process-global guard machinery: per-op sampling counters, breakers,
    fault/drift injections (the chaos surface), and :class:`GuardMetrics`.

    The *mode* is context-local (on the kernel policy); the state is global
    on purpose — a quarantine must hold across policy scopes, threads, and
    the engine's jit traces.
    """

    def __init__(self, config: Optional[GuardConfig] = None):
        self.config = config or GuardConfig()
        self.metrics = GuardMetrics()
        self.clock = 0  # advances once per guarded eager call
        self.breakers: dict = {}  # op name -> OpBreaker
        self._calls: dict = {}  # op name -> guarded-call count (sampling)
        self._probe_cache: dict = {}  # op name -> built (args, kwargs)
        # chaos injections (driven by serve.faults.FaultInjector)
        self._fault_injections: dict = {}  # op name -> message
        self._drift_injections: dict = {}  # op name -> {"scale", "rng"}

    # -- breaker ---------------------------------------------------------
    def _cooldown_ticks(self, br: OpBreaker) -> int:
        cfg = self.config
        return cfg.cooldown * 2 ** min(max(br.fail_count - 1, 0),
                                       cfg.max_cooldown_doublings)

    def trip(self, op_name: str, reason: str) -> None:
        br = self.breakers.setdefault(op_name, OpBreaker())
        br.state = BREAKER_OPEN
        br.opened_at = self.clock
        br.fail_count += 1
        br.probe_ok = 0
        br.reason = reason
        self.metrics.quarantines += 1
        self.metrics.quarantined_ops.add(op_name)

    def close(self, op_name: str) -> None:
        br = self.breakers.get(op_name)
        if br is not None and br.state != BREAKER_CLOSED:
            br.state = BREAKER_CLOSED
            br.probe_ok = 0
            br.reason = ""
            self.metrics.revivals += 1

    # -- native / oracle execution --------------------------------------
    def _run_native(self, op, args, kwargs, backend: str):
        """The op's native path with chaos injections applied: an injected
        fault raises before execution; injected drift perturbs the result
        with seeded noise (deterministic across identical call sequences)."""
        msg = self._fault_injections.get(op.name)
        if msg is not None:
            raise RuntimeError(msg)
        out = op.bound(*args, backend=backend, **kwargs)(*args)
        inj = self._drift_injections.get(op.name)
        if inj is not None:
            o = np.asarray(out)
            if np.issubdtype(o.dtype, np.floating):
                noise = inj["rng"].standard_normal(o.shape)
                scale = inj["scale"] * (float(np.mean(np.abs(o))) + 1.0)
                out = (o + (noise * scale).astype(o.dtype))
        return out

    def _oracle(self, op, args, kwargs):
        return op.bound(*args, backend="xla", **kwargs)(*args)

    # -- sentinels -------------------------------------------------------
    def _sentinel(self, op, args, out) -> None:
        cfg = self.config
        fn = _SENTINELS.get(op.name)
        if fn is None or not cfg.sentinels:
            return
        fraction, detail = fn(args, out)
        self.metrics.sentinel_checks += 1
        self.metrics.max_saturation_fraction = max(
            self.metrics.max_saturation_fraction, fraction
        )
        if fraction > cfg.saturation_threshold:
            self.metrics.saturation_events += 1
            raise SaturationError(op.name, dtype_name(np.asarray(out).dtype),
                                  fraction, detail, cfg.saturation_threshold)

    # -- the dispatch weave (called from KernelOp.__call__) --------------
    def guarded_call(self, op, args, kwargs, backend: str, mode: str):
        cfg, m = self.config, self.metrics
        name = op.name
        self.clock += 1
        br = self.breakers.get(name)
        if br is not None and br.state == BREAKER_OPEN:
            if self.clock - br.opened_at >= self._cooldown_ticks(br):
                br.state = BREAKER_HALF_OPEN
                br.probe_ok = 0
                m.half_opens += 1
            else:
                m.degraded_calls += 1
                return self._oracle(op, args, kwargs)
        half_open = br is not None and br.state == BREAKER_HALF_OPEN
        self._calls[name] = self._calls.get(name, 0) + 1
        check = (
            half_open
            or mode == "shadow"
            or (self._calls[name] + cfg.seed) % cfg.sample_stride == 0
        )
        try:
            out = self._run_native(op, args, kwargs, backend)
        except Exception as err:
            m.faults += 1
            self.trip(name, f"fault: {err!r}")
            if not cfg.degrade:
                raise
            warnings.warn(
                f"kernel op {name!r} quarantined to the xla backend after a "
                f"native-path failure: {err!r}",
                RuntimeWarning,
                stacklevel=4,
            )
            m.degraded_calls += 1
            return self._oracle(op, args, kwargs)
        self._sentinel(op, args, out)
        if not check:
            return out
        want = self._oracle(op, args, kwargs)
        tol = tolerance(np.asarray(out).dtype, hw=cfg.hw)
        report = compare(out, want, tol, op=name, backend=backend)
        m.checks += 1
        if report.ok:
            if half_open:
                br.probe_ok += 1
                if br.probe_ok >= cfg.probe_checks:
                    self.close(name)
            return out
        m.drift_events += 1
        self.trip(name, f"drift: max_ulp={report.max_ulp:.1f}")
        if cfg.on_drift == "oracle":
            warnings.warn(
                f"kernel op {name!r} quarantined to the xla backend after "
                f"drift ({report.describe()})",
                RuntimeWarning,
                stacklevel=4,
            )
            m.degraded_calls += 1
            return want
        raise KernelDriftError(report)

    # -- canonical probes ------------------------------------------------
    def _probe_inputs(self, op_name: str):
        if op_name not in self._probe_cache:
            self._probe_cache[op_name] = _PROBES[op_name]()
        return self._probe_cache[op_name]

    def probe_report(self, op_name: str) -> DriftReport:
        """One canonical native-vs-oracle check of ``op_name``, bypassing
        the breaker (this *is* the half-open probe).  Injections apply, so
        an injected fault/drift is attributable."""
        from repro.kernels import api  # lazy: api imports this module

        op = api.get_op(op_name)
        args, kwargs = self._probe_inputs(op_name)
        tol_dtype = np.asarray(args[0]).dtype
        try:
            out = self._run_native(op, args, kwargs, "pallas")
        except Exception as err:
            self.metrics.checks += 1
            self.metrics.faults += 1
            tol = tolerance(tol_dtype, hw=self.config.hw)
            return DriftReport(op=op_name, backend="pallas", shapes=(),
                               dtype=tol.dtype, ok=False, max_abs=float("inf"),
                               max_rel=float("inf"), max_ulp=float("inf"),
                               checked=0, tol=tol, error=repr(err))
        want = self._oracle(op, args, kwargs)
        tol = tolerance(np.asarray(out).dtype, hw=self.config.hw)
        report = compare(out, want, tol, op=op_name, backend="pallas")
        self.metrics.checks += 1
        if not report.ok:
            self.metrics.drift_events += 1
        return report


_STATE = GuardState()


def state() -> GuardState:
    return _STATE


def reset(config: Optional[GuardConfig] = None) -> GuardState:
    """Replace the global guard state (breakers, metrics, injections)."""
    global _STATE
    _STATE = GuardState(config)
    return _STATE


def configure(**overrides) -> GuardConfig:
    """Update the active :class:`GuardConfig` in place (state/metrics and
    breakers survive — use :func:`reset` for a clean slate)."""
    _STATE.config = replace(_STATE.config, **overrides)
    return _STATE.config


@contextmanager
def isolated(config: Optional[GuardConfig] = None):
    """Scoped fresh guard state: suites that *intentionally* inject faults
    (e.g. the guarded chaos leg) run inside this so their detections do not
    pollute an outer clean-run gate (``repro.bench run --guard``)."""
    global _STATE
    prev = _STATE
    _STATE = GuardState(config)
    try:
        yield _STATE
    finally:
        _STATE = prev


def metrics() -> GuardMetrics:
    return _STATE.metrics


def tracing(args) -> bool:
    """True when any leaf of ``args`` is a jax tracer — shadow comparison
    needs concrete values, so guarded checks skip inside jit traces (the
    quarantine *routing* still applies there)."""
    return any(isinstance(a, jax.core.Tracer)
               for a in jax.tree_util.tree_leaves(args))


def is_quarantined(op_name: str) -> bool:
    """True while the op's breaker is open (calls route to the oracle)."""
    br = _STATE.breakers.get(op_name)
    return br is not None and br.state == BREAKER_OPEN


def quarantined_ops() -> tuple:
    return tuple(sorted(n for n in _STATE.breakers if is_quarantined(n)))


def quarantine(op_name: str, reason: str = "external") -> None:
    """Trip an op's breaker without raising (the engine's attribution path)."""
    _STATE.trip(op_name, reason)


def revive(op_name: str) -> None:
    """Close an op's breaker (counts a revival if it was open)."""
    _STATE.close(op_name)


def probe(op_name: str) -> bool:
    """Half-open re-probe: canonical native-vs-oracle check of a quarantined
    op.  Ops without a registered probe revive optimistically once no chaos
    injection targets them (breaker-standard: let one through; a recurrence
    re-trips with doubled cooldown)."""
    if op_name not in probe_ops():
        return not has_injection(op_name)
    return _STATE.probe_report(op_name).ok


def verify_ops(ops: Optional[list] = None) -> dict:
    """Shadow-verify every probe-registered op once (``op -> DriftReport``).

    This is the clean-run gate behind ``repro.bench run --guard``: a
    non-empty set of failing reports on an uninjected run means the native
    kernels drifted from their oracles.
    """
    return {name: _STATE.probe_report(name) for name in (ops or probe_ops())}


def attribute(ops: Optional[list] = None) -> list:
    """Attribute a failure to specific kernel ops: probe each (non-open) op
    and quarantine + return the ones that fault or drift.  An empty list
    means no kernel op is implicated (the caller falls back to its own
    coarser degradation)."""
    bad = []
    for name in (ops or probe_ops()):
        if is_quarantined(name):
            continue
        report = _STATE.probe_report(name)
        if not report.ok:
            _STATE.trip(name, f"attributed: {report.describe()}")
            bad.append(name)
    return bad


# ---------------------------------------------------------------------------
# chaos injection surface (driven by repro.serve.faults)
# ---------------------------------------------------------------------------
def inject_fault(op_name: str, message: str = "injected pallas kernel fault") -> None:
    """Make the op's native path raise ``RuntimeError(message)``."""
    _STATE._fault_injections[op_name] = message


def clear_fault(op_name: str) -> None:
    _STATE._fault_injections.pop(op_name, None)


def inject_drift(op_name: str, *, scale: float = 0.05, seed: int = 0) -> None:
    """Perturb the op's native output with seeded additive noise of relative
    magnitude ``scale`` (deterministic: the rng sequence replays under the
    same call order)."""
    _STATE._drift_injections[op_name] = {
        "scale": float(scale),
        "rng": np.random.default_rng(seed),
    }


def clear_drift(op_name: str) -> None:
    _STATE._drift_injections.pop(op_name, None)


def has_injection(op_name: str) -> bool:
    return (op_name in _STATE._fault_injections
            or op_name in _STATE._drift_injections)


def clear_injections() -> None:
    _STATE._fault_injections.clear()
    _STATE._drift_injections.clear()


__all__ = [
    "BREAKER_CLOSED",
    "BREAKER_HALF_OPEN",
    "BREAKER_OPEN",
    "DriftReport",
    "GUARD_MODES",
    "GuardConfig",
    "GuardMetrics",
    "GuardState",
    "KernelDriftError",
    "KernelGuardError",
    "OpBreaker",
    "SaturationError",
    "Tolerance",
    "attribute",
    "clear_drift",
    "clear_fault",
    "clear_injections",
    "compare",
    "configure",
    "has_injection",
    "inject_drift",
    "inject_fault",
    "is_quarantined",
    "isolated",
    "metrics",
    "probe",
    "probe_ops",
    "quarantine",
    "quarantined_ops",
    "register_probe",
    "register_sentinel",
    "reset",
    "revive",
    "state",
    "tolerance",
    "tracing",
    "trees_match",
    "verify_ops",
]
