"""Flash-attention Pallas kernel: blockwise online softmax with VMEM-resident
running (m, l, acc) state — the HBM->VMEM tiling the paper's Ch.3 analysis
prescribes for bandwidth-bound inner loops.

Grid: (batch*heads, q_blocks, kv_blocks), kv innermost ("arbitrary"
semantics: scratch persists across kv steps).  Causal blocks above the
diagonal are skipped entirely (predicated off), matching the lower-triangular
work layout of a causal LM.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30

#: |out| within this factor of finfo.max counts as saturated for fp16/bf16
_SATURATION_MARGIN = 0.99


def saturation_check(args, out):
    """Guard sentinel: saturated fraction of the attention output (see
    ``repro.kernels.guard``).

    The softmax weights are bounded in [0, 1], so the output is a convex
    combination of v rows — saturation can only come from the accumulation
    itself: non-finite entries (an overflowed qk^T row poisons the whole
    softmax) or, for the narrow fp16/bf16 dtypes, magnitudes pinned near
    ``finfo.max``.
    """
    o = np.asarray(out)
    if o.size == 0:
        return 0.0, "empty output"
    of = o.astype(np.float64)
    bad = ~np.isfinite(of)
    detail = "non-finite entries"
    if o.dtype in (np.dtype(np.float16), np.dtype(jnp.bfloat16)):
        limit = _SATURATION_MARGIN * float(jnp.finfo(o.dtype).max)
        bad |= np.abs(of) >= limit
        detail = f"non-finite or |out| >= {_SATURATION_MARGIN:g}*finfo.max"
    return float(np.mean(bad)), detail


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
    *, scale: float, causal: bool, bq: int, bk: int, kv_len: int, q_offset: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    if causal:
        # skip blocks strictly above the causal diagonal
        run = (ki * bk) <= (q_offset + qi * bq + bq - 1)
    else:
        run = (ki * bk) < kv_len

    @pl.when(run)
    def _():
        q = q_ref[0].astype(jnp.float32) * scale  # (bq, hd)
        k = k_ref[0].astype(jnp.float32)  # (bk, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (bq, bk)
        kidx = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        valid = kidx < kv_len
        if causal:
            qidx = q_offset + qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            valid = jnp.logical_and(valid, kidx <= qidx)
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(valid, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _():
        o_ref[0] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
        ).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    q_offset: int = 0,
    bq: int = 128,
    bk: int = 128,
    kv_len: int | None = None,
    interpret: bool = True,
) -> jax.Array:
    """q (BH, Sq, hd), k/v (BH, Skv, hd) — head-flattened layout.

    Sq/Skv are padded to block multiples by the ops wrapper; ``kv_len`` (the
    TRUE unpadded key count) masks padded keys inert.
    """
    bh, sq, hd = q.shape
    _, skv, _ = k.shape
    if kv_len is None:
        kv_len = skv
    bq = min(bq, sq)
    bk = min(bk, skv)
    assert sq % bq == 0 and skv % bk == 0
    grid = (bh, sq // bq, skv // bk)
    kern = partial(
        _flash_kernel,
        scale=hd**-0.5, causal=causal, bq=bq, bk=bk, kv_len=kv_len, q_offset=q_offset,
    )
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
