"""Streaming-bandwidth probe kernels (paper §3.1/3.2/3.7 analogue).

``stream_copy``: HBM->VMEM->HBM round trip per block (write-allocate path).
``stream_reduce``: read-only scan accumulating a checksum — the TPU analogue
of the paper's l1_bw/l2_bw read benchmarks (the accumulate into ``sink``
plays the same side-effect role as the paper's ``dsink``).

Block shape is the probe variable: footprint-per-step = block bytes, so
sweeping block shape vs. array footprint maps the memory-hierarchy transfer
efficiency exactly like the paper's working-set sweeps.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _copy_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def stream_copy(
    x: jax.Array, *, block_rows: int = 8, block_cols: int = 512, interpret: bool = True
) -> jax.Array:
    r, c = x.shape
    assert r % block_rows == 0 and c % block_cols == 0
    grid = (r // block_rows, c // block_cols)
    return pl.pallas_call(
        _copy_kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, block_cols), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((block_rows, block_cols), lambda i, j: (i, j)),
        interpret=interpret,
    )(x)


def _reduce_kernel(x_ref, o_ref):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(jnp.logical_and(i == 0, j == 0))
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[0, 0] += jnp.sum(x_ref[...].astype(jnp.float32))


def stream_reduce(
    x: jax.Array, *, block_rows: int = 8, block_cols: int = 512, interpret: bool = True
) -> jax.Array:
    """Read-bandwidth probe: returns the (1,1) fp32 checksum."""
    r, c = x.shape
    assert r % block_rows == 0 and c % block_cols == 0
    grid = (r // block_rows, c // block_cols)
    return pl.pallas_call(
        _reduce_kernel,
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, block_cols), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        interpret=interpret,
    )(x)


def _strided_reduce_kernel(x_ref, o_ref, *, stride: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    # touch one lane-row out of every `stride` sublane-rows: sparse-access
    # pattern probing load granularity (paper Tab 3.1 "load granularity")
    o_ref[0, 0] += jnp.sum(x_ref[::stride, :].astype(jnp.float32))


def strided_reduce(
    x: jax.Array, *, stride: int, block_rows: int = 64, interpret: bool = True
) -> jax.Array:
    r, c = x.shape
    assert r % block_rows == 0
    grid = (r // block_rows,)
    from functools import partial

    return pl.pallas_call(
        partial(_strided_reduce_kernel, stride=stride),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, c), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        interpret=interpret,
    )(x)
