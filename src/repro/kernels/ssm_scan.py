"""Chunked SSD (Mamba2) scan kernel.

One grid cell = one (batch*head); the chunk axis is innermost with the SSM
state (P, N) persisted in VMEM scratch across chunk steps — the Pallas
mirror of ``repro.models.mamba.ssd_chunked``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(u_ref, a_ref, b_ref, c_ref, o_ref, h_ref, *, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _():
        h_ref[...] = jnp.zeros_like(h_ref)

    u = u_ref[0].astype(jnp.float32)  # (L, P)
    a = a_ref[0].astype(jnp.float32)  # (L,)
    bmat = b_ref[0].astype(jnp.float32)  # (L, N)
    cmat = c_ref[0].astype(jnp.float32)  # (L, N)

    acum = jnp.cumsum(a)  # (L,)
    atot = acum[-1]
    h = h_ref[...]  # (P, N)

    # intra-chunk: decay-masked (C.B^T) score matrix
    cb = jax.lax.dot_general(
        cmat, bmat, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (L, L)
    dd = acum[:, None] - acum[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= jax.lax.broadcasted_iota(
        jnp.int32, (chunk, chunk), 1
    )
    w = cb * jnp.exp(jnp.clip(dd, -60.0, 0.0)) * tri.astype(jnp.float32)
    y_intra = jax.lax.dot_general(
        w, u, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (L, P)

    # inter-chunk: contribution of the carried state
    y_inter = jax.lax.dot_general(
        cmat, h, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * jnp.exp(acum)[:, None]  # (L, P)

    o_ref[0] = (y_intra + y_inter).astype(o_ref.dtype)

    # state update: h' = exp(atot) h + sum_s exp(atot - A_s) u_s B_s^T
    sdecay = jnp.exp(jnp.clip(atot - acum, -60.0, 0.0))  # (L,)
    us = u * sdecay[:, None]  # (L, P)
    h_ref[...] = h * jnp.exp(atot) + jax.lax.dot_general(
        us, bmat, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (P, N)


def ssm_scan_pallas(
    u: jax.Array,
    a_log: jax.Array,
    b: jax.Array,
    c: jax.Array,
    *,
    chunk: int = 256,
    interpret: bool = True,
) -> jax.Array:
    """u (BH, S, P); a_log (BH, S); b/c (BH, S, N); S % chunk == 0.

    Returns y (BH, S, P).  (State starts at zero; the framework's cross-chunk
    carry uses the model-level scan — this kernel is the per-sequence core.)
    """
    bh, s, p = u.shape
    n = b.shape[-1]
    assert s % chunk == 0
    grid = (bh, s // chunk)
    return pl.pallas_call(
        partial(_ssd_kernel, chunk=chunk),
        out_shape=jax.ShapeDtypeStruct(u.shape, u.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk), lambda i, j: (i, j)),
            pl.BlockSpec((1, chunk, n), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, p), lambda i, j: (i, j, 0)),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(u, a_log, b, c)
