"""Pallas TPU kernels behind a unified dispatch API.

Probe kernels (the paper's microbenchmark methodology, TPU-native):
  - ``pchase``   pointer-chase dependent-load latency probe (Mei & Chu, §3.1)
  - ``membw``    streaming bandwidth probe with explicit BlockSpec tiling (§3.2/3.7)
  - ``axpy``     the Ch.1 "wide accesses win" example as VMEM-tile width sweep

Compute kernels (perf-critical model hot-spots):
  - ``matmul``           MXU-tiled matmul (the §4.4 GEMM-throughput probe)
  - ``flash_attention``  blockwise-softmax attention
  - ``ssm_scan``         chunked SSD (Mamba2) scan

Each kernel is TARGETED at TPU (pl.pallas_call + BlockSpec VMEM tiling) and
VALIDATED against the pure-jnp oracles in ``ref.py``.

``api.py`` is the public entry point: every op is registered with three
backends — ``pallas`` (native path), ``interpret`` (forced interpret mode),
and ``xla`` (the ref.py oracle) — and dispatch is governed by the
context-local ``kernel_policy`` (backend selection, autotuned tiles).
"""
