"""Pallas TPU kernels.

Probe kernels (the paper's microbenchmark methodology, TPU-native):
  - ``pchase``   pointer-chase dependent-load latency probe (Mei & Chu, §3.1)
  - ``membw``    streaming bandwidth probe with explicit BlockSpec tiling (§3.2/3.7)
  - ``axpy``     the Ch.1 "wide accesses win" example as VMEM-tile width sweep

Compute kernels (perf-critical model hot-spots):
  - ``matmul``           MXU-tiled matmul (the §4.4 GEMM-throughput probe)
  - ``flash_attention``  blockwise-softmax attention
  - ``ssm_scan``         chunked SSD (Mamba2) scan

Each kernel is TARGETED at TPU (pl.pallas_call + BlockSpec VMEM tiling) and
VALIDATED in interpret mode on CPU against the pure-jnp oracles in ``ref.py``.
``ops.py`` holds the jit'd public wrappers.
"""
