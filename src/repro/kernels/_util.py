"""Shared block/layout helpers for the kernel dispatch layer.

Every Pallas wrapper used to repeat the same three snippets: the
``jax.default_backend() != "tpu"`` interpret heuristic, the
``min(block, dim)`` clamp, and ad-hoc ``jnp.pad`` calls to round dims up to
a block multiple.  They live here once; both the Pallas impls and the XLA
oracle bindings in :mod:`repro.kernels.api` share the layout transforms so
all backends of an op accept identical natural-layout arguments.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def default_interpret() -> bool:
    """Pallas kernels run in interpret mode everywhere but real TPU."""
    return jax.default_backend() != "tpu"


def fit_block(block: int, dim: int) -> int:
    """Clamp a requested block size to the actual dimension."""
    return min(block, dim)


def pad_to_multiple(x: jax.Array, multiple: int, axis: int) -> jax.Array:
    """Zero-pad ``axis`` of ``x`` up to the next multiple of ``multiple``."""
    size = x.shape[axis]
    pad = (-size) % multiple
    if not pad:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def flatten_heads(x: jax.Array) -> jax.Array:
    """(B, S, H, hd) model layout -> (B*H, S, hd) kernel layout."""
    b, s, h, hd = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b * h, s, hd)


def unflatten_heads(x: jax.Array, batch: int) -> jax.Array:
    """(B*H, S, hd) kernel layout -> (B, S, H, hd) model layout."""
    bh, s, hd = x.shape
    return x.reshape(batch, bh // batch, s, hd).transpose(0, 2, 1, 3)


def flatten_ssm(u: jax.Array, a_log: jax.Array, b: jax.Array, c: jax.Array):
    """SSD model layout -> per-(batch*head) kernel layout.

    u (B,S,H,P) -> (B*H,S,P); a_log (B,S,H) -> (B*H,S); head-shared b/c
    (B,S,N) are broadcast per head -> (B*H,S,N).
    """
    bsz, s, h, p = u.shape
    n = b.shape[-1]
    uf = u.transpose(0, 2, 1, 3).reshape(bsz * h, s, p)
    af = a_log.transpose(0, 2, 1).reshape(bsz * h, s)
    bf = jnp.repeat(b[:, None], h, axis=1).reshape(bsz * h, s, n)
    cf = jnp.repeat(c[:, None], h, axis=1).reshape(bsz * h, s, n)
    return uf, af, bf, cf
