"""Shared serialization primitives for benchmark results and dissect reports.

Lives in ``repro.core`` so that core modules (dissect) and the higher-level
``repro.bench`` package can share one schema version, env fingerprint, and
probe layout without an upward core -> bench dependency; ``repro.bench.schema``
re-exports everything here.
"""
from __future__ import annotations

import platform
import sys
from dataclasses import dataclass
from typing import Optional

SCHEMA_VERSION = 1


@dataclass(frozen=True)
class EnvFingerprint:
    """Where a result came from — stored with results AND baselines."""

    jax_version: str
    jaxlib_version: str
    backend: str
    device_kind: str
    device_count: int
    platform: str
    python_version: str

    @staticmethod
    def capture() -> "EnvFingerprint":
        import jax
        import jaxlib

        dev = jax.devices()[0]
        return EnvFingerprint(
            jax_version=jax.__version__,
            jaxlib_version=jaxlib.__version__,
            backend=jax.default_backend(),
            device_kind=getattr(dev, "device_kind", str(dev)),
            device_count=jax.device_count(),
            platform=platform.platform(),
            python_version=sys.version.split()[0],
        )


def probe_to_dict(res) -> dict:
    """Serialize a core.probes.ProbeResult into the shared probe layout."""
    return {"x": list(res.x), "y": list(res.y), "unit": res.unit, "meta": dict(res.meta)}


def finite(v: float, fallback: Optional[float] = 0.0) -> float:
    """JSON-safe float (strict JSON has no Infinity/NaN)."""
    v = float(v)
    if v != v or v in (float("inf"), float("-inf")):
        return float(fallback)
    return v
