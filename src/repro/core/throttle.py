"""Clock-throttling model (paper §4.5, Figs 4.3–4.5).

The paper characterizes two mechanisms on the 70 W T4:
  - power-limit throttling: instantaneous power > limit -> proportional clock
    reduction (gradual derate as utilization/matrix size grows, Fig 4.3);
  - thermal throttling: at max operating temperature (85 C) an additional,
    much steeper step-down (Fig 4.4).

We fit that behavior as a first-order thermal RC model + a power-governor
loop.  The default parameterization reproduces the paper's qualitative
curves (validated in tests/benchmarks): full clock for only the first few
seconds, power-limited plateau, thermal step once T reaches max_temp.

Framework integration: ``repro.ft.straggler`` uses ``steady_state_clock`` to
translate observed step-time inflation into a "is this chip thermally
throttled?" judgement — on a 1000-chip fleet the throttled chips of Fig 4.4
are exactly the stragglers.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class ThrottleParams:
    f_max_hz: float
    power_limit_w: float
    max_temp_c: float
    ambient_c: float = 30.0
    idle_power_w: float = 20.0
    # dynamic power ~ c * f * u   (activity-proportional, fixed voltage band)
    watts_per_hz: float = 50.0 / 1.59e9
    # first-order thermal model: C dT/dt = P - (T - T_amb)/R
    thermal_r: float = 0.9  # C per W
    thermal_c: float = 120.0  # J per C
    thermal_derate: float = 0.82  # extra clock factor once at max temp
    governor_gain: float = 0.25  # fraction of clock error corrected per step


T4_THROTTLE = ThrottleParams(
    f_max_hz=1.59e9,
    power_limit_w=70.0,
    max_temp_c=85.0,
    idle_power_w=20.0,
    # full-MXU... full-CUDA-load power slightly exceeds the 70 W cap, so the
    # governor derates within seconds (paper: "only able to run at their
    # highest supported clock frequency for a few seconds", Fig 4.5)
    watts_per_hz=58.0 / 1.59e9,
    thermal_c=60.0,
)

V5E_THROTTLE = ThrottleParams(
    f_max_hz=1.70e9,
    power_limit_w=170.0,
    max_temp_c=87.0,
    idle_power_w=60.0,
    # sustained full-MXU load modestly exceeds the 170 W envelope -> the
    # same power-then-thermal derate shape the paper measured on the T4
    watts_per_hz=135.0 / 1.70e9,
    thermal_r=0.35,
    thermal_c=260.0,
)


@dataclass
class ThrottleState:
    clock_hz: float
    temp_c: float
    power_w: float


def power(p: ThrottleParams, clock_hz: float, utilization: float) -> float:
    return p.idle_power_w + p.watts_per_hz * clock_hz * utilization


def step(p: ThrottleParams, s: ThrottleState, utilization: float, dt: float) -> ThrottleState:
    """Advance the governor + thermal model by ``dt`` seconds."""
    pw = power(p, s.clock_hz, utilization)
    # thermal integration
    temp = s.temp_c + dt * (pw - (s.temp_c - p.ambient_c) / p.thermal_r) / p.thermal_c
    # power governor: move clock toward the highest value satisfying the cap
    if utilization > 0:
        f_power = (p.power_limit_w - p.idle_power_w) / (p.watts_per_hz * utilization)
    else:
        f_power = p.f_max_hz
    f_target = min(p.f_max_hz, f_power)
    if temp >= p.max_temp_c:  # thermal throttling: steeper step-down (Fig 4.4)
        f_target = min(f_target, p.thermal_derate * f_power)
    clock = s.clock_hz + p.governor_gain * (f_target - s.clock_hz)
    clock = float(np.clip(clock, 0.1 * p.f_max_hz, p.f_max_hz))
    return ThrottleState(clock_hz=clock, temp_c=float(temp), power_w=float(pw))


def simulate(
    p: ThrottleParams, utilization: float, duration_s: float, dt: float = 0.5
) -> dict:
    """Run the model; returns arrays t/clock/temp/power (Fig 4.3/4.4 traces)."""
    s = ThrottleState(clock_hz=p.f_max_hz, temp_c=p.ambient_c, power_w=p.idle_power_w)
    n = int(duration_s / dt)
    t = np.arange(n) * dt
    clock = np.empty(n)
    temp = np.empty(n)
    pw = np.empty(n)
    for i in range(n):
        clock[i], temp[i], pw[i] = s.clock_hz, s.temp_c, s.power_w
        s = step(p, s, utilization, dt)
    return {"t": t, "clock_hz": clock, "temp_c": temp, "power_w": pw}


def steady_state_clock(p: ThrottleParams, utilization: float) -> float:
    """Long-run clock under sustained utilization (straggler detector input)."""
    out = simulate(p, utilization, duration_s=600.0, dt=1.0)
    return float(out["clock_hz"][-1])


def slowdown_factor(p: ThrottleParams, utilization: float) -> float:
    """Expected step-time inflation of a fully-throttled chip vs. nominal."""
    return p.f_max_hz / max(steady_state_clock(p, utilization), 1.0)
