"""Benchmark registry — paper-table benchmarks register here with metadata.

``repro.bench`` re-exports :func:`register`; suites decorate a function that
returns ``list[BenchRecord]`` and declare which paper table/figure it
reproduces plus its quick/full sweep grids:

    @register("axpy", paper_ref="Fig 1.1",
              quick={"sizes": (1 << 18,)}, full={"sizes": (1 << 18, 1 << 22)})
    def bench_axpy(sizes=(1 << 18,)) -> list[BenchRecord]: ...

The runner looks benchmarks up here, picks the grid for the requested mode,
and calls the function with those keyword arguments.

Passing ``backends=("pallas", "xla")`` registers one *variant* per backend
(named ``name[backend]``, the paper's side-by-side comparison axis) instead
of the bare name.  Each variant runs its function under
``kernel_policy(backend=...)`` from :mod:`repro.kernels.api`, passes
``backend=`` through when the function accepts it, and tags the emitted
record names with ``[backend]`` so a single results document holds every
hardware path of the same measurement.
"""
from __future__ import annotations

import inspect
from dataclasses import dataclass, field, replace
from typing import Callable, Optional


@dataclass(frozen=True)
class BenchSpec:
    """A registered benchmark plus its per-mode sweep grids."""

    name: str
    fn: Callable
    paper_ref: str = ""  # e.g. "Fig 1.1", "Tab 4.3"
    description: str = ""
    quick: dict = field(default_factory=dict)  # kwargs for quick mode
    full: dict = field(default_factory=dict)  # kwargs for full mode
    tags: tuple = ()
    backend: str = ""  # kernel backend for a parameterized variant

    def params(self, mode: str = "quick") -> dict:
        if mode not in ("quick", "full"):
            raise ValueError(f"mode must be quick|full, got {mode!r}")
        return dict(self.quick if mode == "quick" else self.full)

    def run(self, mode: str = "quick", overrides: Optional[dict] = None) -> list:
        kwargs = self.params(mode)
        if overrides:
            kwargs.update(overrides)
        if not self.backend:
            return self.fn(**kwargs)
        # backend variant: scope the kernel policy, thread the backend kwarg
        # through when accepted, and tag records with the variant identity.
        from repro.kernels.api import kernel_policy

        if "backend" in inspect.signature(self.fn).parameters:
            kwargs.setdefault("backend", self.backend)
        with kernel_policy(backend=self.backend):
            recs = self.fn(**kwargs)
        tag = f"[{self.backend}]"
        return [
            replace(
                r,
                benchmark=self.name,
                name=r.name if r.name.endswith(tag) else r.name + tag,
            )
            for r in recs
        ]


_REGISTRY: dict[str, BenchSpec] = {}


def register(
    name: str,
    *,
    paper_ref: str = "",
    description: str = "",
    quick: Optional[dict] = None,
    full: Optional[dict] = None,
    tags: tuple = (),
    backends: tuple = (),
):
    """Decorator: register ``fn`` as benchmark ``name`` with its metadata.

    With ``backends``, registers one ``name[backend]`` variant per entry
    (and not the bare ``name``).
    """

    def deco(fn: Callable) -> Callable:
        doc_first = (fn.__doc__ or "").strip().splitlines()
        desc = description or (doc_first[0] if doc_first else "")
        variants = [(f"{name}[{b}]", b) for b in backends] if backends else [(name, "")]
        for vname, _ in variants:  # all-or-nothing: check before any insert
            if vname in _REGISTRY:
                raise ValueError(f"benchmark {vname!r} already registered")
        for vname, backend in variants:
            _REGISTRY[vname] = BenchSpec(
                name=vname,
                fn=fn,
                paper_ref=paper_ref,
                description=desc,
                quick=dict(quick or {}),
                full=dict(full if full is not None else quick or {}),
                tags=tuple(tags),
                backend=backend,
            )
        return fn

    return deco


def get(name: str) -> BenchSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown benchmark {name!r}; registered: {', '.join(names()) or '(none)'}"
        ) from None


def names() -> list[str]:
    return sorted(_REGISTRY)


def specs() -> list[BenchSpec]:
    return [_REGISTRY[n] for n in names()]


def unregister(name: str) -> None:
    """Remove a registration (test helper)."""
    _REGISTRY.pop(name, None)
