"""Benchmark registry — paper-table benchmarks register here with metadata.

``repro.bench`` re-exports :func:`register`; suites decorate a function that
returns ``list[BenchRecord]`` and declare which paper table/figure it
reproduces plus its quick/full sweep grids:

    @register("axpy", paper_ref="Fig 1.1",
              quick={"sizes": (1 << 18,)}, full={"sizes": (1 << 18, 1 << 22)})
    def bench_axpy(sizes=(1 << 18,)) -> list[BenchRecord]: ...

The runner looks benchmarks up here, picks the grid for the requested mode,
and calls the function with those keyword arguments.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional


@dataclass(frozen=True)
class BenchSpec:
    """A registered benchmark plus its per-mode sweep grids."""

    name: str
    fn: Callable
    paper_ref: str = ""  # e.g. "Fig 1.1", "Tab 4.3"
    description: str = ""
    quick: dict = field(default_factory=dict)  # kwargs for quick mode
    full: dict = field(default_factory=dict)  # kwargs for full mode
    tags: tuple = ()

    def params(self, mode: str = "quick") -> dict:
        if mode not in ("quick", "full"):
            raise ValueError(f"mode must be quick|full, got {mode!r}")
        return dict(self.quick if mode == "quick" else self.full)

    def run(self, mode: str = "quick", overrides: Optional[dict] = None) -> list:
        kwargs = self.params(mode)
        if overrides:
            kwargs.update(overrides)
        return self.fn(**kwargs)


_REGISTRY: dict[str, BenchSpec] = {}


def register(
    name: str,
    *,
    paper_ref: str = "",
    description: str = "",
    quick: Optional[dict] = None,
    full: Optional[dict] = None,
    tags: tuple = (),
):
    """Decorator: register ``fn`` as benchmark ``name`` with its metadata."""

    def deco(fn: Callable) -> Callable:
        if name in _REGISTRY:
            raise ValueError(f"benchmark {name!r} already registered")
        doc_first = (fn.__doc__ or "").strip().splitlines()
        _REGISTRY[name] = BenchSpec(
            name=name,
            fn=fn,
            paper_ref=paper_ref,
            description=description or (doc_first[0] if doc_first else ""),
            quick=dict(quick or {}),
            full=dict(full if full is not None else quick or {}),
            tags=tuple(tags),
        )
        return fn

    return deco


def get(name: str) -> BenchSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown benchmark {name!r}; registered: {', '.join(names()) or '(none)'}"
        ) from None


def names() -> list[str]:
    return sorted(_REGISTRY)


def specs() -> list[BenchSpec]:
    return [_REGISTRY[n] for n in names()]


def unregister(name: str) -> None:
    """Remove a registration (test helper)."""
    _REGISTRY.pop(name, None)
