"""Benchmark registry: benchmarks/run.py discovers paper-table benchmarks here."""
from __future__ import annotations

from typing import Callable

_REGISTRY: dict[str, Callable] = {}


def register(name: str):
    def deco(fn: Callable) -> Callable:
        _REGISTRY[name] = fn
        return fn

    return deco


def get(name: str) -> Callable:
    return _REGISTRY[name]


def names() -> list[str]:
    return sorted(_REGISTRY)
