"""Pointer-chase pattern generation + latency-curve analysis (Mei & Chu [9],
paper §3.1/3.8).

``single_cycle_permutation`` (Sattolo) gives the random-walk pattern that
defeats prefetchers; ``stride_permutation`` gives the paper's TLB-style
strided walk.  ``detect_plateaus`` reads cache-level sizes and latencies off
the measured curve exactly the way Fig 3.5 / Tab 3.1 were produced.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


def single_cycle_permutation(n: int, seed: int = 0) -> np.ndarray:
    """Random permutation with one n-cycle (Sattolo's algorithm)."""
    rng = np.random.default_rng(seed)
    items = np.arange(n, dtype=np.int64)
    for i in range(n - 1, 0, -1):
        j = rng.integers(0, i)
        items[i], items[j] = items[j], items[i]
    # items is now a cyclic ordering; build successor map
    perm = np.empty(n, dtype=np.int32)
    perm[items[:-1]] = items[1:]
    perm[items[-1]] = items[0]
    return perm


def stride_permutation(n: int, stride: int) -> np.ndarray:
    """Walk with fixed stride (mod n); requires gcd(stride, n) == 1 for a
    full cycle — the caller should pass odd strides for power-of-two n."""
    idx = np.arange(n, dtype=np.int64)
    perm = ((idx + stride) % n).astype(np.int32)
    return perm


@dataclass(frozen=True)
class Plateau:
    latency: float  # representative latency of this level
    start_size: int  # first footprint on the plateau
    end_size: int  # last footprint before the next transition


def detect_plateaus(
    sizes: np.ndarray, lat: np.ndarray, rel_jump: float = 0.30
) -> list[Plateau]:
    """Segment a latency-vs-footprint curve into plateaus.

    A new level starts where latency jumps by more than ``rel_jump`` relative
    to the running plateau median — the transition size is the capacity of
    the previous level (paper Fig 3.6 methodology).
    """
    sizes = np.asarray(sizes)
    lat = np.asarray(lat, dtype=np.float64)
    assert sizes.shape == lat.shape and sizes.ndim == 1
    plateaus: list[Plateau] = []
    seg_start = 0
    seg_vals = [lat[0]]
    for i in range(1, len(sizes)):
        base = float(np.median(seg_vals))
        if lat[i] > base * (1.0 + rel_jump):
            plateaus.append(Plateau(base, int(sizes[seg_start]), int(sizes[i - 1])))
            seg_start = i
            seg_vals = [lat[i]]
        else:
            seg_vals.append(lat[i])
    plateaus.append(Plateau(float(np.median(seg_vals)), int(sizes[seg_start]), int(sizes[-1])))
    return plateaus


def capacities_from_plateaus(plateaus: list[Plateau]) -> list[int]:
    """Detected capacity of each level = footprint where the next level begins."""
    return [p.end_size for p in plateaus[:-1]]
