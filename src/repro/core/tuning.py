"""Persisted autotune cache for the kernel dispatch layer.

The paper's workflow is: microbenchmark the hardware once, distill the
findings into a model, then let the model drive tile/layout choices forever
after.  ``TuningCache`` is the "forever after" part: tile choices computed by
:mod:`repro.core.autotune` are memoized under a key of
``(op, shape signature, dtype, backend)`` and optionally persisted to a JSON
file so later processes skip the search.

The cache is deliberately dumb — a flat ``{key: {tile kwarg: int}}`` table —
so the JSON file is hand-inspectable and diffs cleanly in review.  Set the
``REPRO_TUNING_CACHE`` environment variable (or call :func:`configure`) to
enable persistence; by default the cache is in-memory only, which keeps unit
tests and CI hermetic.
"""
from __future__ import annotations

import json
import os
from pathlib import Path
from typing import Optional

__all__ = ["ENV_VAR", "TuningCache", "configure", "get_cache", "make_key", "shape_signature"]

ENV_VAR = "REPRO_TUNING_CACHE"


def shape_signature(args) -> str:
    """Stable signature of the array arguments: ``f32[128,256];f32[256,64]``."""
    parts = []
    for a in args:
        shape = getattr(a, "shape", None)
        dtype = getattr(a, "dtype", None)
        if shape is None or dtype is None:
            continue  # scalars/python values don't affect tiling
        parts.append(f"{dtype}[{','.join(str(d) for d in shape)}]")
    return ";".join(parts)


def make_key(op: str, args, backend: str) -> str:
    return f"{op}|{backend}|{shape_signature(args)}"


class TuningCache:
    """Flat tile-choice store with hit/miss counters and JSON persistence."""

    def __init__(self, path: Optional[str] = None):
        self.path = Path(path) if path else None
        self.entries: dict = {}
        self.hits = 0
        self.misses = 0
        if self.path is not None and self.path.exists():
            self.load(self.path)

    # -- lookup/store -------------------------------------------------------
    def lookup(self, key: str) -> Optional[dict]:
        entry = self.entries.get(key)
        if entry is None:
            self.misses += 1
            return None
        self.hits += 1
        return dict(entry)

    def store(self, key: str, tiles: dict) -> None:
        self.entries[key] = {k: int(v) for k, v in tiles.items()}
        if self.path is not None:
            self.save(self.path)

    def __len__(self) -> int:
        return len(self.entries)

    # -- persistence --------------------------------------------------------
    def save(self, path) -> None:
        """Merge-then-replace: re-read entries persisted by other processes
        (ours win on key conflict), then write via a temp file + os.replace
        so concurrent readers never observe a half-written document."""
        p = Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        if p.exists():
            try:
                self.load(p, theirs_win=False)
            except (ValueError, json.JSONDecodeError):
                pass  # corrupt/foreign file: overwrite with our entries
        tmp = p.with_name(f"{p.name}.tmp{os.getpid()}")
        tmp.write_text(json.dumps({"version": 1, "entries": self.entries}, indent=2) + "\n")
        os.replace(tmp, p)

    def load(self, path, theirs_win: bool = True) -> None:
        doc = json.loads(Path(path).read_text())
        if doc.get("version") != 1:
            raise ValueError(f"{path}: unsupported tuning-cache version {doc.get('version')}")
        theirs = doc.get("entries", {})
        if theirs_win:
            self.entries.update(theirs)
        else:
            self.entries = {**theirs, **self.entries}


# ---------------------------------------------------------------------------
# process-wide singleton (the dispatch layer's default cache)
# ---------------------------------------------------------------------------
_CACHE: Optional[TuningCache] = None


def get_cache() -> TuningCache:
    global _CACHE
    if _CACHE is None:
        _CACHE = TuningCache(path=os.environ.get(ENV_VAR) or None)
    return _CACHE


def configure(path: Optional[str] = None) -> TuningCache:
    """Replace the process-wide cache (tests; opting into persistence)."""
    global _CACHE
    _CACHE = TuningCache(path=path)
    return _CACHE
