"""Steady-state wall-clock harness (the paper's clock-bracket methodology,
adapted: no %%clock register on host, so warm-up + median-of-k around
``block_until_ready``)."""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable

import jax


@dataclass(frozen=True)
class Timing:
    median_s: float
    min_s: float
    mean_s: float
    reps: int

    @property
    def median_us(self) -> float:
        return self.median_s * 1e6


def percentile(samples, q: float) -> float:
    """Linear-interpolated percentile (``q`` in [0, 100]) of a sample
    sequence; NaN on empty input.  Shared by the serving metrics and any
    harness that reports latency distributions."""
    samples = list(samples)
    if not samples:
        return float("nan")
    import numpy as np

    return float(np.percentile(samples, q))


def time_fn(fn: Callable, *args, warmup: int = 3, reps: int = 10, **kw) -> Timing:
    """Times ``fn(*args, **kw)``; fn must return jax arrays (blocked on)."""
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    samples = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        samples.append(time.perf_counter() - t0)
    samples.sort()
    n = len(samples)
    med = samples[n // 2] if n % 2 else 0.5 * (samples[n // 2 - 1] + samples[n // 2])
    return Timing(median_s=med, min_s=samples[0], mean_s=sum(samples) / n, reps=n)
