"""HardwareModel-driven tile/layout selection — the paper's Chapter-1 loop
("know the hardware -> rewrite the access pattern") automated.

Scores candidate Pallas block shapes with a two-term model (MXU compute vs.
HBM<->VMEM traffic under the VMEM capacity constraint) and returns the
argmin.  Used by the GEMM benchmark and the §Perf hillclimb.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

from .hwmodel import TPU_V5E, HardwareModel


@dataclass(frozen=True)
class TileChoice:
    bm: int
    bk: int
    bn: int
    predicted_s: float
    vmem_bytes: int
    notes: str = ""


def _dtype_bytes(dtype: str) -> int:
    sizes = {"bfloat16": 2, "float16": 2, "float32": 4, "int8": 1}
    try:
        return sizes[dtype]
    except KeyError:
        raise KeyError(
            f"no byte-size entry for dtype {dtype!r}; known: {sorted(sizes)}"
        ) from None


def dtype_name(dtype) -> str:
    """Normalize a jnp/numpy dtype (or string) to the names the models use."""
    return str(getattr(dtype, "name", dtype))


def matmul_time_model(
    m: int, k: int, n: int, bm: int, bk: int, bn: int, dtype: str, hw: HardwareModel
) -> tuple[float, int]:
    """(predicted seconds, VMEM working set).

    Traffic model: A is streamed once per N-block column, B once per M-block
    row, C written once:
        bytes = (n/bn) * m*k + (m/bm) * k*n + m*n
    Compute: 2mnk / peak(dtype), assuming full MXU utilization for
    128-aligned tiles, derated for misaligned ones.
    """
    eb = _dtype_bytes(dtype)
    traffic = (n // bn) * m * k * eb + (m // bm) * k * n * eb + m * n * eb
    t_mem = traffic / hw.main_memory_Bps
    align = hw.mxu_align()
    eff = 1.0
    for b in (bm, bk, bn):
        if b % align:
            eff *= max(b / (align * -(-b // align)), 0.25)
    t_compute = 2.0 * m * n * k / (hw.peak(dtype) * eff)
    vmem = (bm * bk + bk * bn + bm * bn) * eb + bm * bn * 4  # + fp32 acc
    return max(t_mem, t_compute), vmem


def choose_matmul_tiles(
    m: int,
    k: int,
    n: int,
    dtype: str = "bfloat16",
    hw: HardwareModel = TPU_V5E,
    candidates: Sequence[int] = (128, 256, 512, 1024),
    vmem_budget_frac: float = 0.8,
) -> TileChoice:
    budget = int(hw.staging_bytes * vmem_budget_frac)
    best: TileChoice | None = None
    for bm in candidates:
        if m % bm:
            continue
        for bk in candidates:
            if k % bk:
                continue
            for bn in candidates:
                if n % bn:
                    continue
                t, v = matmul_time_model(m, k, n, bm, bk, bn, dtype, hw)
                if v > budget:
                    continue
                if best is None or t < best.predicted_s:
                    best = TileChoice(bm, bk, bn, t, v)
    if best is None:  # fall back to whole-array (small problem)
        t, v = matmul_time_model(m, k, n, m, k, n, dtype, hw)
        best = TileChoice(m, k, n, t, v, notes="unblocked-fallback")
    return best


def choose_attention_chunk(
    seq_len: int,
    head_dim: int,
    n_heads_local: int,
    dtype: str = "bfloat16",
    hw: HardwareModel = TPU_V5E,
    candidates: Sequence[int] = (128, 256, 512, 1024, 2048),
    vmem_budget_frac: float = 0.6,
) -> int:
    """KV-chunk size for blockwise attention: biggest chunk whose working set
    (q tile + kv chunk + acc) fits the VMEM budget — larger chunks amortize
    HBM streaming (the Ch.1 width lesson applied to attention)."""
    eb = _dtype_bytes(dtype)
    budget = hw.staging_bytes * vmem_budget_frac
    best = candidates[0]
    for c in candidates:
        if c > seq_len:
            break
        # per-core working set: q block (128, hd), kv chunk (c, hd) x2, acc
        ws = (128 * head_dim + 2 * c * head_dim) * eb + 128 * head_dim * 4
        ws *= n_heads_local
        if ws <= budget:
            best = c
    return best


def choose_ssm_chunk(
    seq_len: int,
    head_dim: int,
    state_dim: int,
    dtype: str = "float32",
    hw: HardwareModel = TPU_V5E,
    candidates: Sequence[int] = (64, 128, 256, 512),
    vmem_budget_frac: float = 0.6,
) -> int:
    """Chunk length for the chunked-SSD scan: biggest chunk whose per-step
    working set (u/y tiles, B/C chunks, and the (chunk, chunk) intra-chunk
    decay matrix) fits the VMEM budget — same width-vs-capacity trade as
    :func:`choose_attention_chunk`, with the quadratic score tile dominating."""
    eb = _dtype_bytes(dtype)
    budget = hw.staging_bytes * vmem_budget_frac
    best = candidates[0]
    for c in candidates:
        if c > seq_len:
            break
        ws = c * (2 * head_dim + 2 * state_dim) * eb + c * c * 4  # + fp32 decay tile
        if ws <= budget:
            best = c
    return best
