"""HardwareModel-driven tile/layout selection — the paper's Chapter-1 loop
("know the hardware -> rewrite the access pattern") automated.

Scores candidate Pallas block shapes with a two-term model (MXU compute vs.
HBM<->VMEM traffic under the VMEM capacity constraint) and returns the
argmin.  Hardware facts come from the :mod:`repro.hw` spec database —
every entry point takes ``hw=`` as a DB name or a ``HardwareModel``, so
tiles can be chosen for any registered part.  Consumed by the GEMM bench
suites, :mod:`repro.kernels.api` autotuning, and the ``benchmarks/hillclimb.py``
entry point (which re-lowers cells under modified configs; that tool imports
``repro.launch.cell``, not this module).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence, Union

from repro.hw import HardwareModel, resolve as _resolve_hw

from .hwmodel import TPU_V5E

HwLike = Union[str, HardwareModel]  # every hw= arg takes a DB name or a model


@dataclass(frozen=True)
class TileChoice:
    bm: int
    bk: int
    bn: int
    predicted_s: float
    vmem_bytes: int
    notes: str = ""


_DTYPE_BYTES = {
    "float64": 8,
    "float32": 4,
    "tf32": 4,
    "bfloat16": 2,
    "float16": 2,
    "int8": 1,
    "float8_e4m3fn": 1,
    "float8_e5m2": 1,
    "int4": 0.5,
}

# nearest-supported-precision chains for parts that don't publish a peak for
# the requested dtype (documented fallback= semantics of HardwareModel.peak):
# bf16 on a pre-Ampere GPU costs at its fp16 TensorCore rate, fp8 on a
# pre-Hopper part at its int8 rate, everything else degrades to fp32.
_PEAK_FALLBACK = {
    "bfloat16": ("float16", "float32"),
    "float16": ("bfloat16", "float32"),
    "tf32": ("float32",),
    "float8_e4m3fn": ("int8", "bfloat16", "float32"),
    "float8_e5m2": ("int8", "bfloat16", "float32"),
    "int4": ("int8", "float32"),
    "int8": ("bfloat16", "float32"),
}


def peak_for(hw: HwLike, dtype: str) -> float:
    """Per-dtype peak from the spec DB with the autotuner's fallback chain —
    int8/bf16 tiles are costed at their own rates where the part publishes
    them, at the nearest supported precision where it doesn't."""
    return _resolve_hw(hw).peak(dtype, fallback=_PEAK_FALLBACK.get(dtype, ("float32",)))


def _dtype_bytes(dtype: str) -> float:
    try:
        return _DTYPE_BYTES[dtype]
    except KeyError:
        raise KeyError(
            f"no byte-size entry for dtype {dtype!r}; known: {sorted(_DTYPE_BYTES)}"
        ) from None


def dtype_name(dtype) -> str:
    """Normalize a jnp/numpy dtype (or string) to the names the models use."""
    return str(getattr(dtype, "name", dtype))


def matmul_time_model(
    m: int, k: int, n: int, bm: int, bk: int, bn: int, dtype: str, hw: HwLike
) -> tuple[float, int]:
    """(predicted seconds, VMEM working set).

    ``hw`` is a spec-DB name or a :class:`HardwareModel`.  Traffic model: A
    is streamed once per N-block column, B once per M-block row, C written
    once:
        bytes = (n/bn) * m*k + (m/bm) * k*n + m*n
    Compute: 2mnk / peak(dtype) via :func:`peak_for` (per-dtype DB peaks
    with the nearest-precision fallback), assuming full MXU utilization for
    128-aligned tiles, derated for misaligned ones.
    """
    hw = _resolve_hw(hw)
    eb = _dtype_bytes(dtype)
    traffic = (n // bn) * m * k * eb + (m // bm) * k * n * eb + m * n * eb
    t_mem = traffic / hw.main_memory_Bps
    align = hw.mxu_align()
    eff = 1.0
    for b in (bm, bk, bn):
        if b % align:
            eff *= max(b / (align * -(-b // align)), 0.25)
    t_compute = 2.0 * m * n * k / (peak_for(hw, dtype) * eff)
    vmem = int((bm * bk + bk * bn + bm * bn) * eb) + bm * bn * 4  # + fp32 acc
    return max(t_mem, t_compute), vmem


def choose_matmul_tiles(
    m: int,
    k: int,
    n: int,
    dtype: str = "bfloat16",
    hw: HwLike = TPU_V5E,
    candidates: Sequence[int] = (128, 256, 512, 1024),
    vmem_budget_frac: float = 0.8,
) -> TileChoice:
    hw = _resolve_hw(hw)
    budget = int(hw.staging_bytes * vmem_budget_frac)
    best: TileChoice | None = None
    for bm in candidates:
        if m % bm:
            continue
        for bk in candidates:
            if k % bk:
                continue
            for bn in candidates:
                if n % bn:
                    continue
                t, v = matmul_time_model(m, k, n, bm, bk, bn, dtype, hw)
                if v > budget:
                    continue
                if best is None or t < best.predicted_s:
                    best = TileChoice(bm, bk, bn, t, v)
    if best is None:  # fall back to whole-array (small problem)
        t, v = matmul_time_model(m, k, n, m, k, n, dtype, hw)
        best = TileChoice(m, k, n, t, v, notes="unblocked-fallback")
    return best


def choose_attention_chunk(
    seq_len: int,
    head_dim: int,
    n_heads_local: int,
    dtype: str = "bfloat16",
    hw: HwLike = TPU_V5E,
    candidates: Sequence[int] = (128, 256, 512, 1024, 2048),
    vmem_budget_frac: float = 0.6,
) -> int:
    """KV-chunk size for blockwise attention: biggest chunk whose working set
    (q tile + kv chunk + acc) fits the VMEM budget — larger chunks amortize
    HBM streaming (the Ch.1 width lesson applied to attention)."""
    hw = _resolve_hw(hw)
    eb = _dtype_bytes(dtype)
    budget = hw.staging_bytes * vmem_budget_frac
    best = candidates[0]
    for c in candidates:
        if c > seq_len:
            break
        # per-core working set: q block (128, hd), kv chunk (c, hd) x2, acc
        ws = (128 * head_dim + 2 * c * head_dim) * eb + 128 * head_dim * 4
        ws *= n_heads_local
        if ws <= budget:
            best = c
    return best


def choose_ssm_chunk(
    seq_len: int,
    head_dim: int,
    state_dim: int,
    dtype: str = "float32",
    hw: HwLike = TPU_V5E,
    candidates: Sequence[int] = (64, 128, 256, 512),
    vmem_budget_frac: float = 0.6,
) -> int:
    """Chunk length for the chunked-SSD scan: biggest chunk whose per-step
    working set (u/y tiles, B/C chunks, and the (chunk, chunk) intra-chunk
    decay matrix) fits the VMEM budget — same width-vs-capacity trade as
    :func:`choose_attention_chunk`, with the quadratic score tile dominating."""
    hw = _resolve_hw(hw)
    eb = _dtype_bytes(dtype)
    budget = hw.staging_bytes * vmem_budget_frac
    best = candidates[0]
    for c in candidates:
        if c > seq_len:
            break
        ws = c * (2 * head_dim + 2 * state_dim) * eb + c * c * 4  # + fp32 decay tile
        if ws <= budget:
            best = c
    return best
