"""Probe definitions — each maps one of the paper's benchmark families onto
a measurable JAX/Pallas workload.

Measure mode runs for real on the current backend (CPU here: the probes then
characterize the *host's* memory hierarchy — the end-to-end validation of the
methodology).  Model mode predicts TPU v5e numbers from the HardwareModel
(reported in EXPERIMENTS.md; on a real TPU the same probes run natively).

Probes that exercise a kernel take a ``backend`` argument routed through
:mod:`repro.kernels.api` ("pallas" | "interpret" | "xla"), so one probe
definition measures every hardware path side by side — the paper's
same-op-different-path recipe.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import api

from . import pchase as pc
from .timing import time_fn


@dataclass(frozen=True)
class ProbeResult:
    name: str
    x: tuple  # sweep variable values
    y: tuple  # measured values
    unit: str
    meta: dict


def _pick_backend(backend: Optional[str], default: str = "xla") -> str:
    """Resolve the probe's kernel path: explicit ``backend`` kwarg > an
    ambient ``kernel_policy`` backend > the probe's own ``default``."""
    if backend is not None:
        return backend
    return api.current_policy().backend or default


# ---------------------------------------------------------------------------
# §3.1/3.2/3.8: pointer-chase latency vs. working set
# ---------------------------------------------------------------------------
def probe_pointer_chase(
    sizes_bytes: Sequence[int] = (),
    steps: int = 1 << 16,
    seed: int = 0,
    backend: Optional[str] = None,
) -> ProbeResult:
    """Dependent-load latency (ns/load) vs. footprint.

    The ``xla`` backend times a jitted fori_loop walk (minimal dispatch
    overhead); the Pallas backends time the kernel (identical semantics).
    """
    be = _pick_backend(backend)
    if not sizes_bytes:
        sizes_bytes = [1 << p for p in range(12, 27)]  # 4 KiB .. 64 MiB
    lats = []
    for sz in sizes_bytes:
        n = max(sz // 4, 8)
        perm = jnp.asarray(pc.single_cycle_permutation(n, seed))
        fn = api.pchase.bound(perm, steps, backend=be)
        t = time_fn(fn, perm, steps, warmup=2, reps=5)
        lats.append(t.min_s / steps * 1e9)
    return ProbeResult(
        "pointer_chase", tuple(int(s) for s in sizes_bytes), tuple(lats), "ns/load",
        {"steps": steps, "backend": be},
    )


def analyze_pointer_chase(res: ProbeResult, rel_jump: float = 0.35):
    """Plateau segmentation -> detected (latency, capacity) per level."""
    plats = pc.detect_plateaus(np.array(res.x), np.array(res.y), rel_jump)
    return plats, pc.capacities_from_plateaus(plats)


# ---------------------------------------------------------------------------
# §3.2/3.7: streaming bandwidth vs. footprint and block shape
# ---------------------------------------------------------------------------
def probe_stream_bandwidth(
    footprints: Sequence[int] = (),
    block_cols: int = 512,
    # interpret-mode grids are Python loops: XLA default for wall-clock
    backend: Optional[str] = None,
) -> ProbeResult:
    be = _pick_backend(backend)
    if not footprints:
        footprints = [1 << p for p in range(16, 28)]  # 64 KiB .. 256 MiB
    bws = []
    for sz in footprints:
        cols = block_cols
        rows = max(sz // (4 * cols), 8)
        rows -= rows % 8
        x = jnp.ones((rows, cols), jnp.float32)
        fn = api.stream_reduce.bound(x, block_rows=8, block_cols=cols, backend=be)
        t = time_fn(fn, x, warmup=2, reps=5)
        bws.append(x.size * 4 / t.min_s / 1e9)
    return ProbeResult(
        "stream_bandwidth", tuple(int(f) for f in footprints), tuple(bws), "GB/s",
        {"block_cols": block_cols, "backend": be},
    )


def probe_block_shape_bandwidth(
    footprint: int = 1 << 20,
    col_widths: Sequence[int] = (128, 256, 512, 1024, 2048),
    backend: Optional[str] = None,
) -> ProbeResult:
    """The Ch.1 axpy experiment: bandwidth vs. access width (VMEM tile cols)."""
    be = _pick_backend(backend, default="pallas")
    bws = []
    for cols in col_widths:
        rows = max(footprint // (4 * cols), 8)
        rows -= rows % 8
        x = jnp.ones((rows, cols), jnp.float32)
        y = jnp.ones((rows, cols), jnp.float32)
        fn = api.axpy.bound(x, y, 2.0, block_rows=8, block_cols=cols, backend=be)
        t = time_fn(fn, x, y, 2.0, warmup=2, reps=5)
        bws.append(3 * x.size * 4 / t.min_s / 1e9)  # 2 reads + 1 write
    return ProbeResult(
        "block_shape_bandwidth", tuple(int(c) for c in col_widths), tuple(bws), "GB/s",
        {"footprint": footprint, "backend": be},
    )


# ---------------------------------------------------------------------------
# §4.1: dependent-issue op latency table (Table 4.1 analogue)
# ---------------------------------------------------------------------------
_OP_TABLE: list[tuple[str, Callable, str]] = [
    ("add.f32", lambda x: x + 1.000001, "f32"),
    ("mul.f32", lambda x: x * 1.000001, "f32"),
    ("fma.f32", lambda x: x * 1.000001 + 1e-7, "f32"),
    ("max.f32", lambda x: jnp.maximum(x, 0.5), "f32"),
    ("rsqrt.f32", lambda x: jax.lax.rsqrt(jnp.abs(x) + 1.0), "f32"),
    ("exp.f32", lambda x: jnp.exp(x * 1e-8), "f32"),
    ("tanh.f32", lambda x: jnp.tanh(x * 0.999), "f32"),
    ("log.f32", lambda x: jnp.log(jnp.abs(x) + 1.0), "f32"),
    ("add.s32", lambda x: x + 1, "s32"),
    ("mul.s32", lambda x: x * 1, "s32"),
    ("shift.s32", lambda x: (x << 1) >> 1, "s32"),
]


def probe_op_latency(chain: int = 4096, width: int = 128, reps: int = 5) -> ProbeResult:
    """Dependent-chain latency per op (ns): a ``chain``-long fori_loop where
    each iteration consumes the previous result — the paper's fixed-latency
    measurement design (§4.1), with the loop overhead subtracted via a
    move-only baseline chain."""
    names, lats = [], []

    def run_chain(op, kind):
        @jax.jit
        def fn(x0):
            def body(_, x):
                return op(x)

            return jax.lax.fori_loop(0, chain, body, x0)

        if kind == "s32":
            x0 = jnp.arange(width, dtype=jnp.int32)
        else:
            x0 = jnp.linspace(0.5, 1.5, width, dtype=jnp.float32)
        t = time_fn(fn, x0, warmup=2, reps=reps)
        return t.min_s / chain * 1e9

    base = run_chain(lambda x: x, "f32")  # loop overhead baseline
    for name, op, kind in _OP_TABLE:
        names.append(name)
        lats.append(max(run_chain(op, kind) - base, 0.0))
    return ProbeResult(
        "op_latency", tuple(names), tuple(lats), "ns/op", {"chain": chain, "base_ns": base}
    )


# ---------------------------------------------------------------------------
# §4.2: scatter-add contention (atomics analogue, Fig 4.1 scenarios)
# ---------------------------------------------------------------------------
def probe_scatter_contention(
    n_updates: int = 1 << 16, collisions: Sequence[int] = (1, 2, 4, 8, 16, 32)
) -> ProbeResult:
    """Throughput (updates/s) of scatter-add with R threads per address."""
    rates = []
    for r in collisions:
        tgt = jnp.zeros((max(n_updates // r, 1),), jnp.float32)
        idx = jnp.repeat(jnp.arange(max(n_updates // r, 1), dtype=jnp.int32), r)[:n_updates]
        val = jnp.ones((n_updates,), jnp.float32)

        @jax.jit
        def fn(t, i, v):
            return t.at[i].add(v)

        tm = time_fn(fn, tgt, idx, val, warmup=2, reps=5)
        rates.append(n_updates / tm.min_s / 1e6)
    return ProbeResult(
        "scatter_contention", tuple(int(c) for c in collisions), tuple(rates),
        "Mupdates/s", {"n_updates": n_updates},
    )


# ---------------------------------------------------------------------------
# §4.4: matmul arithmetic throughput (Fig 4.2 / Table 4.3 analogue)
# ---------------------------------------------------------------------------
def probe_matmul_throughput(
    sizes: Sequence[int] = (256, 512, 1024, 2048),
    dtypes: Sequence[str] = ("float32",),
    backend: Optional[str] = None,
) -> ProbeResult:
    be = _pick_backend(backend)
    recs, keys = [], []
    int8_rows = []
    for dt in dtypes:
        jdt = {"float32": jnp.float32, "bfloat16": jnp.bfloat16, "int8": jnp.int8}[dt]
        for n in sizes:
            a = jnp.ones((n, n), jdt)
            b = jnp.ones((n, n), jdt)
            if jdt == jnp.int8:
                # int8 has no Pallas/oracle path (int32-accumulating
                # dot_general only); always XLA — tagged so a backend
                # comparison can't mistake these rows for the kernel path
                fn = jax.jit(lambda a, b: jax.lax.dot_general(
                    a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32))
                int8_rows.append(f"{dt}:{n}")
            else:
                # tiles default to the clamped 128 MXU alignment; a
                # kernel_policy(autotune=True) in scope overrides them
                fn = api.matmul.bound(a, b, backend=be)
            t = time_fn(fn, a, b, warmup=2, reps=5)
            keys.append(f"{dt}:{n}")
            recs.append(2 * n**3 / t.min_s / 1e9)
    meta = {"backend": be}
    if int8_rows:
        meta["xla_only_rows"] = tuple(int8_rows)
    return ProbeResult("matmul_throughput", tuple(keys), tuple(recs), "GFLOP/s", meta)


# ---------------------------------------------------------------------------
# Tab 2.1 analogue: grid occupancy (programs vs. core count)
# ---------------------------------------------------------------------------
def probe_grid_occupancy(
    rows_per_program: int = 256,
    programs: Sequence[int] = (1, 2, 3, 4, 6, 8),
    backend: Optional[str] = None,
) -> ProbeResult:
    """Throughput vs. grid size.  On TPU, grid cells execute sequentially per
    core; throughput/program is flat (unlike the Turing scheduler-collision
    table) — the probe demonstrates/verifies that contrast."""
    be = _pick_backend(backend, default="pallas")
    rates = []
    for g in programs:
        x = jnp.ones((g * rows_per_program, 512), jnp.float32)
        fn = api.stream_reduce.bound(x, block_rows=rows_per_program, block_cols=512,
                                     backend=be)
        t = time_fn(fn, x, warmup=2, reps=5)
        rates.append(x.size * 4 / t.min_s / 1e9)
    return ProbeResult(
        "grid_occupancy", tuple(int(p) for p in programs), tuple(rates), "GB/s",
        {"rows_per_program": rows_per_program, "backend": be},
    )
