"""The paper's primary contribution, TPU-native: a microbenchmark engine
(pointer-chase, bandwidth, op-latency, contention, GEMM, occupancy probes)
that distills hardware behavior into a ``HardwareModel`` consumed by the
roofline analyzer, the tile autotuner, and the straggler detector.
"""
from .hwmodel import TPU_V5E, T4_PAPER, HardwareModel, MemoryLevel
from .throttle import T4_THROTTLE, V5E_THROTTLE, ThrottleParams, simulate, steady_state_clock

__all__ = [
    "TPU_V5E",
    "T4_PAPER",
    "HardwareModel",
    "MemoryLevel",
    "T4_THROTTLE",
    "V5E_THROTTLE",
    "ThrottleParams",
    "simulate",
    "steady_state_clock",
]
