"""Back-compat shim — the hardware model moved to :mod:`repro.hw`.

``HardwareModel`` grew from two hard-coded presets into the multi-generation
spec database in ``repro.hw`` (P4/T4/V100 from the paper, A100/H100/B200
from the sequel dissections, TPU v5e).  This module keeps the historical
import path alive; new code should use ``repro.hw`` directly:

    import repro.hw as hw
    hw.get("T4").peak("int8")
"""
from repro.hw import (  # noqa: F401  (re-exported legacy surface)
    HardwareModel,
    MemoryLevel,
    T4_PAPER,
    TPU_V5E,
    UnknownDtypeError,
    fit_from_probes,
)
from repro.hw.specs import TPU_LIKE_DTYPES_T4  # noqa: F401

__all__ = [
    "HardwareModel",
    "MemoryLevel",
    "T4_PAPER",
    "TPU_LIKE_DTYPES_T4",
    "TPU_V5E",
    "UnknownDtypeError",
    "fit_from_probes",
]
