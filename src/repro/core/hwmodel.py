"""HardwareModel — the machine-readable analogue of the paper's Table 3.1.

The paper's meta-contribution is a *quantitative hardware model distilled
from microbenchmarks*.  ``HardwareModel`` is that object: every consumer
(roofline, autotuner, straggler detector, modeled benchmarks) reads hardware
facts from here, never from scattered constants.

Presets:
  - ``TPU_V5E``   the dry-run/roofline target (per the assignment constants:
                  197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI).
  - ``T4_PAPER``  the paper's own T4 findings (Table 3.1 / 4.3) — used to
                  validate our throttle model and benchmark structure against
                  the paper's published numbers.
  - ``fit_from_probes`` builds one from dissect.py probe data (measure mode).
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass


@dataclass(frozen=True)
class MemoryLevel:
    name: str
    size_bytes: int  # capacity (0 = unbounded, e.g. DRAM/HBM)
    latency_ns: float  # dependent-load latency
    bandwidth_Bps: float  # sustained streaming bandwidth
    line_bytes: int = 0
    shared: bool = False  # shared across cores/SMs or private


@dataclass(frozen=True)
class HardwareModel:
    name: str
    # compute
    peak_flops: dict  # dtype name -> FLOP/s (per chip)
    clock_hz: float
    num_cores: int
    # memory
    levels: tuple  # tuple[MemoryLevel, ...] fastest-first
    main_memory_Bps: float
    main_memory_bytes: int
    # on-chip staging (VMEM on TPU, smem+L1 on GPU)
    staging_bytes: int
    staging_Bps: float
    # interconnect
    ici_Bps_per_link: float = 0.0
    ici_links: int = 0
    dci_Bps: float = 0.0  # cross-pod (data-center interconnect)
    # power/thermal envelope (throttle model inputs, paper §4.5)
    power_limit_w: float = 0.0
    max_temp_c: float = 0.0
    idle_power_w: float = 0.0

    def peak(self, dtype: str) -> float:
        if dtype in self.peak_flops:
            return self.peak_flops[dtype]
        raise KeyError(f"{self.name}: no peak for {dtype!r}")

    def mxu_align(self) -> int:
        return 128

    def to_json(self) -> str:
        d = asdict(self)
        d["levels"] = [asdict(l) for l in self.levels]
        return json.dumps(d, indent=2)

    @staticmethod
    def from_json(s: str) -> "HardwareModel":
        d = json.loads(s)
        d["levels"] = tuple(MemoryLevel(**l) for l in d["levels"])
        d["peak_flops"] = dict(d["peak_flops"])
        return HardwareModel(**d)


# ---------------------------------------------------------------------------
# TPU v5e — the roofline/dry-run target
# ---------------------------------------------------------------------------
TPU_V5E = HardwareModel(
    name="tpu-v5e",
    peak_flops={
        "bfloat16": 197e12,
        "float32": 49.25e12,  # MXU f32 path ~ bf16/4
        "int8": 394e12,
    },
    clock_hz=1.70e9,  # ~940 MHz x2 issue equivalent; per-chip effective
    num_cores=1,  # v5e is single-TensorCore per chip
    levels=(
        MemoryLevel("vreg", 512 * 1024, 0.6, 0.0, line_bytes=4 * 128),
        MemoryLevel("vmem", 128 * 1024 * 1024, 12.0, 3.3e12, line_bytes=4 * 8 * 128),
        MemoryLevel("hbm", 16 * 1024**3, 450.0, 819e9, line_bytes=512, shared=True),
    ),
    main_memory_Bps=819e9,
    main_memory_bytes=16 * 1024**3,
    staging_bytes=128 * 1024 * 1024,
    staging_Bps=3.3e12,
    ici_Bps_per_link=50e9,  # per the assignment: ~50 GB/s/link
    ici_links=4,  # 2D torus
    dci_Bps=25e9,  # cross-pod effective per-chip share (assumption, see DESIGN)
    power_limit_w=170.0,
    max_temp_c=90.0,
    idle_power_w=60.0,
)


# ---------------------------------------------------------------------------
# The paper's T4 (Table 3.1 / 4.3, converted to SI) — validation anchor
# ---------------------------------------------------------------------------
_T4_CLK = 1.59e9  # 1590 MHz max graphics clock

TPU_LIKE_DTYPES_T4 = {
    # paper Table 4.3 measured matmul throughput (not theoretical peaks)
    "float64": 253e9,
    "float32": 7.174e12,
    "float16": 41.616e12,
    "int8": 74.934e12,
    "int4": 114.384e12,
    "int1": 552.230e12,
}

T4_PAPER = HardwareModel(
    name="nvidia-t4-paper",
    peak_flops=dict(TPU_LIKE_DTYPES_T4),
    clock_hz=_T4_CLK,
    num_cores=40,  # SMs
    levels=(
        # latency_ns = cycles / 1.59 GHz; sizes from Table 3.1
        MemoryLevel("L1", 64 * 1024, 32 / _T4_CLK * 1e9, 58.8 * 40 * _T4_CLK, 32),
        MemoryLevel("L2", 4096 * 1024, 188 / _T4_CLK * 1e9, 1.27e12, 64, shared=True),
        MemoryLevel("global", 15 * 1024**3, 616 / _T4_CLK * 1e9, 220e9, 512, shared=True),
    ),
    main_memory_Bps=220e9,  # measured (theoretical 320; ratio 68.8%, Tab 3.1)
    main_memory_bytes=15 * 1024**3,
    staging_bytes=64 * 1024 * 40,  # shared memory per chip
    staging_Bps=3.662e12,  # Tab 3.1 actual shared bw
    power_limit_w=70.0,
    max_temp_c=85.0,
    idle_power_w=20.0,
)


# ---------------------------------------------------------------------------
def fit_from_probes(
    name: str,
    plateau_levels: list,  # [(latency_ns, size_bytes_boundary_or_None), ...]
    stream_Bps: float,
    matmul_flops: dict,
    clock_hz: float = 0.0,
) -> HardwareModel:
    """Build a HardwareModel from dissect.py probe output (measure mode)."""
    levels = []
    for i, (lat, size) in enumerate(plateau_levels):
        levels.append(
            MemoryLevel(
                name=f"level{i}",
                size_bytes=int(size) if size else 0,
                latency_ns=float(lat),
                bandwidth_Bps=stream_Bps,
            )
        )
    main = levels[-1] if levels else MemoryLevel("main", 0, 100.0, stream_Bps)
    return HardwareModel(
        name=name,
        peak_flops=dict(matmul_flops),
        clock_hz=clock_hz,
        num_cores=1,
        levels=tuple(levels),
        main_memory_Bps=stream_Bps,
        main_memory_bytes=0,
        staging_bytes=levels[0].size_bytes if levels else 0,
        staging_Bps=stream_Bps,
    )
