"""dissect() — orchestrates the probe suite into a fitted HardwareModel,
the executable version of the paper's whole Chapter 3 + 4 workflow.

measure mode: runs every probe on the live backend (CPU container: the
fitted model describes the host — end-to-end methodology validation, since
the host's real L1/L2/L3 plateaus must emerge from our pointer-chase).

model mode: evaluates the same probe grid analytically against any part in
the :mod:`repro.hw` spec database (``hw=`` takes a name like ``"T4"`` or a
``HardwareModel``; default TPU v5e — the numbers EXPERIMENTS.md reports for
the target).  :func:`dissect_compare` runs model mode across several
generations and emits the paper's T4-vs-P4-vs-V100 comparison as records.

Measure mode registers the fitted model into the same database (via
``fit_from_probes``), so a dissected host is immediately comparable:
``repro.hw.compare("measured-host", "T4")``.
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from pathlib import Path
from typing import Iterable, Optional, Union

from repro.hw import HardwareModel, resolve as _resolve_hw
from repro.hw import compare as _hw_compare

from . import probes
from .hwmodel import fit_from_probes
from .serialization import SCHEMA_VERSION, EnvFingerprint, probe_to_dict


@dataclass
class DissectReport:
    mode: str
    hardware: HardwareModel
    probe_results: dict  # name -> ProbeResult-as-dict (bench.schema probe layout)
    detected_levels: list  # [(latency_ns, capacity_bytes|None)]

    def to_json(self) -> str:
        """Serialize on the shared bench schema (version + env fingerprint),
        so dissect reports and bench results are one JSON dialect."""
        from dataclasses import asdict

        return json.dumps(
            {
                "schema_version": SCHEMA_VERSION,
                "kind": "dissect_report",
                "mode": self.mode,
                "env": asdict(EnvFingerprint.capture()),
                "hardware": json.loads(self.hardware.to_json()),
                "probes": self.probe_results,
                "detected_levels": self.detected_levels,
            },
            indent=2,
        )


def dissect_measure(
    quick: bool = True, out_path: Optional[str] = None
) -> DissectReport:
    """Run the full probe suite on the live backend and fit a HardwareModel."""
    sizes = [1 << p for p in range(12, 25 if quick else 28)]
    steps = 1 << (14 if quick else 17)
    res_pc = probes.probe_pointer_chase(sizes, steps=steps)
    plats, caps = probes.analyze_pointer_chase(res_pc)
    detected = [
        (p.latency, caps[i] if i < len(caps) else None) for i, p in enumerate(plats)
    ]

    res_bw = probes.probe_stream_bandwidth(
        [1 << p for p in range(18, 24 if quick else 28)]
    )
    stream_bps = max(res_bw.y) * 1e9

    res_mm = probes.probe_matmul_throughput(
        sizes=(256, 512) if quick else (256, 512, 1024, 2048)
    )
    flops = {"float32": max(res_mm.y) * 1e9}

    res_ops = probes.probe_op_latency(chain=1024 if quick else 8192)

    hw = fit_from_probes(
        name="measured-host",
        plateau_levels=detected,
        stream_Bps=stream_bps,
        matmul_flops=flops,
    )
    report = DissectReport(
        mode="measure",
        hardware=hw,
        probe_results={
            r.name: probe_to_dict(r) for r in (res_pc, res_bw, res_mm, res_ops)
        },
        detected_levels=detected,
    )
    if out_path:
        Path(out_path).write_text(report.to_json())
    return report


# ---------------------------------------------------------------------------
# model mode: analytic TPU v5e predictions over the same probe grid
# ---------------------------------------------------------------------------
def _predict_pchase(hw: HardwareModel, sizes) -> list[float]:
    lat = []
    for s in sizes:
        for lvl in hw.levels:
            if lvl.size_bytes == 0 or s <= lvl.size_bytes:
                lat.append(lvl.latency_ns)
                break
        else:
            lat.append(hw.levels[-1].latency_ns)
    return lat


def _predict_stream(hw: HardwareModel, sizes) -> list[float]:
    out = []
    for s in sizes:
        for lvl in hw.levels:
            if lvl.bandwidth_Bps and (lvl.size_bytes == 0 or s <= lvl.size_bytes):
                out.append(lvl.bandwidth_Bps / 1e9)
                break
        else:
            out.append(hw.main_memory_Bps / 1e9)
    return out


def _predict_matmul(hw: HardwareModel, sizes, dtype="bfloat16") -> list[float]:
    peak = hw.peak(dtype, fallback=("float16", "float32"))
    eb = {"float64": 8, "float32": 4, "int8": 1}.get(dtype, 2)
    out = []
    for n in sizes:
        flops = 2 * n**3
        t_compute = flops / peak
        t_mem = 3 * n * n * eb / hw.main_memory_Bps
        out.append(flops / max(t_compute, t_mem) / 1e9)
    return out


def dissect_model(
    hw: Union[str, HardwareModel] = "tpu-v5e",
    out_path: Optional[str] = None,
    dtype: str = "bfloat16",
) -> DissectReport:
    hw = _resolve_hw(hw)
    sizes = [1 << p for p in range(12, 31)]
    bw_sizes = [1 << p for p in range(18, 31)]
    mm_sizes = (256, 512, 1024, 2048, 4096, 8192)
    report = DissectReport(
        mode="model",
        hardware=hw,
        probe_results={
            "pointer_chase": {
                "x": sizes, "y": _predict_pchase(hw, sizes), "unit": "ns/load", "meta": {},
            },
            "stream_bandwidth": {
                "x": bw_sizes, "y": _predict_stream(hw, bw_sizes), "unit": "GB/s", "meta": {},
            },
            "matmul_throughput": {
                "x": [f"{dtype}:{n}" for n in mm_sizes],
                "y": _predict_matmul(hw, mm_sizes, dtype), "unit": "GFLOP/s", "meta": {},
            },
        },
        detected_levels=[(l.latency_ns, l.size_bytes or None) for l in hw.levels],
    )
    if out_path:
        Path(out_path).write_text(report.to_json())
    return report


def dissect_compare(
    hws: Iterable[Union[str, HardwareModel]] = ("P4", "T4", "V100"),
    baseline: Union[str, HardwareModel] = "T4",
    dtypes: Optional[Iterable[str]] = None,
) -> dict:
    """Model-mode dissection across generations — the paper's comparison
    tables as one record.

    Runs :func:`dissect_model` for every part and pairs each against
    ``baseline`` with :func:`repro.hw.compare`.  The default grid is the
    paper's own column set (P4/T4/V100); pass successors ("A100", "H100",
    "B200") to extend the table the way the sequel dissections do.
    """
    base = _resolve_hw(baseline)
    parts = [_resolve_hw(h) for h in hws]
    return {
        "baseline": base.name,
        "parts": [h.name for h in parts],
        "reports": {h.name: dissect_model(h).probe_results for h in parts},
        "comparisons": {
            h.name: _hw_compare(h, base, dtypes=dtypes)
            for h in parts
            if h.name != base.name
        },
    }
