"""DBRX-132B — 40L d_model=6144 48H (GQA kv=8) per-expert d_ff=10752,
vocab 100352, MoE 16 experts top-4 (fine-grained).  [hf:databricks/dbrx-base]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,
    vocab_size=100352,
    n_experts=16,
    experts_per_token=4,
    mlp_variant="swiglu",
    rope_theta=500_000.0,
    moe_shard="expert",  # 16 experts / 16-way model axis -> 1 expert per device
    # 132B bf16 = 264 GB exceeds a 16-chip TP replica's HBM; serving shards
    # weights over the data axis too (per-layer all-gather, FSDP-style)
    serve_param_fsdp=True,
)
