"""Zamba2-7B — 81L d_model=3584 32H d_ff=14336 vocab 32000, ssm_state=64.
Mamba2 backbone + one SHARED attention+MLP block applied periodically.
[arXiv:2411.15242]

Layout here: 27 macro-blocks x 3 Mamba2 layers (= 81 SSM layers, scanned),
with the shared attention block invoked every 2nd macro-block (14 calls).
The shared block takes concat(hidden, residual_embedding) = 2*d_model input,
per the Zamba design.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    macro_size=3,
    attn_every_k_macro=2,
    mlp_variant="gelu",
)
