"""Architecture config registry: ``get_config(name)`` / ``list_configs()``."""
from __future__ import annotations

from .base import ModelConfig, ShapeConfig, SHAPES

from .olmoe_1b_7b import CONFIG as olmoe_1b_7b
from .dbrx_132b import CONFIG as dbrx_132b
from .xlstm_1_3b import CONFIG as xlstm_1_3b
from .whisper_base import CONFIG as whisper_base
from .internvl2_76b import CONFIG as internvl2_76b
from .gemma_2b import CONFIG as gemma_2b
from .qwen2_5_14b import CONFIG as qwen2_5_14b
from .minitron_8b import CONFIG as minitron_8b
from .yi_34b import CONFIG as yi_34b
from .zamba2_7b import CONFIG as zamba2_7b

CONFIGS: dict[str, ModelConfig] = {
    c.name: c
    for c in [
        olmoe_1b_7b,
        dbrx_132b,
        xlstm_1_3b,
        whisper_base,
        internvl2_76b,
        gemma_2b,
        qwen2_5_14b,
        minitron_8b,
        yi_34b,
        zamba2_7b,
    ]
}


def get_config(name: str) -> ModelConfig:
    if name not in CONFIGS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(CONFIGS)}")
    return CONFIGS[name]


def list_configs() -> list[str]:
    return sorted(CONFIGS)


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}")
    return SHAPES[name]


def all_cells() -> list[tuple[ModelConfig, ShapeConfig]]:
    """Every (arch, shape) dry-run cell, including skip-eligible ones."""
    return [(c, s) for c in CONFIGS.values() for s in SHAPES.values()]


def runnable_cells() -> list[tuple[ModelConfig, ShapeConfig]]:
    return [(c, s) for c, s in all_cells() if c.supports_shape(s)]


__all__ = [
    "ModelConfig",
    "ShapeConfig",
    "SHAPES",
    "CONFIGS",
    "get_config",
    "get_shape",
    "list_configs",
    "all_cells",
    "runnable_cells",
]
