"""InternVL2-76B — 80L d_model=8192 64H (GQA kv=8) d_ff=28672, vocab 128256.
InternViT frontend is a STUB: ``input_specs`` provides 256 precomputed patch
embeddings per image, prepended to the text sequence.  [arXiv:2404.16821]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    mlp_variant="swiglu",
    rope_theta=500_000.0,
    frontend="vision",
    frontend_len=256,
    train_microbatches=2,
    # §Perf hillclimb: 32k-prefill memory term minimized at KV-chunk 256
    # (score-tile traffic grows with chunk faster than q-pass savings)
    attn_chunk=256,
)
