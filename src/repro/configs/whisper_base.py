"""Whisper-base — enc-dec, 6L encoder + 6L decoder, d_model=512 8H,
d_ff=2048, vocab 51865.  Conv/mel frontend is a STUB: ``input_specs``
provides precomputed frame embeddings (1500, d_model).  [arXiv:2212.04356]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="encdec",
    n_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    n_enc_layers=6,
    n_dec_layers=6,
    mlp_variant="gelu",
    frontend="audio",
    frontend_len=1500,  # 30 s of mel frames after the conv stub
    qkv_bias=True,
)
