"""OLMoE-1B-7B — 16L d_model=2048 16H (GQA kv=16) per-expert d_ff=1024,
vocab 50304, MoE 64 experts top-8.  [arXiv:2409.02060; hf]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab_size=50304,
    n_experts=64,
    experts_per_token=8,
    mlp_variant="swiglu",
    rope_theta=10_000.0,
    # 64 experts / 16-way model axis -> 4 experts per device (EP); per-expert
    # d_ff=1024 is too narrow to TP-shard (1024/16=64 < 128 lanes), so EP only.
    moe_shard="expert",
)
