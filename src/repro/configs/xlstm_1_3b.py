"""xLSTM-1.3B — 48L d_model=2048 4H, vocab 50304, sLSTM + mLSTM blocks.
[arXiv:2405.04517]

d_ff=0 per the assignment: xLSTM blocks carry their own (gated) up/down
projections instead of a separate FFN.  Layer pattern: 6 macro-blocks of
(7 mLSTM + 1 sLSTM) = 48 layers (the paper's ~7:1 ratio).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    macro_size=8,  # scan unit: 7 mLSTM + 1 sLSTM
    xlstm_mlstm_per_macro=7,
    xlstm_slstm_per_macro=1,
    ssm_chunk=256,
    tie_embeddings=False,
)
