"""Config dataclasses: architectures and input-shape suites.

Every assigned architecture is a ``ModelConfig``; every assigned input shape
is a ``ShapeConfig``.  ``(arch, shape)`` pairs form the dry-run/roofline cells.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclass(frozen=True)
class ModelConfig:
    """Architecture hyperparameters (superset across the 10 assigned archs)."""

    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads

    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    moe_aux_coef: float = 0.01

    # --- MLP / attention details ---
    mlp_variant: str = "swiglu"  # swiglu | geglu | gelu
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # --- SSM / hybrid (mamba2, zamba2) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 256
    attn_every_k_macro: int = 0  # zamba2: shared attn block every k macro-blocks
    macro_size: int = 1  # layers per macro block (scan unit)

    # --- xLSTM ---
    xlstm_slstm_per_macro: int = 0  # sLSTM layers appended per macro block
    xlstm_mlstm_per_macro: int = 0

    # --- encoder-decoder (whisper) ---
    n_enc_layers: int = 0
    n_dec_layers: int = 0

    # --- modality frontend stubs ---
    frontend: Optional[str] = None  # "audio" | "vision"
    frontend_len: int = 0  # precomputed embeddings prepended / cross-attended

    # --- numerics / execution ---
    dtype: str = "bfloat16"  # compute dtype
    param_dtype: str = "float32"
    attn_chunk: int = 1024  # blockwise-attention KV chunk
    attn_impl: str = "blockwise"  # blockwise | naive | pallas
    # xla (chunked-scan in jnp) | pallas (kernels.api ssm_scan; stateful
    # calls — decode prefill with h0 / return_state — stay on the jnp scan)
    ssm_impl: str = "xla"
    remat: bool = True  # checkpoint each layer block in training
    remat_policy: str = "full"  # full (recompute all) | dots (save matmul outputs)
    zero_stage: int = 3  # 0: none, 1: opt state, 2: +grads, 3: +fp32 params (FSDP)
    scan_layers: bool = True

    train_microbatches: int = 1  # gradient-accumulation splits of the global batch

    # --- distribution knobs (overridable per experiment) ---
    moe_shard: str = "expert"  # expert (EP on model axis) | ffn (TP inside expert)
    serve_param_fsdp: bool = False  # serving weights also sharded over data
    shard_kv_seq_decode: bool = False  # flash-decoding style KV-seq sharding
    logits_parallel: bool = True  # keep logits vocab-sharded through the loss

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.n_heads, 1))

    # ------------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to 128 (TP-divisible, lane-aligned). Pad logits
        are masked to -inf; targets never index the pad region."""
        return -(-self.vocab_size // 128) * 128

    @property
    def is_subquadratic(self) -> bool:
        """Whether the arch can run the long_500k cell (SSM / hybrid)."""
        return self.family in ("ssm", "hybrid")

    def max_useful_tp(self, limit: int = 1 << 30) -> int:
        """Largest tensor-parallel degree (<= ``limit``) that actually
        shards attention: it must divide both ``n_heads`` (wq/wo) and
        ``n_kv_heads`` (wk/wv and the KV cache).  Beyond this the
        divisibility-guarded sharding rules leave those weights replicated,
        so extra devices add communication without splitting the work —
        ``ClusterConfig.tp`` should not exceed it (see docs/scaling.md)."""
        tp = 1
        for d in range(1, min(self.n_heads, limit) + 1):
            if self.n_heads % d == 0 and self.n_kv_heads % d == 0:
                tp = d
        return tp

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs decode (whisper is enc-dec, not enc-only)

    def supports_shape(self, shape: ShapeConfig) -> bool:
        if shape.name == "long_500k":
            return self.is_subquadratic
        return True

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND roofline checks)."""
        d, hd = self.d_model, self.head_dim
        qkvo = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
        if self.qkv_bias:
            qkvo += (self.n_heads + 2 * self.n_kv_heads) * hd
        if self.mlp_variant in ("swiglu", "geglu"):
            mlp = 3 * d * self.d_ff
        else:
            mlp = 2 * d * self.d_ff
        emb = self.vocab_size * d
        head = 0 if self.tie_embeddings else self.vocab_size * d
        norms = 2 * d  # per layer

        if self.family == "moe":
            moe = self.n_experts * 3 * d * self.d_ff + d * self.n_experts
            per_layer = qkvo + moe + norms
            return self.n_layers * per_layer + emb + head + d
        if self.family == "ssm":  # xlstm
            return self.n_layers * self._xlstm_block_params() + emb + head + d
        if self.family == "hybrid":  # zamba2
            mamba = self.n_layers * self._mamba_block_params()
            shared_attn = qkvo * 4 + mlp  # shared block takes concat(2d) input
            return mamba + shared_attn + emb + head + d
        if self.family == "encdec":
            enc = self.n_enc_layers * (qkvo + mlp + 2 * norms)
            dec = self.n_dec_layers * (2 * qkvo + mlp + 3 * norms)
            return enc + dec + emb + head + 2 * d
        # dense / vlm backbone
        per_layer = qkvo + mlp + norms
        return self.n_layers * per_layer + emb + head + d

    def _mamba_block_params(self) -> int:
        d = self.d_model
        d_in = self.ssm_expand * d
        nheads = d_in // self.ssm_head_dim
        in_proj = d * (2 * d_in + 2 * self.ssm_state + nheads)
        conv = (d_in + 2 * self.ssm_state) * self.ssm_conv_width
        out_proj = d_in * d
        return in_proj + conv + out_proj + 2 * nheads + d

    def _xlstm_block_params(self) -> int:
        d = self.d_model
        hd = d // self.n_heads
        # mLSTM block: qkv + gates + out + ln
        qkv = 3 * d * d
        gates = 2 * d * self.n_heads  # i,f per head
        up = 2 * d * 2 * d  # up-projection pair (gated)
        down = 2 * d * d
        return qkv + gates + up + down + 2 * d

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed experts)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        hd = self.head_dim
        qkvo = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
        act_moe = self.experts_per_token * 3 * d * self.d_ff + d * self.n_experts
        per_layer = qkvo + act_moe + 2 * d
        emb = self.vocab_size * d
        head = 0 if self.tie_embeddings else self.vocab_size * d
        return self.n_layers * per_layer + emb + head + d

    # ------------------------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw: dict = dict(
            name=self.name + "-smoke",
            n_layers=min(self.n_layers, 2),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads > 1 else 1,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            head_dim=16,
            attn_chunk=32,
            ssm_state=16 if self.ssm_state else 0,
            ssm_head_dim=16,
            ssm_chunk=16,
            frontend_len=8 if self.frontend_len else 0,
            n_enc_layers=min(self.n_enc_layers, 2),
            n_dec_layers=min(self.n_dec_layers, 2),
            n_experts=min(self.n_experts, 4),
            experts_per_token=min(self.experts_per_token, 2),
            macro_size=min(self.macro_size, 2),
            xlstm_mlstm_per_macro=min(self.xlstm_mlstm_per_macro, 1),
            xlstm_slstm_per_macro=min(self.xlstm_slstm_per_macro, 1),
            attn_every_k_macro=min(self.attn_every_k_macro, 2),
            dtype="float32",
            param_dtype="float32",
            remat=False,
        )
        if self.family == "ssm":
            kw["n_layers"] = 4
        elif self.family == "hybrid":
            kw["n_layers"] = 5  # 1 super-unit (4 layers) + 1 tail layer
        return dataclasses.replace(self, **kw)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)
