"""Sharded serving cluster: replica router over tensor-parallel engines.

Two axes of scale-out, composed (see ``docs/scaling.md`` for the topology
guide):

- **Tensor parallel (inside one engine)** — each replica's
  :class:`~repro.serve.engine.ServeEngine` gets its own
  :class:`jax.sharding.Mesh` with a ``model`` axis of ``tp`` devices; the
  ``dist.sharding`` rules shard the params (head-sharded wq/wk/wv,
  row-parallel wo, vocab-sharded embed/lm_head) and the KV/page cache
  (KV-head dim), and the compiled decode/prefill steps trace inside
  ``activation_sharding(mesh)``.  Sharded decode is token-identical to the
  single-device engine.
- **Data parallel (across engines)** — :class:`ClusterRouter` owns
  ``n_replicas`` engines, each on its own device subset, behind the same
  ``submit() -> Session`` API as a single engine.  A pluggable
  :class:`RouterPolicy` picks the replica per request (least-loaded by
  default; round-robin; prefix-affinity that follows registered shared
  prefixes), per-replica :class:`~repro.serve.metrics.EngineMetrics` roll up
  into one :class:`~repro.serve.metrics.ClusterMetrics` summary, and
  :meth:`ClusterRouter.fail_replica` simulates a replica loss: the failed
  engine drains, and every in-flight/queued session re-queues onto the
  survivors with its generated output intact (the recompute-preemption
  invariant makes the resume token-exact).

Replicas are built lazily on first use, so a model family the engine cannot
serve surfaces its typed :class:`~repro.serve.engine.UnsupportedFamilyError`
at ``submit()`` time — the first call a caller actually makes — rather than
at router construction.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, replace
from typing import Optional, Protocol, Sequence, runtime_checkable

import jax
import numpy as np
from jax.sharding import Mesh

from repro.models.api import ModelApi

from .engine import EngineConfig, ServeEngine
from .metrics import ClusterMetrics
from .paging import SharedPrefix
from .session import Session


# ---------------------------------------------------------------------------
# device topology
# ---------------------------------------------------------------------------
def replica_meshes(n_replicas: int, tp: int = 1, devices=None) -> list:
    """One tensor-parallel mesh per replica over the available devices.

    Each mesh is 1-D with a ``model`` axis of ``tp`` devices.  Replicas take
    disjoint device subsets when ``n_replicas * tp`` fits; otherwise they
    wrap around and share devices (useful for in-process simulation on small
    hosts — throughput is then nominal, correctness is not affected).  With
    ``tp == 1`` on a single-device host the meshes are ``None`` and replicas
    are plain unsharded engines.
    """
    if n_replicas < 1:
        raise ValueError("n_replicas must be >= 1")
    if tp < 1:
        raise ValueError("tp must be >= 1")
    devices = list(jax.devices()) if devices is None else list(devices)
    if tp > len(devices):
        raise ValueError(
            f"tensor-parallel degree {tp} needs {tp} devices, "
            f"have {len(devices)}"
        )
    if tp == 1 and len(devices) == 1:
        return [None] * n_replicas
    meshes = []
    for r in range(n_replicas):
        devs = [devices[(r * tp + i) % len(devices)] for i in range(tp)]
        meshes.append(Mesh(np.array(devs), ("model",)))
    return meshes


# ---------------------------------------------------------------------------
# replicas
# ---------------------------------------------------------------------------
@dataclass
class Replica:
    """One data-parallel member: an engine pinned to a device subset."""

    index: int
    engine: ServeEngine
    mesh: Optional[Mesh] = None
    alive: bool = True

    def load(self) -> int:
        """Routing load: occupied slots plus queued sessions."""
        active = sum(s is not None for s in self.engine.slots)
        return active + self.engine.scheduler.pending()

    def has_work(self) -> bool:
        return self.alive and self.engine.has_work()


# ---------------------------------------------------------------------------
# routing policies
# ---------------------------------------------------------------------------
@runtime_checkable
class RouterPolicy(Protocol):
    """Replica selection: which live replica serves the next request.

    ``place`` must return the index of an *alive* replica (the router
    guarantees at least one exists when it calls).  Policies may also
    implement two optional hooks the router invokes when present:
    ``note_prefix(tokens, index)`` after a shared prefix is registered on a
    replica, and ``forget_replica(index)`` when a replica fails.
    """

    def place(self, prompt: Sequence[int], priority: int,
              replicas: Sequence[Replica]) -> int:
        ...


class RoundRobinPolicy:
    """Cycle through live replicas in index order."""

    def __init__(self):
        self._next = 0

    def place(self, prompt, priority, replicas) -> int:
        for _ in range(len(replicas)):
            idx = self._next % len(replicas)
            self._next += 1
            if replicas[idx].alive:
                return idx
        raise RuntimeError("no live replicas")

    def __repr__(self):
        return "RoundRobinPolicy()"


class LeastLoadedPolicy:
    """Fewest occupied slots + queued sessions wins (ties: lowest index)."""

    def place(self, prompt, priority, replicas) -> int:
        live = [r for r in replicas if r.alive]
        if not live:
            raise RuntimeError("no live replicas")
        return min(live, key=lambda r: (r.load(), r.index)).index

    def __repr__(self):
        return f"{type(self).__name__}()"


class PrefixAffinityPolicy(LeastLoadedPolicy):
    """Follow shared prefixes: a prompt that extends a registered prefix
    routes to the replica holding that prefix's pages (longest match wins),
    so the copy-on-write fork actually fires instead of re-prefilling on a
    replica that never saw the prefix.  Everything else falls back to
    least-loaded."""

    def __init__(self):
        self._owners: dict = {}  # prefix token tuple -> replica index

    def note_prefix(self, tokens, index: int) -> None:
        self._owners[tuple(int(t) for t in tokens)] = index

    def forget_replica(self, index: int) -> None:
        self._owners = {t: i for t, i in self._owners.items() if i != index}

    def place(self, prompt, priority, replicas) -> int:
        prompt = tuple(int(t) for t in prompt)
        best, best_len = None, 0
        for tokens, idx in self._owners.items():
            if (len(tokens) > best_len and len(tokens) < len(prompt)
                    and prompt[: len(tokens)] == tokens
                    and replicas[idx].alive):
                best, best_len = idx, len(tokens)
        if best is not None:
            return best
        return super().place(prompt, priority, replicas)


ROUTERS = {
    "round_robin": RoundRobinPolicy,
    "least_loaded": LeastLoadedPolicy,
    "prefix_affinity": PrefixAffinityPolicy,
}


def make_router(name: str) -> RouterPolicy:
    try:
        return ROUTERS[name]()
    except KeyError:
        raise ValueError(
            f"unknown router {name!r}; registered: {sorted(ROUTERS)}"
        ) from None


# ---------------------------------------------------------------------------
# cluster
# ---------------------------------------------------------------------------
#: rid stride per replica: engine-local rids stay unique cluster-wide.
_RID_STRIDE = 10**6


@dataclass(frozen=True)
class ClusterConfig:
    """Cluster-level knobs wrapped around one :class:`EngineConfig`.

    ``engine`` is the per-replica template — its ``mesh`` must be unset
    (the cluster owns device placement: each replica gets a ``tp``-device
    ``model``-axis mesh from :func:`replica_meshes`).  ``devices`` limits
    the device pool (default: all of ``jax.devices()``).
    """

    engine: EngineConfig
    n_replicas: int = 1
    tp: int = 1  # tensor-parallel degree inside each replica
    router: str = "least_loaded"  # policy name used when none is injected
    devices: Optional[tuple] = None  # device pool (None: jax.devices())

    def __post_init__(self):
        if self.n_replicas < 1:
            raise ValueError("n_replicas must be >= 1")
        if self.tp < 1:
            raise ValueError("tp must be >= 1")
        if self.router not in ROUTERS:
            raise ValueError(
                f"unknown router {self.router!r}; registered: {sorted(ROUTERS)}"
            )
        if self.engine.mesh is not None:
            raise ValueError(
                "ClusterConfig owns device placement; leave EngineConfig.mesh "
                "unset (set ClusterConfig.tp for tensor parallelism)"
            )


class ClusterRouter:
    """Data-parallel front door: N engine replicas behind one ``submit``.

    The router exposes the single-engine surface — ``submit`` /
    ``register_prefix`` / ``step`` / ``run`` / ``summary`` — and fans it out
    over replicas via the configured :class:`RouterPolicy`.  Replicas are
    constructed lazily on first use; an unservable model family therefore
    raises :class:`UnsupportedFamilyError` from ``submit()``, naming the
    family and the dense fallback.

    In-process, ``step()`` advances every live replica one tick (replicas
    step sequentially, so cluster wall-clock — not summed engine time — is
    the throughput denominator; :class:`ClusterMetrics` handles that).
    """

    def __init__(self, model: ModelApi, params, config: ClusterConfig,
                 policy: Optional[RouterPolicy] = None):
        self.model = model
        self.params = params
        self.cfg = config
        self.policy = policy if policy is not None else make_router(config.router)
        if not isinstance(self.policy, RouterPolicy):
            raise TypeError(
                f"policy {type(self.policy).__name__} does not implement "
                "the RouterPolicy protocol (place)"
            )
        self.replicas: list = []  # built lazily by _ensure_replicas
        self.metrics = ClusterMetrics()
        self._placement: dict = {}  # session rid -> replica index

    # -- lifecycle ---------------------------------------------------------
    def _ensure_replicas(self) -> None:
        if self.replicas:
            return
        meshes = replica_meshes(
            self.cfg.n_replicas, self.cfg.tp,
            list(self.cfg.devices) if self.cfg.devices is not None else None,
        )
        for i, mesh in enumerate(meshes):
            engine = ServeEngine(
                self.model, self.params, replace(self.cfg.engine, mesh=mesh)
            )
            engine._rid = i * _RID_STRIDE  # cluster-unique session rids
            self.replicas.append(Replica(index=i, engine=engine, mesh=mesh))

    def _live(self) -> list:
        live = [r for r in self.replicas if r.alive]
        if not live:
            raise RuntimeError(
                "no live replicas (all failed); cannot place the request"
            )
        return live

    # -- the engine-shaped surface -----------------------------------------
    def submit(self, prompt, max_new_tokens: int, *, priority: int = 0,
               on_token=None) -> Session:
        """Route a request to a replica; returns its :class:`Session`."""
        self._ensure_replicas()  # UnsupportedFamilyError surfaces here
        self._live()
        idx = self.policy.place([int(t) for t in prompt], priority, self.replicas)
        if not self.replicas[idx].alive:
            raise RuntimeError(f"policy placed request on dead replica {idx}")
        session = self.replicas[idx].engine.submit(
            prompt, max_new_tokens, priority=priority, on_token=on_token
        )
        self._placement[session.rid] = idx
        self.metrics.record_route()
        return session

    def register_prefix(self, tokens, replica: Optional[int] = None) -> SharedPrefix:
        """Register a shared prompt prefix on one replica (paged mode).

        The owning replica is ``replica`` when given, else the least-loaded
        live one.  Policies with a ``note_prefix`` hook (prefix-affinity)
        learn the placement so future matching prompts follow the pages.
        """
        self._ensure_replicas()
        if replica is None:
            live = self._live()
            replica = min(live, key=lambda r: (r.load(), r.index)).index
        elif not self.replicas[replica].alive:
            raise ValueError(f"replica {replica} is not alive")
        prefix = self.replicas[replica].engine.register_prefix(tokens)
        note = getattr(self.policy, "note_prefix", None)
        if note is not None:
            note(tokens, replica)
        return prefix

    def step(self) -> None:
        """One cluster tick: every live replica with work advances one step."""
        self._ensure_replicas()
        for r in self.replicas:
            if r.has_work():
                r.engine.step()

    def has_work(self) -> bool:
        return any(r.has_work() for r in self.replicas)

    def run(self, max_ticks: int = 10_000) -> list:
        """Drive until every replica drains (or ``max_ticks``); returns the
        cluster-wide finished list.  Router wall-clock accumulates into
        ``ClusterMetrics.wall_s`` — the throughput denominator."""
        self._ensure_replicas()
        t0 = time.perf_counter()
        ticks = 0
        while self.has_work() and ticks < max_ticks:
            self.step()
            ticks += 1
        self.metrics.wall_s += time.perf_counter() - t0
        return self.finished

    @property
    def finished(self) -> list:
        return [s for r in self.replicas for s in r.engine.finished]

    # -- failure path ------------------------------------------------------
    def fail_replica(self, index: int) -> list:
        """Simulate losing replica ``index``: drain it and requeue its live
        sessions onto the survivors.

        Every in-flight and queued session comes off the failed engine with
        its generated output intact; re-admission on the target replica
        replays prompt+output through prefill, so streams resume token-exact
        (each session keeps its ``Session`` handle — callers notice nothing
        but latency).  Returns the requeued sessions.
        """
        self._ensure_replicas()
        failed = self.replicas[index]
        if not failed.alive:
            raise ValueError(f"replica {index} already failed")
        failed.alive = False
        drained = failed.engine.drain()
        self.metrics.record_failure(drained)
        forget = getattr(self.policy, "forget_replica", None)
        if forget is not None:
            forget(index)
        self._live()  # raises if nobody is left to take the load
        for session in drained:
            idx = self.policy.place(session.prompt, session.priority, self.replicas)
            target = self.replicas[idx].engine
            # scheduler-level resubmit keeps the Session object (and its
            # partial output) alive — engine.submit would mint a new one
            session._on_queued_cancel = target._record_queued_cancel
            target.scheduler.submit(session)
            self._placement[session.rid] = idx
        return drained

    # -- telemetry ---------------------------------------------------------
    def _parts(self) -> list:
        return [r.engine.metrics for r in self.replicas]

    def summary(self) -> dict:
        """Cluster roll-up plus a ``per_replica`` breakdown."""
        self._ensure_replicas()
        out = self.metrics.summary(self._parts())
        out["tp"] = self.cfg.tp
        out["per_replica"] = [
            {"replica": r.index, "alive": r.alive, **r.engine.summary()}
            for r in self.replicas
        ]
        return out

    def to_records(self, benchmark: str, prefix: str, x=None) -> list:
        self._ensure_replicas()
        return self.metrics.to_records(self._parts(), benchmark, prefix, x=x)

    def reset_metrics(self) -> None:
        """Fresh telemetry on every replica and the router (post-warm-up)."""
        for r in self.replicas:
            r.engine.reset_metrics()
        self.metrics = ClusterMetrics()
