"""Sharded serving cluster: replica router over tensor-parallel engines.

Two axes of scale-out, composed (see ``docs/scaling.md`` for the topology
guide):

- **Tensor parallel (inside one engine)** — each replica's
  :class:`~repro.serve.engine.ServeEngine` gets its own
  :class:`jax.sharding.Mesh` with a ``model`` axis of ``tp`` devices; the
  ``dist.sharding`` rules shard the params (head-sharded wq/wk/wv,
  row-parallel wo, vocab-sharded embed/lm_head) and the KV/page cache
  (KV-head dim), and the compiled decode/prefill steps trace inside
  ``activation_sharding(mesh)``.  Sharded decode is token-identical to the
  single-device engine.
- **Data parallel (across engines)** — :class:`ClusterRouter` owns
  ``n_replicas`` engines, each on its own device subset, behind the same
  ``submit() -> Session`` API as a single engine.  A pluggable
  :class:`RouterPolicy` picks the replica per request (least-loaded by
  default; round-robin; prefix-affinity that follows registered shared
  prefixes), per-replica :class:`~repro.serve.metrics.EngineMetrics` roll up
  into one :class:`~repro.serve.metrics.ClusterMetrics` summary, and
  :meth:`ClusterRouter.fail_replica` simulates a replica loss: the failed
  engine drains, and every in-flight/queued session re-queues onto the
  survivors with its generated output intact (the recompute-preemption
  invariant makes the resume token-exact).

Replicas are built lazily on first use, so a model family the engine cannot
serve surfaces its typed :class:`~repro.serve.engine.UnsupportedFamilyError`
at ``submit()`` time — the first call a caller actually makes — rather than
at router construction.

Health-driven failover (see ``docs/robustness.md``): with
``ClusterConfig.health`` set, every cluster tick beats per-replica
heartbeats into :class:`repro.ft.HeartbeatMonitor` (clocked in *ticks*, not
seconds, so detection is deterministic) and feeds per-replica step times to
:class:`repro.ft.StragglerDetector` (the paper's §4.5 throttle signature);
a replica that stops beating — e.g. its engine raised
:class:`~repro.serve.engine.ReplicaCrashed` — or drifts into the throttle
signature is failed over automatically, and a circuit breaker half-opens it
back in after an exponentially-growing cool-down.
"""
from __future__ import annotations

import time
import warnings
from dataclasses import dataclass, replace
from typing import Optional, Protocol, Sequence, runtime_checkable

import jax
import numpy as np
from jax.sharding import Mesh

from repro.core.throttle import V5E_THROTTLE, ThrottleParams
from repro.ft import HeartbeatMonitor, StragglerDetector
from repro.models.api import ModelApi

from .engine import EngineConfig, ReplicaCrashed, ServeEngine
from .metrics import ClusterMetrics
from .paging import SharedPrefix
from .session import Session


# ---------------------------------------------------------------------------
# device topology
# ---------------------------------------------------------------------------
def replica_meshes(n_replicas: int, tp: int = 1, devices=None) -> list:
    """One tensor-parallel mesh per replica over the available devices.

    Each mesh is 1-D with a ``model`` axis of ``tp`` devices.  Replicas take
    disjoint device subsets when ``n_replicas * tp`` fits; otherwise they
    wrap around and share devices (useful for in-process simulation on small
    hosts — throughput is then nominal, correctness is not affected).  With
    ``tp == 1`` on a single-device host the meshes are ``None`` and replicas
    are plain unsharded engines.
    """
    if n_replicas < 1:
        raise ValueError("n_replicas must be >= 1")
    if tp < 1:
        raise ValueError("tp must be >= 1")
    devices = list(jax.devices()) if devices is None else list(devices)
    if tp > len(devices):
        raise ValueError(
            f"tensor-parallel degree {tp} needs {tp} devices, "
            f"have {len(devices)}"
        )
    if tp == 1 and len(devices) == 1:
        return [None] * n_replicas
    meshes = []
    for r in range(n_replicas):
        devs = [devices[(r * tp + i) % len(devices)] for i in range(tp)]
        meshes.append(Mesh(np.array(devs), ("model",)))
    return meshes


# ---------------------------------------------------------------------------
# replicas
# ---------------------------------------------------------------------------
# circuit-breaker states (per replica): CLOSED serves normally, OPEN is
# failed and unroutable, HALF_OPEN is probing its way back in after cool-down
BREAKER_CLOSED = "closed"
BREAKER_OPEN = "open"
BREAKER_HALF_OPEN = "half_open"


@dataclass
class Replica:
    """One data-parallel member: an engine pinned to a device subset."""

    index: int
    engine: ServeEngine
    mesh: Optional[Mesh] = None
    alive: bool = True
    # circuit-breaker bookkeeping (driven by ClusterRouter when health
    # monitoring is on; a manual fail_replica still opens the breaker)
    breaker: str = BREAKER_CLOSED
    failed_at: int = -1  # cluster tick of the most recent failure
    fail_count: int = 0  # lifetime failures (doubles the cool-down)
    probe_ok: int = 0  # consecutive healthy half-open ticks
    work_ticks: int = 0  # successful steps with work (straggler warm-up gate)

    def load(self) -> int:
        """Routing load: occupied slots plus queued sessions."""
        active = sum(s is not None for s in self.engine.slots)
        return active + self.engine.scheduler.pending()

    def has_work(self) -> bool:
        return self.alive and self.engine.has_work()

    @property
    def name(self) -> str:
        """Worker id in the heartbeat/straggler monitors."""
        return f"r{self.index}"


# ---------------------------------------------------------------------------
# routing policies
# ---------------------------------------------------------------------------
@runtime_checkable
class RouterPolicy(Protocol):
    """Replica selection: which live replica serves the next request.

    ``place`` must return the index of an *alive* replica (the router
    guarantees at least one exists when it calls).  Policies may also
    implement two optional hooks the router invokes when present:
    ``note_prefix(tokens, index)`` after a shared prefix is registered on a
    replica, and ``forget_replica(index)`` when a replica fails.
    """

    def place(self, prompt: Sequence[int], priority: int,
              replicas: Sequence[Replica]) -> int:
        ...


class RoundRobinPolicy:
    """Cycle through live replicas in index order."""

    def __init__(self):
        self._next = 0

    def place(self, prompt, priority, replicas) -> int:
        for _ in range(len(replicas)):
            idx = self._next % len(replicas)
            self._next += 1
            if replicas[idx].alive:
                return idx
        raise RuntimeError("no live replicas")

    def __repr__(self):
        return "RoundRobinPolicy()"


class LeastLoadedPolicy:
    """Fewest occupied slots + queued sessions wins (ties: lowest index)."""

    def place(self, prompt, priority, replicas) -> int:
        live = [r for r in replicas if r.alive]
        if not live:
            raise RuntimeError("no live replicas")
        return min(live, key=lambda r: (r.load(), r.index)).index

    def __repr__(self):
        return f"{type(self).__name__}()"


class PrefixAffinityPolicy(LeastLoadedPolicy):
    """Follow shared prefixes: a prompt that extends a registered prefix
    routes to the replica holding that prefix's pages (longest match wins),
    so the copy-on-write fork actually fires instead of re-prefilling on a
    replica that never saw the prefix.  Everything else falls back to
    least-loaded."""

    def __init__(self):
        self._owners: dict = {}  # prefix token tuple -> replica index

    def note_prefix(self, tokens, index: int) -> None:
        self._owners[tuple(int(t) for t in tokens)] = index

    def forget_replica(self, index: int) -> None:
        self._owners = {t: i for t, i in self._owners.items() if i != index}

    def place(self, prompt, priority, replicas) -> int:
        prompt = tuple(int(t) for t in prompt)
        best, best_len = None, 0
        for tokens, idx in self._owners.items():
            if (len(tokens) > best_len and len(tokens) < len(prompt)
                    and prompt[: len(tokens)] == tokens
                    and replicas[idx].alive):
                best, best_len = idx, len(tokens)
        if best is not None:
            return best
        return super().place(prompt, priority, replicas)


ROUTERS = {
    "round_robin": RoundRobinPolicy,
    "least_loaded": LeastLoadedPolicy,
    "prefix_affinity": PrefixAffinityPolicy,
}


def register_router(name: str, policy: Optional[type] = None):
    """Register a :class:`RouterPolicy` factory under ``name``.

    Registered policies become reachable everywhere stock ones are — by
    name in :class:`ClusterConfig`, :func:`make_router`, and the
    ``launch/serve.py --router`` flag.  Usable directly or as a decorator::

        @register_router("sticky")
        class StickyPolicy: ...

        register_router("sticky2", StickyPolicy)

    ``name`` must be new (re-registering raises, so stock policies cannot be
    shadowed silently); the factory is called with no arguments.
    """

    def _register(cls):
        if name in ROUTERS:
            raise ValueError(
                f"router {name!r} already registered ({ROUTERS[name].__name__}); "
                "pick a new name"
            )
        ROUTERS[name] = cls
        return cls

    return _register if policy is None else _register(policy)


def make_router(name: str) -> RouterPolicy:
    try:
        return ROUTERS[name]()
    except KeyError:
        raise ValueError(
            f"unknown router {name!r}; registered: {sorted(ROUTERS)}"
        ) from None


# ---------------------------------------------------------------------------
# cluster
# ---------------------------------------------------------------------------
#: rid stride per replica: engine-local rids stay unique cluster-wide.
_RID_STRIDE = 10**6


@dataclass(frozen=True)
class HealthConfig:
    """Knobs for health-driven failover (``ClusterConfig.health``).

    All horizons are in **cluster ticks** — the monitors run on the router's
    tick clock, so detection points are deterministic and fault schedules
    replay exactly (wall-clock enters only through the straggler detector's
    step-time ratios).

    - ``heartbeat_timeout`` — ticks without a beat before a replica is
      declared dead and failed over (a crashed engine stops beating).
    - ``straggler`` — enable throttle-signature straggler failover;
      ``throttle``/``utilization``/``margin``/``min_samples`` parameterize
      the :class:`repro.ft.StragglerDetector` (§4.5 slowdown signature).
    - ``cooldown`` — ticks a failed replica's breaker stays OPEN before the
      first half-open probe; doubles per repeat failure (capped at
      ``2**max_cooldown_doublings``).
    - ``probe_ticks`` — consecutive healthy HALF_OPEN ticks before the
      breaker fully closes again.
    - ``warmup_ticks`` — per-replica working steps to skip before feeding
      the straggler detector: the first few ticks carry jit-compile spikes
      that would otherwise read as a throttle signature.
    """

    heartbeat_timeout: int = 3
    straggler: bool = True
    throttle: ThrottleParams = V5E_THROTTLE
    utilization: float = 0.9
    margin: float = 0.25
    min_samples: int = 5
    cooldown: int = 8
    probe_ticks: int = 2
    max_cooldown_doublings: int = 4
    warmup_ticks: int = 5

    def __post_init__(self):
        if self.heartbeat_timeout < 1:
            raise ValueError("heartbeat_timeout must be >= 1 tick")
        if self.cooldown < 1:
            raise ValueError("cooldown must be >= 1 tick")
        if self.probe_ticks < 1:
            raise ValueError("probe_ticks must be >= 1")
        if not 0.0 <= self.margin <= 1.0:
            raise ValueError("margin must be in [0, 1]")
        if self.warmup_ticks < 0:
            raise ValueError("warmup_ticks must be >= 0")


@dataclass(frozen=True)
class ClusterConfig:
    """Cluster-level knobs wrapped around one :class:`EngineConfig`.

    ``engine`` is the per-replica template — its ``mesh`` must be unset
    (the cluster owns device placement: each replica gets a ``tp``-device
    ``model``-axis mesh from :func:`replica_meshes`).  ``devices`` limits
    the device pool (default: all of ``jax.devices()``).  ``health`` turns
    on heartbeat/straggler monitoring with automatic failover and the
    circuit breaker (default off: detection thresholds are workload-relative
    and first-tick compile spikes would need the warm-up pass the bench
    drivers do — opt in per deployment, see docs/robustness.md).
    """

    engine: EngineConfig
    n_replicas: int = 1
    tp: int = 1  # tensor-parallel degree inside each replica
    router: str = "least_loaded"  # policy name used when none is injected
    devices: Optional[tuple] = None  # device pool (None: jax.devices())
    health: Optional[HealthConfig] = None  # None: manual fail_replica only

    def __post_init__(self):
        if self.n_replicas < 1:
            raise ValueError("n_replicas must be >= 1")
        if self.tp < 1:
            raise ValueError("tp must be >= 1")
        if self.router not in ROUTERS:
            raise ValueError(
                f"unknown router {self.router!r}; registered: {sorted(ROUTERS)}"
            )
        if self.engine.mesh is not None:
            raise ValueError(
                "ClusterConfig owns device placement; leave EngineConfig.mesh "
                "unset (set ClusterConfig.tp for tensor parallelism)"
            )


class ClusterRouter:
    """Data-parallel front door: N engine replicas behind one ``submit``.

    The router exposes the single-engine surface — ``submit`` /
    ``register_prefix`` / ``step`` / ``run`` / ``summary`` — and fans it out
    over replicas via the configured :class:`RouterPolicy`.  Replicas are
    constructed lazily on first use; an unservable model family therefore
    raises :class:`UnsupportedFamilyError` from ``submit()``, naming the
    family and the dense fallback.

    In-process, ``step()`` advances every live replica one tick (replicas
    step sequentially, so cluster wall-clock — not summed engine time — is
    the throughput denominator; :class:`ClusterMetrics` handles that).
    """

    def __init__(self, model: ModelApi, params, config: ClusterConfig,
                 policy: Optional[RouterPolicy] = None):
        self.model = model
        self.params = params
        self.cfg = config
        self.policy = policy if policy is not None else make_router(config.router)
        if not isinstance(self.policy, RouterPolicy):
            raise TypeError(
                f"policy {type(self.policy).__name__} does not implement "
                "the RouterPolicy protocol (place)"
            )
        self.replicas: list = []  # built lazily by _ensure_replicas
        self.metrics = ClusterMetrics()
        self._placement: dict = {}  # session rid -> replica index
        # health monitoring runs on the router's tick clock — deterministic
        # detection horizons regardless of wall-clock jitter
        self._tick = 0
        h = config.health
        self.monitor = HeartbeatMonitor(
            timeout_s=float(h.heartbeat_timeout if h else 0),
            clock=lambda: float(self._tick),
        ) if h else None
        self.detector = StragglerDetector(
            throttle=h.throttle, utilization=h.utilization,
            margin=h.margin, min_samples=h.min_samples,
        ) if h and h.straggler else None

    # -- lifecycle ---------------------------------------------------------
    def _ensure_replicas(self) -> None:
        if self.replicas:
            return
        meshes = replica_meshes(
            self.cfg.n_replicas, self.cfg.tp,
            list(self.cfg.devices) if self.cfg.devices is not None else None,
        )
        for i, mesh in enumerate(meshes):
            engine = ServeEngine(
                self.model, self.params, replace(self.cfg.engine, mesh=mesh)
            )
            engine._rid = i * _RID_STRIDE  # cluster-unique session rids
            self.replicas.append(Replica(index=i, engine=engine, mesh=mesh))
            if self.monitor is not None:
                # seed a beat so a replica that crashes before its first
                # successful step still ages into dead_workers()
                self.monitor.beat(f"r{i}", self._tick)

    def _live(self) -> list:
        live = [r for r in self.replicas if r.alive]
        if not live:
            raise RuntimeError(
                "no live replicas (all failed); cannot place the request"
            )
        return live

    # -- the engine-shaped surface -----------------------------------------
    def submit(self, prompt, max_new_tokens: int, *, priority: int = 0,
               on_token=None, deadline_s: Optional[float] = None) -> Session:
        """Route a request to a replica; returns its :class:`Session`."""
        self._ensure_replicas()  # UnsupportedFamilyError surfaces here
        self._live()
        idx = self.policy.place([int(t) for t in prompt], priority, self.replicas)
        if not self.replicas[idx].alive:
            raise RuntimeError(f"policy placed request on dead replica {idx}")
        session = self.replicas[idx].engine.submit(
            prompt, max_new_tokens, priority=priority, on_token=on_token,
            deadline_s=deadline_s,
        )
        self._placement[session.rid] = idx
        self.metrics.record_route()
        return session

    def register_prefix(self, tokens, replica: Optional[int] = None) -> SharedPrefix:
        """Register a shared prompt prefix on one replica (paged mode).

        The owning replica is ``replica`` when given, else the least-loaded
        live one.  Policies with a ``note_prefix`` hook (prefix-affinity)
        learn the placement so future matching prompts follow the pages.
        """
        self._ensure_replicas()
        if replica is None:
            live = self._live()
            replica = min(live, key=lambda r: (r.load(), r.index)).index
        elif not self.replicas[replica].alive:
            raise ValueError(f"replica {replica} is not alive")
        prefix = self.replicas[replica].engine.register_prefix(tokens)
        note = getattr(self.policy, "note_prefix", None)
        if note is not None:
            note(tokens, replica)
        return prefix

    def step(self) -> None:
        """One cluster tick: every live replica with work advances one step.

        With health monitoring on, this is also the detection loop: replicas
        that step successfully beat the heartbeat monitor and feed the
        straggler detector their (scale-dilated) step times; a replica whose
        engine raises :class:`ReplicaCrashed` misses its beat and is failed
        over once the heartbeat horizon passes; OPEN breakers cool down
        toward HALF_OPEN, and healthy HALF_OPEN probes re-close.  Without
        health monitoring a crashed engine's error propagates (the manual,
        pre-health behavior).
        """
        self._ensure_replicas()
        h = self.cfg.health
        if h:
            self._breaker_tick(h)
        for r in self.replicas:
            if not r.alive:
                continue
            try:
                if r.engine.crashed:
                    # surface without mutating engine state (step() would
                    # raise the same before doing any work)
                    raise ReplicaCrashed(
                        f"replica {r.index} crashed at cluster tick {self._tick}"
                    )
                if r.has_work():
                    r.engine.step()
                    r.work_ticks += 1
                    # skip the replica's first few working steps: jit-compile
                    # spikes there would read as a throttle signature.  Same
                    # for mid-run re-traces (op quarantine/revival, backend
                    # degradation) and for replicas with ops quarantined to
                    # the oracle — their step times are not fleet-comparable
                    # and would skew the throttle median both ways.
                    if (self.detector is not None
                            and r.work_ticks > h.warmup_ticks
                            and not r.engine.last_step_recompiled
                            and not r.engine.op_quarantined):
                        self.detector.observe(r.name, r.engine.last_step_s)
            except ReplicaCrashed:
                if h is None:
                    raise
                # no beat this tick: the heartbeat horizon drives failover
                if r.breaker == BREAKER_HALF_OPEN:
                    self._auto_fail(r.index, "probe")
                continue
            if h:
                self.monitor.beat(r.name, self._tick)
                if r.breaker == BREAKER_HALF_OPEN:
                    r.probe_ok += 1
                    if r.probe_ok >= h.probe_ticks:
                        r.breaker = BREAKER_CLOSED
                        self.metrics.record_revival()
        if h:
            self._health_failover(h)
            self.metrics.record_liveness(
                sum(r.alive for r in self.replicas), len(self.replicas)
            )
        self._tick += 1

    # -- health-driven failover (docs/robustness.md) -----------------------
    def _breaker_tick(self, h: HealthConfig) -> None:
        """OPEN -> HALF_OPEN once a failed replica's cool-down has elapsed."""
        for r in self.replicas:
            if r.alive or r.breaker != BREAKER_OPEN:
                continue
            cooldown = h.cooldown * 2 ** min(
                max(r.fail_count - 1, 0), h.max_cooldown_doublings
            )
            if self._tick - r.failed_at < cooldown:
                continue
            r.alive = True
            r.breaker = BREAKER_HALF_OPEN
            r.probe_ok = 0
            self.metrics.record_half_open()
            self.monitor.beat(r.name, self._tick)  # not instantly dead again
            # the revived engine still holds its registered prefixes — re-teach
            # prefix-affinity policies the placement forget_replica() dropped
            note = getattr(self.policy, "note_prefix", None)
            if note is not None:
                for tokens in getattr(r.engine, "_prefixes", {}):
                    note(tokens, r.index)

    def _health_failover(self, h: HealthConfig) -> None:
        """Fail over replicas the monitors flag (dead beats, stragglers)."""
        for name in self.monitor.dead_workers():
            idx = int(name[1:])
            if self.replicas[idx].alive:
                self._auto_fail(idx, "heartbeat")
        if self.detector is not None:
            for name, _inflation in self.detector.stragglers():
                idx = int(name[1:])
                if self.replicas[idx].alive:
                    self._auto_fail(idx, "straggler")

    def _auto_fail(self, index: int, reason: str) -> None:
        """Detected-failure response; skips (and counts) when ``index`` is
        the last live replica — killing it would lose the cluster."""
        live = [r for r in self.replicas if r.alive]
        if len(live) <= 1:
            self.metrics.record_failover_skipped()
            return
        self.fail_replica(index, reason=reason)

    def has_work(self) -> bool:
        return any(r.has_work() for r in self.replicas)

    def run(self, max_ticks: int = 10_000) -> list:
        """Drive until every replica drains (or ``max_ticks``); returns the
        cluster-wide finished list.  Router wall-clock accumulates into
        ``ClusterMetrics.wall_s`` — the throughput denominator.  Exhausting
        the tick budget with work pending warns and bumps the
        ``tick_budget_exhausted`` counter (mirrors ``ServeEngine.run``)."""
        self._ensure_replicas()
        t0 = time.perf_counter()
        ticks = 0
        while self.has_work() and ticks < max_ticks:
            self.step()
            ticks += 1
        self.metrics.wall_s += time.perf_counter() - t0
        if self.has_work():
            self.metrics.record_tick_budget_exhausted()
            warnings.warn(
                f"cluster run(max_ticks={max_ticks}) stopped with work still "
                f"pending on {sum(r.has_work() for r in self.replicas)} "
                "replica(s)",
                RuntimeWarning,
                stacklevel=2,
            )
        return self.finished

    @property
    def finished(self) -> list:
        return [s for r in self.replicas for s in r.engine.finished]

    # -- failure path ------------------------------------------------------
    def fail_replica(self, index: int, *, reason: str = "manual") -> list:
        """Take replica ``index`` out (manually or via health detection):
        drain it and requeue its live sessions onto the survivors.

        Every in-flight and queued session comes off the failed engine with
        its generated output intact; re-admission on the target replica
        replays prompt+output through prefill, so streams resume token-exact
        (each session keeps its ``Session`` handle — callers notice nothing
        but latency).  Requeues run through the target engine's budgeted
        :meth:`~repro.serve.engine.ServeEngine.requeue` (a session bounced
        too often raises the typed ``RetryBudgetExceeded``).  The replica's
        circuit breaker opens; with health monitoring on it will half-open
        back in after the cool-down.  ``reason`` tags the failover counter
        (``manual`` / ``heartbeat`` / ``straggler`` / ``probe``).  Returns
        the requeued sessions.
        """
        self._ensure_replicas()
        failed = self.replicas[index]
        if not failed.alive:
            raise ValueError(f"replica {index} already failed")
        failed.alive = False
        failed.breaker = BREAKER_OPEN
        failed.failed_at = self._tick
        failed.fail_count += 1
        if self.monitor is not None:
            self.monitor.forget(failed.name)
        if self.detector is not None:
            self.detector.forget(failed.name)
        drained = failed.engine.drain()
        self.metrics.record_failure(drained, reason=reason)
        forget = getattr(self.policy, "forget_replica", None)
        if forget is not None:
            forget(index)
        self._live()  # raises if nobody is left to take the load
        for session in drained:
            idx = self.policy.place(session.prompt, session.priority, self.replicas)
            target = self.replicas[idx].engine
            # requeue (not engine.submit) keeps the Session object and its
            # partial output alive, and charges the session's retry budget
            target.requeue(session)
            self._placement[session.rid] = idx
        return drained

    # -- telemetry ---------------------------------------------------------
    def _parts(self) -> list:
        return [r.engine.metrics for r in self.replicas]

    def summary(self) -> dict:
        """Cluster roll-up plus a ``per_replica`` breakdown."""
        self._ensure_replicas()
        out = self.metrics.summary(self._parts())
        out["tp"] = self.cfg.tp
        out["per_replica"] = [
            {"replica": r.index, "alive": r.alive, "breaker": r.breaker,
             **r.engine.summary()}
            for r in self.replicas
        ]
        return out

    def to_records(self, benchmark: str, prefix: str, x=None) -> list:
        self._ensure_replicas()
        return self.metrics.to_records(self._parts(), benchmark, prefix, x=x)

    def reset_metrics(self) -> None:
        """Fresh telemetry on every replica and the router (post-warm-up)."""
        for r in self.replicas:
            r.engine.reset_metrics()
        self.metrics = ClusterMetrics()
