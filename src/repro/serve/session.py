"""Streaming sessions: the handle ``ServeEngine.submit`` returns.

A :class:`Session` carries the request, its incremental output (with an
optional per-token callback), cancellation, and per-request timing stats
(TTFT, inter-token latencies) that :mod:`repro.serve.metrics` aggregates.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Optional

# session lifecycle: QUEUED -> PREFILL -> ACTIVE -> DONE | CANCELLED
# (a paged engine may preempt an ACTIVE session back to QUEUED; it re-enters
# PREFILL with its prior output intact and resumes exactly — see
# ServeEngine._preempt)
QUEUED = "queued"
PREFILL = "prefill"
ACTIVE = "active"
DONE = "done"
CANCELLED = "cancelled"

# finish reasons
FINISH_EOS = "eos"
FINISH_MAX_NEW_TOKENS = "max_new_tokens"
FINISH_MAX_LEN = "max_len"
FINISH_CANCELLED = "cancelled"
FINISH_DEADLINE = "deadline"  # per-request deadline expired before completion


@dataclass
class RequestStats:
    """Wall-clock trace of one request's life (absolute perf_counter stamps)."""

    submitted_at: float = 0.0
    admitted_at: Optional[float] = None
    first_token_at: Optional[float] = None
    finished_at: Optional[float] = None
    token_times: list = field(default_factory=list)
    preemptions: int = 0  # times evicted (paged pool pressure) and resumed
    # times the engine put the session back in the queue after it had been
    # drained / preempted / quarantined — the retry-budget denominator
    # (pool-misfit waits in paged admission do NOT count; see
    # ServeEngine.requeue)
    requeues: int = 0

    @property
    def ttft_s(self) -> Optional[float]:
        """Submit -> first generated token (includes queueing + prefill)."""
        if self.first_token_at is None:
            return None
        return self.first_token_at - self.submitted_at

    @property
    def token_latencies_s(self) -> list:
        """Inter-token gaps after the first token (decode-tick latencies)."""
        return [b - a for a, b in zip(self.token_times, self.token_times[1:])]

    @property
    def total_s(self) -> Optional[float]:
        if self.finished_at is None:
            return None
        return self.finished_at - self.submitted_at


@dataclass
class Session:
    """One request in flight.  Engine-owned fields; callers read ``out``,
    ``status``, ``finish_reason`` and may call :meth:`cancel` at any time."""

    rid: int
    prompt: list  # token ids
    max_new_tokens: int
    priority: int = 0  # higher admits first under PriorityScheduler
    on_token: Optional[Callable] = None  # fn(session, token) per generated token
    # wall-clock budget from submit; when it runs out the engine finishes the
    # session with finish_reason="deadline" and partial output (None: no limit)
    deadline_s: Optional[float] = None
    status: str = QUEUED
    out: list = field(default_factory=list)
    finish_reason: str = ""
    stats: RequestStats = field(default_factory=RequestStats)
    _cancel_requested: bool = field(default=False, repr=False)
    # set by the engine at submit so queued-cancels still reach its
    # metrics/finished accounting (running cancels go through the step loop)
    _on_queued_cancel: Optional[Callable] = field(default=None, repr=False)
    # engine tick before which a requeued session must not be re-admitted
    # (exponential backoff; see ServeEngine.requeue)
    _backoff_until: int = field(default=0, repr=False)

    def deadline_expired(self, now: Optional[float] = None) -> bool:
        if self.deadline_s is None:
            return False
        now = time.perf_counter() if now is None else now
        return now - self.stats.submitted_at > self.deadline_s

    @property
    def done(self) -> bool:
        return self.status in (DONE, CANCELLED)

    @property
    def cancel_requested(self) -> bool:
        return self._cancel_requested

    def cancel(self) -> None:
        """Request cancellation.  Queued sessions are dropped immediately;
        running sessions are released at the next engine step boundary."""
        if self.done:
            return
        self._cancel_requested = True
        if self.status == QUEUED:
            self._finish(FINISH_CANCELLED)
            if self._on_queued_cancel is not None:
                self._on_queued_cancel(self)

    # -- engine-side transitions -------------------------------------------
    def _record_token(self, token: int, now: Optional[float] = None) -> None:
        now = time.perf_counter() if now is None else now
        self.out.append(int(token))
        self.stats.token_times.append(now)
        if self.stats.first_token_at is None:
            self.stats.first_token_at = now
        if self.on_token is not None:
            self.on_token(self, int(token))

    def _finish(self, reason: str, now: Optional[float] = None) -> None:
        self.status = CANCELLED if reason == FINISH_CANCELLED else DONE
        self.finish_reason = reason
        self.stats.finished_at = time.perf_counter() if now is None else now
