"""Engine instrumentation: every tick is measured, every request traced.

The engine feeds :class:`EngineMetrics` wall-clock samples (tick duration,
prefill-chunk duration, slot occupancy, KV-page-pool occupancy) plus each
finished session's :class:`~repro.serve.session.RequestStats`; ``summary()``
distills the paper-style sustained-load numbers (TTFT, per-token latency
percentiles, throughput, occupancy/concurrency, page occupancy, preemption
and shared-prefix-hit counts) and ``to_records()`` emits them in the
schema-v1 record format the bench subsystem stores and gates (the
``page_occupancy`` row appears only for paged engines).

:class:`ClusterMetrics` is the one-level-up view: it pools per-replica
``EngineMetrics`` into a single cluster summary (request samples pooled,
throughput counters summed, occupancy weighted by each replica's tick
coverage) and adds the router-level counters — replica failures and
requeued sessions — that no single engine can see.
"""
from __future__ import annotations

from typing import Sequence

from repro.core.timing import percentile

from .session import Session


class EngineMetrics:
    """Accumulates one engine's serving telemetry.

    ``n_pages`` is 0 for dense engines; paged engines report page-pool
    occupancy per tick (:meth:`record_pages`), recompute preemptions
    (:meth:`record_preemption`), and shared-prefix cache hits
    (:meth:`record_prefix_hit`) on top of the common tick/request telemetry.
    """

    def __init__(self, n_slots: int, n_pages: int = 0):
        self.n_slots = n_slots
        self.n_pages = n_pages  # KV page pool size (0: dense engine)
        self.tick_s: list = []  # full step() wall-clock
        self.decode_s: list = []  # decode-step portion of each tick
        self.occupancy: list = []  # active slots at each decode tick
        self.prefill_s: list = []  # per prefill flush (all chunks)
        self.prefill_tokens = 0  # prompt tokens prefilled
        self.prefill_requests = 0
        self.ttft_s: list = []  # per finished request
        self.token_latency_s: list = []  # inter-token gaps, pooled
        self.generated_tokens = 0
        self.finished = 0
        self.cancelled = 0
        self.pages_used: list = []  # pool pages in use at each decode tick
        self.preemptions = 0  # lanes evicted to free pages
        self.prefix_hits = 0  # admissions that forked a shared prefix
        self.prefix_tokens_reused = 0  # prompt tokens NOT re-prefilled
        # robustness counters (see docs/robustness.md)
        self.deadline_expired = 0  # sessions finished with reason="deadline"
        self.deadline_tokens = 0  # tokens generated for deadline-missed sessions
        self.requeues = 0  # budgeted requeues (preempt/drain/quarantine/failover)
        self.quarantines = 0  # lanes benched after non-finite logits
        self.nan_events = 0  # decode/prefill rows that failed the NaN guard
        self.degradations = 0  # pallas -> xla backend fallbacks
        self.tick_budget_exhausted = 0  # run() returns with work still pending
        # numerics-guard counters (docs/robustness.md#numerics-guard)
        self.guard_checks = 0  # compiled-step outputs shadow-checked
        self.drift_events = 0  # shadow checks that failed the tolerance ladder
        self.op_degradations = 0  # kernel ops quarantined to the oracle
        self.op_revivals = 0  # quarantined ops re-probed clean and revived

    # -- engine hooks ------------------------------------------------------
    def record_tick(self, seconds: float, decode_seconds: float, n_active: int) -> None:
        self.tick_s.append(seconds)
        self.decode_s.append(decode_seconds)
        self.occupancy.append(n_active)

    def record_prefill(self, seconds: float, n_tokens: int, n_requests: int) -> None:
        self.prefill_s.append(seconds)
        self.prefill_tokens += n_tokens
        self.prefill_requests += n_requests

    def record_pages(self, pages_in_use: int) -> None:
        self.pages_used.append(pages_in_use)

    def record_preemption(self) -> None:
        self.preemptions += 1

    def record_prefix_hit(self, tokens_reused: int) -> None:
        self.prefix_hits += 1
        self.prefix_tokens_reused += tokens_reused

    def record_requeue(self) -> None:
        self.requeues += 1

    def record_quarantine(self) -> None:
        self.quarantines += 1

    def record_nan_event(self, n_lanes: int = 1) -> None:
        self.nan_events += n_lanes

    def record_degradation(self) -> None:
        self.degradations += 1

    def record_tick_budget_exhausted(self) -> None:
        self.tick_budget_exhausted += 1

    def record_guard_check(self) -> None:
        self.guard_checks += 1

    def record_drift_event(self) -> None:
        self.drift_events += 1

    def record_op_degradation(self, n_ops: int = 1) -> None:
        self.op_degradations += n_ops

    def record_op_revival(self) -> None:
        self.op_revivals += 1

    def record_finished(self, session: Session) -> None:
        if session.finish_reason == "cancelled":
            self.cancelled += 1
            return
        self.finished += 1
        self.generated_tokens += len(session.out)
        if session.finish_reason == "deadline":
            # still a served request, but its tokens missed the SLA —
            # excluded from goodput, tracked separately
            self.deadline_expired += 1
            self.deadline_tokens += len(session.out)
        if session.stats.ttft_s is not None:
            self.ttft_s.append(session.stats.ttft_s)
        self.token_latency_s.extend(session.stats.token_latencies_s)

    # -- derived -----------------------------------------------------------
    def summary(self) -> dict:
        """Sustained-load summary; times in ms, rates in tokens/s."""
        total_s = sum(self.tick_s) + sum(self.prefill_s)
        n_t = len(self.ttft_s)
        occ = (
            sum(self.occupancy) / (len(self.occupancy) * self.n_slots)
            if self.occupancy
            else 0.0
        )
        page_occ = (
            sum(self.pages_used) / (len(self.pages_used) * self.n_pages)
            if self.pages_used and self.n_pages
            else 0.0
        )
        return {
            "requests": self.finished,
            "cancelled": self.cancelled,
            "generated_tokens": self.generated_tokens,
            "prefill_tokens": self.prefill_tokens,
            "ticks": len(self.tick_s),
            "total_s": total_s,
            "throughput_tok_s": self.generated_tokens / total_s if total_s else 0.0,
            "prefill_tok_s": (
                self.prefill_tokens / sum(self.prefill_s) if self.prefill_s else 0.0
            ),
            "ttft_ms_mean": (sum(self.ttft_s) / n_t * 1e3) if n_t else float("nan"),
            "ttft_ms_p50": percentile(self.ttft_s, 50) * 1e3,
            "ttft_ms_p95": percentile(self.ttft_s, 95) * 1e3,
            "tok_latency_ms_p50": percentile(self.token_latency_s, 50) * 1e3,
            "tok_latency_ms_p95": percentile(self.token_latency_s, 95) * 1e3,
            "occupancy": occ,
            # mean concurrently-active lanes: the absolute twin of
            # ``occupancy`` — comparable across engines with different
            # n_slots (the paged-vs-dense equal-memory contrast)
            "concurrency": occ * self.n_slots,
            "page_occupancy": page_occ,
            "pages_peak": max(self.pages_used, default=0),
            "preemptions": self.preemptions,
            "prefix_hits": self.prefix_hits,
            "prefix_tokens_reused": self.prefix_tokens_reused,
            # goodput: tokens generated for sessions that met their deadline
            # (== generated for engines without deadlines)
            "goodput_tokens": self.generated_tokens - self.deadline_tokens,
            "goodput_tok_s": (
                (self.generated_tokens - self.deadline_tokens) / total_s
                if total_s else 0.0
            ),
            "deadline_expired": self.deadline_expired,
            "requeues": self.requeues,
            "quarantines": self.quarantines,
            "nan_events": self.nan_events,
            "degradations": self.degradations,
            "tick_budget_exhausted": self.tick_budget_exhausted,
            "guard_checks": self.guard_checks,
            "drift_events": self.drift_events,
            "op_degradations": self.op_degradations,
            "op_revivals": self.op_revivals,
        }

    def to_records(self, benchmark: str, prefix: str, x=None) -> list:
        """Schema-v1 rows for one engine run: TTFT, per-token latency
        percentiles, throughput, and slot occupancy."""
        from repro.bench.schema import BenchRecord

        s = self.summary()
        shared = {
            "requests": s["requests"],
            "generated_tokens": s["generated_tokens"],
            "ticks": s["ticks"],
        }
        rows = [
            BenchRecord(
                name=f"{prefix}_ttft",
                benchmark=benchmark,
                x=x,
                value=s["ttft_ms_mean"],
                unit="ms",
                metrics={**shared, "p50": s["ttft_ms_p50"], "p95": s["ttft_ms_p95"]},
                info="time to first token (queue + prefill + sample)",
            ),
            BenchRecord(
                name=f"{prefix}_tok_latency_p50",
                benchmark=benchmark,
                x=x,
                value=s["tok_latency_ms_p50"],
                unit="ms",
                metrics=shared,
                info="median inter-token latency",
            ),
            BenchRecord(
                name=f"{prefix}_tok_latency_p95",
                benchmark=benchmark,
                x=x,
                value=s["tok_latency_ms_p95"],
                unit="ms",
                metrics=shared,
                info="p95 inter-token latency",
            ),
            BenchRecord(
                name=f"{prefix}_throughput",
                benchmark=benchmark,
                x=x,
                value=s["throughput_tok_s"],
                unit="tok/s",
                better="higher",
                metrics={**shared, "prefill_tok_s": s["prefill_tok_s"]},
                info="generated tokens / engine wall-clock",
            ),
            BenchRecord(
                name=f"{prefix}_occupancy",
                benchmark=benchmark,
                x=x,
                value=s["occupancy"],
                unit="frac",
                better="info",
                metrics=shared,
                info=f"mean active slots / {self.n_slots}",
            ),
            BenchRecord(
                name=f"{prefix}_concurrency",
                benchmark=benchmark,
                x=x,
                value=s["concurrency"],
                unit="slots",
                better="higher",
                metrics={**shared, "n_slots": self.n_slots},
                info="mean concurrently-active lanes (absolute slot occupancy)",
            ),
            BenchRecord(
                name=f"{prefix}_goodput",
                benchmark=benchmark,
                x=x,
                value=s["goodput_tok_s"],
                unit="tok/s",
                better="higher",
                metrics={
                    **shared,
                    "goodput_tokens": s["goodput_tokens"],
                    "deadline_expired": s["deadline_expired"],
                },
                info="deadline-met tokens / engine wall-clock",
            ),
            BenchRecord(
                name=f"{prefix}_faults",
                benchmark=benchmark,
                x=x,
                value=float(
                    s["requeues"] + s["quarantines"] + s["nan_events"]
                    + s["degradations"] + s["deadline_expired"]
                    + s["drift_events"] + s["op_degradations"]
                ),
                unit="count",
                better="info",
                metrics={
                    **shared,
                    "requeues": s["requeues"],
                    "quarantines": s["quarantines"],
                    "nan_events": s["nan_events"],
                    "degradations": s["degradations"],
                    "deadline_expired": s["deadline_expired"],
                    "preemptions": s["preemptions"],
                    "tick_budget_exhausted": s["tick_budget_exhausted"],
                    "guard_checks": s["guard_checks"],
                    "drift_events": s["drift_events"],
                    "op_degradations": s["op_degradations"],
                    "op_revivals": s["op_revivals"],
                },
                info="fault-handling events (requeue/quarantine/nan/degrade/deadline/drift)",
            ),
        ]
        if self.n_pages:
            rows.append(
                BenchRecord(
                    name=f"{prefix}_page_occupancy",
                    benchmark=benchmark,
                    x=x,
                    value=s["page_occupancy"],
                    unit="frac",
                    better="info",
                    metrics={
                        **shared,
                        "n_pages": self.n_pages,
                        "pages_peak": s["pages_peak"],
                        "preemptions": s["preemptions"],
                        "prefix_hits": s["prefix_hits"],
                        "prefix_tokens_reused": s["prefix_tokens_reused"],
                    },
                    info=f"mean KV pages in use / {self.n_pages}",
                )
            )
        return rows


class ClusterMetrics:
    """Router-level telemetry pooled over per-replica :class:`EngineMetrics`.

    Request-level samples (TTFT, inter-token gaps) are pooled across
    replicas — a cluster percentile is over *all* finished requests, not a
    mean of per-replica percentiles.  Occupancy is slot-weighted: each
    replica contributes ``sum(occ samples)`` over ``ticks * n_slots``, so a
    busy replica with more ticks weighs more — a naive mean of per-replica
    occupancies would not.  Throughput uses the router's own wall clock
    (``wall_s``) when set: in-process replicas step sequentially, so summing
    per-replica engine time would double-count the same wall interval.

    The router itself records what engines can't see: replica failures and
    the sessions drained + requeued onto surviving replicas.
    """

    def __init__(self):
        self.failures = 0  # replicas failed over the cluster's lifetime
        self.requeued_sessions = 0  # sessions drained off a failed replica
        self.requeued_tokens = 0  # generated tokens carried through requeue
        self.routed = 0  # submit() placements (first placement only)
        self.wall_s = 0.0  # router-measured serving wall-clock
        # robustness counters (see docs/robustness.md)
        self.failovers: dict = {}  # failover reason -> count (manual/heartbeat/...)
        self.failover_skipped = 0  # detections left unactioned (last live replica)
        self.half_opens = 0  # cooled-down replicas probed back in
        self.revivals = 0  # half-open probes that fully closed the breaker
        self.live_replica_ticks = 0  # sum over ticks of live replicas
        self.total_replica_ticks = 0  # sum over ticks of configured replicas
        self.tick_budget_exhausted = 0  # run() returns with work still pending

    def record_route(self) -> None:
        self.routed += 1

    def record_failure(self, drained: Sequence[Session], reason: str = "manual") -> None:
        self.failures += 1
        self.failovers[reason] = self.failovers.get(reason, 0) + 1
        self.requeued_sessions += len(drained)
        self.requeued_tokens += sum(len(s.out) for s in drained)

    def record_liveness(self, n_alive: int, n_total: int) -> None:
        """Per-tick availability sample: live replicas out of configured."""
        self.live_replica_ticks += n_alive
        self.total_replica_ticks += n_total

    def record_failover_skipped(self) -> None:
        self.failover_skipped += 1

    def record_half_open(self) -> None:
        self.half_opens += 1

    def record_revival(self) -> None:
        self.revivals += 1

    def record_tick_budget_exhausted(self) -> None:
        self.tick_budget_exhausted += 1

    # -- derived -----------------------------------------------------------
    def summary(self, parts: Sequence[EngineMetrics]) -> dict:
        """Cluster summary over per-replica engine metrics (times in ms)."""
        ttft = [t for m in parts for t in m.ttft_s]
        gaps = [g for m in parts for g in m.token_latency_s]
        generated = sum(m.generated_tokens for m in parts)
        engine_s = sum(sum(m.tick_s) + sum(m.prefill_s) for m in parts)
        total_s = self.wall_s or engine_s
        occ_num = sum(sum(m.occupancy) for m in parts)
        occ_den = sum(len(m.occupancy) * m.n_slots for m in parts)
        prefill_s = sum(sum(m.prefill_s) for m in parts)
        page_num = sum(sum(m.pages_used) for m in parts)
        page_den = sum(len(m.pages_used) * m.n_pages for m in parts if m.n_pages)
        n_t = len(ttft)
        return {
            "replicas": len(parts),
            "requests": sum(m.finished for m in parts),
            "cancelled": sum(m.cancelled for m in parts),
            "generated_tokens": generated,
            "prefill_tokens": sum(m.prefill_tokens for m in parts),
            "ticks": sum(len(m.tick_s) for m in parts),
            "total_s": total_s,
            "throughput_tok_s": generated / total_s if total_s else 0.0,
            "prefill_tok_s": (
                sum(m.prefill_tokens for m in parts) / prefill_s
                if prefill_s else 0.0
            ),
            "ttft_ms_mean": (sum(ttft) / n_t * 1e3) if n_t else float("nan"),
            "ttft_ms_p50": percentile(ttft, 50) * 1e3,
            "ttft_ms_p95": percentile(ttft, 95) * 1e3,
            "tok_latency_ms_p50": percentile(gaps, 50) * 1e3,
            "tok_latency_ms_p95": percentile(gaps, 95) * 1e3,
            "occupancy": occ_num / occ_den if occ_den else 0.0,
            # mean concurrently-active lanes summed over replicas: the
            # cluster-wide twin of EngineMetrics.concurrency
            "concurrency": sum(m.summary()["concurrency"] for m in parts),
            "page_occupancy": page_num / page_den if page_den else 0.0,
            # per-replica pools are disjoint, so the cluster-wide KV
            # footprint peak is the sum of per-replica peaks
            "pages_peak": sum(max(m.pages_used, default=0) for m in parts),
            "preemptions": sum(m.preemptions for m in parts),
            "prefix_hits": sum(m.prefix_hits for m in parts),
            "prefix_tokens_reused": sum(m.prefix_tokens_reused for m in parts),
            "routed": self.routed,
            "failures": self.failures,
            "requeued_sessions": self.requeued_sessions,
            "requeued_tokens": self.requeued_tokens,
            # robustness roll-up: engine fault counters summed, plus the
            # router-level availability/failover view
            "goodput_tokens": sum(m.summary()["goodput_tokens"] for m in parts),
            "goodput_tok_s": (
                sum(m.summary()["goodput_tokens"] for m in parts) / total_s
                if total_s else 0.0
            ),
            "deadline_expired": sum(m.deadline_expired for m in parts),
            "requeues": sum(m.requeues for m in parts),
            "quarantines": sum(m.quarantines for m in parts),
            "nan_events": sum(m.nan_events for m in parts),
            "degradations": sum(m.degradations for m in parts),
            "guard_checks": sum(m.guard_checks for m in parts),
            "drift_events": sum(m.drift_events for m in parts),
            "op_degradations": sum(m.op_degradations for m in parts),
            "op_revivals": sum(m.op_revivals for m in parts),
            "failovers": dict(self.failovers),
            "failover_skipped": self.failover_skipped,
            "half_opens": self.half_opens,
            "revivals": self.revivals,
            # fraction of replica-ticks with the replica alive (1.0 when no
            # liveness samples were recorded, i.e. health monitoring off)
            "availability": (
                self.live_replica_ticks / self.total_replica_ticks
                if self.total_replica_ticks else 1.0
            ),
            "tick_budget_exhausted": self.tick_budget_exhausted,
        }

    def to_records(
        self,
        parts: Sequence[EngineMetrics],
        benchmark: str,
        prefix: str,
        x=None,
    ) -> list:
        """Schema-v1 rows for one cluster run (pooled-percentile semantics)."""
        from repro.bench.schema import BenchRecord

        s = self.summary(parts)
        shared = {
            "replicas": s["replicas"],
            "requests": s["requests"],
            "generated_tokens": s["generated_tokens"],
            "failures": s["failures"],
            "requeued_sessions": s["requeued_sessions"],
        }
        return [
            BenchRecord(
                name=f"{prefix}_ttft",
                benchmark=benchmark,
                x=x,
                value=s["ttft_ms_mean"],
                unit="ms",
                metrics={**shared, "p50": s["ttft_ms_p50"], "p95": s["ttft_ms_p95"]},
                info="cluster TTFT pooled over all replicas",
            ),
            BenchRecord(
                name=f"{prefix}_tok_latency_p95",
                benchmark=benchmark,
                x=x,
                value=s["tok_latency_ms_p95"],
                unit="ms",
                metrics={**shared, "p50": s["tok_latency_ms_p50"]},
                info="p95 inter-token latency pooled over all replicas",
            ),
            BenchRecord(
                name=f"{prefix}_throughput",
                benchmark=benchmark,
                x=x,
                value=s["throughput_tok_s"],
                unit="tok/s",
                better="higher",
                metrics={**shared, "total_s": s["total_s"]},
                info="cluster generated tokens / router wall-clock",
            ),
            BenchRecord(
                name=f"{prefix}_occupancy",
                benchmark=benchmark,
                x=x,
                value=s["occupancy"],
                unit="frac",
                better="info",
                metrics={**shared, "concurrency": s["concurrency"]},
                info="slot-weighted mean occupancy across replicas",
            ),
            BenchRecord(
                name=f"{prefix}_goodput",
                benchmark=benchmark,
                x=x,
                value=s["goodput_tok_s"],
                unit="tok/s",
                better="higher",
                metrics={
                    **shared,
                    "goodput_tokens": s["goodput_tokens"],
                    "deadline_expired": s["deadline_expired"],
                },
                info="deadline-met tokens / router wall-clock",
            ),
            BenchRecord(
                name=f"{prefix}_availability",
                benchmark=benchmark,
                x=x,
                value=s["availability"],
                unit="frac",
                better="higher",
                metrics={
                    **shared,
                    # record metrics are numeric: the by-reason breakdown
                    # stays in summary()["failovers"]
                    "failovers": sum(s["failovers"].values()),
                    "failover_skipped": s["failover_skipped"],
                    "half_opens": s["half_opens"],
                    "revivals": s["revivals"],
                },
                info="live replica-ticks / configured replica-ticks",
            ),
            BenchRecord(
                name=f"{prefix}_faults",
                benchmark=benchmark,
                x=x,
                value=float(
                    s["requeues"] + s["quarantines"] + s["nan_events"]
                    + s["degradations"] + s["deadline_expired"] + s["failures"]
                    + s["drift_events"] + s["op_degradations"]
                ),
                unit="count",
                better="info",
                metrics={
                    **shared,
                    "requeues": s["requeues"],
                    "quarantines": s["quarantines"],
                    "nan_events": s["nan_events"],
                    "degradations": s["degradations"],
                    "deadline_expired": s["deadline_expired"],
                    "failovers": sum(s["failovers"].values()),
                    "tick_budget_exhausted": s["tick_budget_exhausted"],
                    "guard_checks": s["guard_checks"],
                    "drift_events": s["drift_events"],
                    "op_degradations": s["op_degradations"],
                    "op_revivals": s["op_revivals"],
                },
                info="cluster fault-handling events (incl. replica failovers)",
            ),
        ]
