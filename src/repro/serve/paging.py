"""Host-side KV page accounting: allocator, refcounts, shared prefixes.

The device side (``repro.models.attention``) stores KV in one global pool of
fixed-size pages; everything *about* those pages — which are free, which lane
owns which, how many owners a shared page has — lives here, in plain Python,
off the compiled path.  The engine consults the allocator between ticks and
ships the resulting block tables to the device as plain int32 arrays.

Invariants the allocator maintains (and the engine relies on):

- a page id is handed out exactly once until every owner frees it
  (``refcount`` drops to 0),
- a page with ``refcount > 1`` is *shared* and must never be written —
  writers call :meth:`PageAllocator.is_shared` and copy first
  (copy-on-write, at page granularity),
- ``free`` is idempotent per owner (each ``free`` drops one reference).

>>> a = PageAllocator(n_pages=4, page_size=8)
>>> p = a.alloc(2)
>>> a.used, a.free_pages
(2, 2)
>>> a.share(p)            # a second owner: refcount 2 each
>>> a.is_shared(p[0])
True
>>> a.free(p)             # first owner releases; still held by the second
>>> a.used
2
>>> a.free(p)             # second owner releases; pool fully free again
>>> a.used
0
>>> a.alloc(5)
Traceback (most recent call last):
    ...
repro.serve.paging.PagePoolExhausted: need 5 pages, 4 free (pool=4)
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterable


class PagePoolExhausted(RuntimeError):
    """Raised by :meth:`PageAllocator.alloc` when the pool cannot satisfy a
    request; the engine turns this into admission back-off or preemption."""


class PageAllocator:
    """Refcounted fixed-size page pool (host bookkeeping only).

    ``n_pages`` pages of ``page_size`` KV slots each.  Pages are identified
    by their pool index (0..n_pages-1).  Free pages are recycled LIFO, which
    keeps recently-touched pool regions hot.
    """

    def __init__(self, n_pages: int, page_size: int):
        if n_pages < 1 or page_size < 1:
            raise ValueError("n_pages and page_size must be >= 1")
        self.n_pages = n_pages
        self.page_size = page_size
        self._free: list[int] = list(range(n_pages - 1, -1, -1))  # pop() -> page 0 first
        self._refs: dict[int, int] = {}

    # -- queries -----------------------------------------------------------
    @property
    def free_pages(self) -> int:
        """Pages with no owner."""
        return len(self._free)

    @property
    def used(self) -> int:
        """Pages with at least one owner."""
        return self.n_pages - len(self._free)

    def refcount(self, page: int) -> int:
        return self._refs.get(page, 0)

    def is_shared(self, page: int) -> bool:
        """True if writing ``page`` would corrupt another owner's view."""
        return self._refs.get(page, 0) > 1

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def pages_for(self, n_slots: int) -> int:
        """Pages needed to hold ``n_slots`` KV entries (ceil division)."""
        return -(-n_slots // self.page_size)

    # -- transitions -------------------------------------------------------
    def alloc(self, n: int = 1) -> list[int]:
        """Claim ``n`` fresh pages (refcount 1 each) or raise
        :class:`PagePoolExhausted` claiming none."""
        if n > len(self._free):
            raise PagePoolExhausted(
                f"need {n} pages, {len(self._free)} free (pool={self.n_pages})"
            )
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._refs[p] = 1
        return pages

    def share(self, pages: Iterable[int]) -> None:
        """Add one owner to each page (must currently be owned)."""
        for p in pages:
            if self._refs.get(p, 0) < 1:
                raise ValueError(f"page {p} is not allocated")
            self._refs[p] += 1

    def free(self, pages: Iterable[int]) -> None:
        """Drop one owner from each page; pages with no owners return to the
        pool.  Freeing an unallocated page is an error (double free)."""
        for p in pages:
            r = self._refs.get(p, 0)
            if r < 1:
                raise ValueError(f"double free of page {p}")
            if r == 1:
                del self._refs[p]
                self._free.append(p)
            else:
                self._refs[p] = r - 1


@dataclass
class SharedPrefix:
    """A registered common prompt prefix whose KV pages live in the pool.

    The registry (the engine) holds one permanent reference on every page, so
    prefix pages survive any session's exit; forking sessions take additional
    references on the pages they reuse.  ``tokens`` is the full registered
    prefix; a fork reuses KV for positions ``[0, len(tokens))`` except that at
    least the final prompt token is always re-fed so the fork has logits to
    sample from (see ``ServeEngine._fork_plan``).
    """

    tokens: tuple
    pages: list[int] = field(default_factory=list)
    hits: int = 0

    def __len__(self) -> int:
        return len(self.tokens)
