"""Serving substrate: pluggable batched engine.

``ServeEngine`` + ``EngineConfig`` drive a fixed slot grid with one compiled
decode step per tick and chunked batched prefill; admission order is a
swappable ``Scheduler`` (FCFS / priority / static-batch, or user-supplied);
``submit()`` returns a streaming ``Session`` handle; ``EngineMetrics`` emits
schema-v1 serving records (TTFT, latency percentiles, throughput).
"""
from .engine import EngineConfig, ServeEngine
from .metrics import EngineMetrics
from .sampler import greedy, temperature_sample, top_k_sample
from .scheduler import (
    SCHEDULERS,
    FCFSScheduler,
    PriorityScheduler,
    Scheduler,
    StaticBatchScheduler,
    make_scheduler,
)
from .session import RequestStats, Session

__all__ = [
    "SCHEDULERS",
    "EngineConfig",
    "EngineMetrics",
    "FCFSScheduler",
    "PriorityScheduler",
    "RequestStats",
    "Scheduler",
    "ServeEngine",
    "Session",
    "StaticBatchScheduler",
    "greedy",
    "make_scheduler",
    "temperature_sample",
    "top_k_sample",
]
