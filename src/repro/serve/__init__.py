"""Serving substrate: pluggable batched engine with paged or dense KV.

``ServeEngine`` + ``EngineConfig`` drive a fixed slot grid with one compiled
decode step per tick and chunked batched prefill; admission order is a
swappable ``Scheduler`` (FCFS / priority / static-batch, or user-supplied);
``submit()`` returns a streaming ``Session`` handle; ``EngineMetrics`` emits
schema-v1 serving records (TTFT, latency percentiles, throughput).  Setting
``EngineConfig.page_size`` switches the KV layout from dense per-slot regions
to a global refcounted page pool (``PageAllocator``) with continuous
batching, recompute preemption, and copy-on-write shared prefixes
(``ServeEngine.register_prefix``) — see docs/serving.md.
"""
from .engine import EngineConfig, ServeEngine
from .metrics import EngineMetrics
from .paging import PageAllocator, PagePoolExhausted, SharedPrefix
from .sampler import greedy, temperature_sample, top_k_sample
from .scheduler import (
    SCHEDULERS,
    FCFSScheduler,
    PriorityScheduler,
    Scheduler,
    StaticBatchScheduler,
    make_scheduler,
)
from .session import RequestStats, Session

__all__ = [
    "SCHEDULERS",
    "EngineConfig",
    "EngineMetrics",
    "FCFSScheduler",
    "PageAllocator",
    "PagePoolExhausted",
    "PriorityScheduler",
    "RequestStats",
    "Scheduler",
    "ServeEngine",
    "Session",
    "SharedPrefix",
    "StaticBatchScheduler",
    "greedy",
    "make_scheduler",
    "temperature_sample",
    "top_k_sample",
]
