"""Serving substrate: pluggable batched engine with paged or dense KV.

``ServeEngine`` + ``EngineConfig`` drive a fixed slot grid with one compiled
decode step per tick and chunked batched prefill; admission order is a
swappable ``Scheduler`` (FCFS / priority / static-batch, or user-supplied);
``submit()`` returns a streaming ``Session`` handle; ``EngineMetrics`` emits
schema-v1 serving records (TTFT, latency percentiles, throughput).  Setting
``EngineConfig.page_size`` switches the KV layout from dense per-slot regions
to a global refcounted page pool (``PageAllocator``) with continuous
batching, recompute preemption, and copy-on-write shared prefixes
(``ServeEngine.register_prefix``) — see docs/serving.md.

``ClusterRouter`` + ``ClusterConfig`` scale the same surface out: optional
tensor-parallel decode inside each engine (``EngineConfig.mesh`` /
``ClusterConfig.tp``) and a data-parallel replica router with pluggable
placement policies (extensible via ``register_router``), pooled
``ClusterMetrics``, and replica-failure drain/requeue — see docs/scaling.md.

Robustness (see docs/robustness.md): per-request deadlines
(``submit(deadline_s=)``), a budgeted requeue path with exponential backoff
(``RetryBudgetExceeded``), NaN-guard lane quarantine, graceful pallas->xla
degradation, health-driven failover with a circuit breaker
(``ClusterConfig.health`` / ``HealthConfig``), and the deterministic chaos
layer in ``repro.serve.faults`` (``FaultPlan`` / ``FaultInjector``).
"""
from .cluster import (
    ROUTERS,
    ClusterConfig,
    ClusterRouter,
    HealthConfig,
    LeastLoadedPolicy,
    PrefixAffinityPolicy,
    Replica,
    RoundRobinPolicy,
    RouterPolicy,
    make_router,
    register_router,
    replica_meshes,
)
from .engine import (
    SERVABLE_FAMILIES,
    EngineConfig,
    ReplicaCrashed,
    RetryBudgetExceeded,
    ServeEngine,
    UnsupportedFamilyError,
)
from .faults import Fault, FaultInjector, FaultPlan
from .metrics import ClusterMetrics, EngineMetrics
from .paging import PageAllocator, PagePoolExhausted, SharedPrefix
from .sampler import greedy, temperature_sample, top_k_sample
from .scheduler import (
    SCHEDULERS,
    FCFSScheduler,
    PriorityScheduler,
    Scheduler,
    StaticBatchScheduler,
    make_scheduler,
)
from .session import RequestStats, Session

__all__ = [
    "ROUTERS",
    "SCHEDULERS",
    "SERVABLE_FAMILIES",
    "ClusterConfig",
    "ClusterMetrics",
    "ClusterRouter",
    "EngineConfig",
    "EngineMetrics",
    "FCFSScheduler",
    "Fault",
    "FaultInjector",
    "FaultPlan",
    "HealthConfig",
    "LeastLoadedPolicy",
    "PageAllocator",
    "PagePoolExhausted",
    "PrefixAffinityPolicy",
    "PriorityScheduler",
    "Replica",
    "ReplicaCrashed",
    "RequestStats",
    "RetryBudgetExceeded",
    "RoundRobinPolicy",
    "RouterPolicy",
    "Scheduler",
    "ServeEngine",
    "Session",
    "SharedPrefix",
    "StaticBatchScheduler",
    "UnsupportedFamilyError",
    "greedy",
    "make_router",
    "make_scheduler",
    "register_router",
    "replica_meshes",
    "temperature_sample",
    "top_k_sample",
]
