"""Serving substrate: batched prefill/decode engine, sampler, batcher."""
from .engine import ServeEngine
from .sampler import greedy, temperature_sample
from .batcher import Batcher, Request

__all__ = ["ServeEngine", "greedy", "temperature_sample", "Batcher", "Request"]
