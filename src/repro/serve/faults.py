"""Deterministic fault injection for the serving stack (chaos testing).

A :class:`FaultPlan` is a tick-addressed schedule of faults; a
:class:`FaultInjector` drives a :class:`~repro.serve.engine.ServeEngine` or
:class:`~repro.serve.cluster.ClusterRouter` tick by tick, applying each fault
at its scheduled tick and retiring it after its duration.  Everything is
derived from the plan (optionally seeded via :meth:`FaultPlan.random`), so a
chaos run is exactly reproducible: same plan + same workload seed -> same
tokens, same fault/retry/degradation counters (see docs/robustness.md).

Fault kinds and the engine surface they drive:

====================  =====================================================
kind                  effect while active
====================  =====================================================
``crash``             ``engine.crashed = True`` — ``step()`` raises
                      :class:`~repro.serve.engine.ReplicaCrashed`; with
                      cluster health monitoring on, missed heartbeats fail
                      the replica over
``straggler``        ``engine.step_time_scale = factor`` — reported step
                      times dilate by the §4.5 throttle signature
                      (``core.throttle.slowdown_factor`` by default), which
                      the cluster's ``StragglerDetector`` flags; no real
                      sleeping, so chaos runs stay fast and deterministic
``kernel_fault``      the next compiled step raises (simulated pallas
                      lowering/runtime failure) — with ``op`` set the error
                      carries the kernel op's name and the numerics guard
                      quarantines *that op* to the oracle; without it the
                      engine degrades once to the ``xla`` backend; either
                      way serving continues token-identical
``kernel_drift``      the named ``op`` (default ``"matmul"``) starts
                      returning plausible-but-wrong values: seeded additive
                      noise of relative scale ``drift_scale`` perturbs the
                      replica's step logits, and the guard's global
                      injection surface perturbs the op's eager calls — the
                      shadow-oracle check detects it, attribution
                      quarantines the op, and output stays token-exact
``nan_logits``        the listed lanes' decode logits are poisoned with NaN
                      — the NaN guard quarantines the lane and retries the
                      session token-exact
``page_pressure``     steals free pages from the paged engine's pool
                      (held, then returned at expiry) — admission waits and
                      recompute preemption fire under real pressure
====================  =====================================================

One caveat: the ``kernel_drift``/op-targeted injections flow through
``repro.kernels.guard``'s process-global state, so they are global across
replicas (the per-replica logits perturbation still honours ``replica``).

The injector never reaches into compiled code: every fault is a host-side
flag the hardened engine already honours, so injection composes with any
backend/mesh/scheduler combination.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence, Union

import numpy as np

from repro.core.throttle import V5E_THROTTLE, ThrottleParams, slowdown_factor
from repro.kernels import guard as kguard

from .cluster import ClusterRouter
from .engine import ReplicaCrashed, ServeEngine

# fault kinds
CRASH = "crash"
STRAGGLER = "straggler"
KERNEL_FAULT = "kernel_fault"
KERNEL_DRIFT = "kernel_drift"
NAN_LOGITS = "nan_logits"
PAGE_PRESSURE = "page_pressure"
KINDS = (CRASH, STRAGGLER, KERNEL_FAULT, KERNEL_DRIFT, NAN_LOGITS, PAGE_PRESSURE)
#: default draw set for :meth:`FaultPlan.random` — ``kernel_drift`` is
#: opt-in (pass ``kinds=KINDS``): undetected drift on a guard-off engine
#: corrupts tokens by design, which random chaos on arbitrary targets
#: (e.g. the serve driver's --chaos) must not do
RANDOM_KINDS = (CRASH, STRAGGLER, KERNEL_FAULT, NAN_LOGITS, PAGE_PRESSURE)


@dataclass(frozen=True)
class Fault:
    """One scheduled fault: ``kind`` hits ``replica`` at ``tick`` and stays
    active for ``duration`` injector ticks.

    ``factor`` (straggler) defaults to the throttle-signature slowdown;
    ``lanes`` (nan_logits) are the poisoned slot indices; ``pages``
    (page_pressure) is how many free pages to steal (clamped to what the
    pool has); ``message`` (kernel_fault) is the simulated error text;
    ``op`` names the kernel op a kernel_fault/kernel_drift targets
    (kernel_drift defaults to ``"matmul"``) and ``drift_scale`` is the
    relative magnitude of the injected drift noise.
    """

    tick: int
    kind: str
    replica: int = 0
    duration: int = 1
    factor: Optional[float] = None
    lanes: tuple = (0,)
    pages: int = 1
    message: str = "injected pallas kernel fault"
    op: Optional[str] = None  # kernel op targeted by kernel_fault/kernel_drift
    drift_scale: float = 0.05  # relative noise magnitude for kernel_drift

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; expected {KINDS}")
        if self.tick < 0:
            raise ValueError("fault tick must be >= 0")
        if self.duration < 1:
            raise ValueError("fault duration must be >= 1 tick")
        if self.replica < 0:
            raise ValueError("fault replica must be >= 0")
        if self.factor is not None and self.factor <= 1.0:
            raise ValueError("straggler factor must be > 1.0")
        if self.pages < 1:
            raise ValueError("page_pressure pages must be >= 1")
        if self.drift_scale <= 0:
            raise ValueError("drift_scale must be > 0")
        if self.kind == KERNEL_DRIFT and self.op is None:
            object.__setattr__(self, "op", "matmul")


@dataclass(frozen=True)
class FaultPlan:
    """An immutable, tick-addressed fault schedule."""

    faults: tuple = ()
    seed: Optional[int] = None  # provenance when built by random()

    def __post_init__(self):
        object.__setattr__(self, "faults", tuple(self.faults))

    @classmethod
    def random(cls, seed: int, *, n_ticks: int = 32, n_faults: int = 4,
               n_replicas: int = 1, kinds: Sequence[str] = RANDOM_KINDS,
               max_duration: int = 4) -> "FaultPlan":
        """Seed-deterministic plan: ``n_faults`` draws over ``kinds`` with
        ticks in ``[1, n_ticks)`` — the same seed always yields the same
        schedule, so CI chaos runs are reproducible."""
        if n_ticks < 2:
            raise ValueError("n_ticks must be >= 2")
        rng = np.random.default_rng(seed)
        faults = []
        for _ in range(n_faults):
            kind = kinds[int(rng.integers(len(kinds)))]
            faults.append(Fault(
                tick=int(rng.integers(1, n_ticks)),
                kind=kind,
                replica=int(rng.integers(n_replicas)),
                duration=int(rng.integers(1, max_duration + 1)),
                lanes=(int(rng.integers(8)),),
                pages=int(rng.integers(1, 4)),
            ))
        return cls(faults=tuple(sorted(faults, key=lambda f: f.tick)), seed=seed)

    def at(self, tick: int) -> list:
        return [f for f in self.faults if f.tick == tick]

    @property
    def horizon(self) -> int:
        """First tick with every fault expired."""
        return max((f.tick + f.duration for f in self.faults), default=0)


class FaultInjector:
    """Applies a :class:`FaultPlan` against an engine or cluster while
    driving it tick by tick.

    The injector owns the drive loop (``step()`` / ``run()``): at each of
    its ticks it retires expired faults, applies newly-due ones, then steps
    the target once.  Fault state is *recomputed from the active set* every
    transition, so overlapping same-kind faults compose correctly (e.g. two
    crash windows on one replica keep it down until both pass).  A tick on
    which the target's engine is crashed still counts — the outage window
    passes, the fault expires, and serving resumes with zero lost sessions.
    """

    def __init__(self, plan: FaultPlan, target: Union[ServeEngine, ClusterRouter],
                 *, throttle: ThrottleParams = V5E_THROTTLE,
                 utilization: float = 0.9):
        self.plan = plan
        self.target = target
        self.tick = 0
        self.signature = slowdown_factor(throttle, utilization)
        self.counts: dict = {k: 0 for k in KINDS}  # applied, by kind
        self.skipped = 0  # faults that could not apply (e.g. pages on dense)
        self.crash_ticks = 0  # ticks the target refused to step
        self._active: list = []  # (expire_tick, fault, held_pages|None)
        self._drift_rngs: dict = {}  # id(fault) -> rng for its logits noise
        # kernel-op injections mirrored into the process-global guard state
        self._guard_drift_ops: set = set()
        self._guard_fault_ops: set = set()
        if any(f.replica >= self._n_replicas() for f in plan.faults):
            raise ValueError(
                f"plan targets replica >= {self._n_replicas()} but the "
                f"target has {self._n_replicas()} replica(s)"
            )

    # -- target introspection ------------------------------------------
    def _clustered(self) -> bool:
        return isinstance(self.target, ClusterRouter)

    def _n_replicas(self) -> int:
        return self.target.cfg.n_replicas if self._clustered() else 1

    def _engines(self) -> dict:
        """replica index -> engine (building cluster replicas if needed)."""
        if self._clustered():
            self.target._ensure_replicas()
            return {r.index: r.engine for r in self.target.replicas}
        return {0: self.target}

    # -- fault application ---------------------------------------------
    def _sync(self) -> None:
        """Recompute every engine's fault surface from the active set."""
        engines = self._engines()
        active = [f for _, f, _ in self._active]
        for idx, eng in engines.items():
            eng.crashed = any(
                f.kind == CRASH and f.replica == idx for f in active
            )
            factors = [
                f.factor if f.factor is not None else self.signature
                for f in active if f.kind == STRAGGLER and f.replica == idx
            ]
            eng.step_time_scale = max(factors) if factors else 1.0
            errs = [f for f in active
                    if f.kind == KERNEL_FAULT and f.replica == idx]
            err = RuntimeError(errs[-1].message) if errs else None
            if err is not None and errs[-1].op is not None:
                err.op = errs[-1].op  # attribution hint for the guard
            eng._inject_step_error = err
            eng._inject_nan_lanes = {
                lane for f in active if f.kind == NAN_LOGITS
                and f.replica == idx for lane in f.lanes
            }
            drifts = [f for f in active
                      if f.kind == KERNEL_DRIFT and f.replica == idx]
            eng._inject_drift = (
                {
                    "op": drifts[-1].op,
                    "scale": drifts[-1].drift_scale,
                    "rng": self._drift_rngs[id(drifts[-1])],
                }
                if drifts else None
            )
        # mirror op-targeted injections into the guard's global state so
        # eager guarded calls and attribution probes see them too (global
        # across replicas — see the module docstring caveat)
        drift_ops = {f.op for f in active if f.kind == KERNEL_DRIFT}
        fault_ops = {f.op for f in active if f.kind == KERNEL_FAULT
                     and f.op is not None}
        for op in drift_ops - self._guard_drift_ops:
            f = next(f for f in active if f.kind == KERNEL_DRIFT and f.op == op)
            kguard.inject_drift(op, scale=f.drift_scale,
                                seed=(self.plan.seed or 0) * 7919 + f.tick)
        for op in self._guard_drift_ops - drift_ops:
            kguard.clear_drift(op)
        for op in fault_ops - self._guard_fault_ops:
            f = next(f for f in active if f.kind == KERNEL_FAULT and f.op == op)
            kguard.inject_fault(op, f.message)
        for op in self._guard_fault_ops - fault_ops:
            kguard.clear_fault(op)
        self._guard_drift_ops = drift_ops
        self._guard_fault_ops = fault_ops

    def _apply(self, fault: Fault) -> None:
        engines = self._engines()
        eng = engines.get(fault.replica)
        if eng is None:
            self.skipped += 1
            return
        held = None
        if fault.kind == PAGE_PRESSURE:
            if not eng.paged or eng.allocator.free_pages == 0:
                self.skipped += 1
                return
            held = eng.allocator.alloc(
                min(fault.pages, eng.allocator.free_pages)
            )
        if fault.kind == KERNEL_DRIFT:
            # seeded per-fault rng: the same plan replays the same noise
            self._drift_rngs[id(fault)] = np.random.default_rng(
                (self.plan.seed or 0) * 7919 + fault.tick
            )
        self.counts[fault.kind] += 1
        self._active.append((self.tick + fault.duration, fault, held))
        self._sync()

    def _expire(self) -> None:
        due = [entry for entry in self._active if entry[0] <= self.tick]
        if not due:
            return
        self._active = [e for e in self._active if e[0] > self.tick]
        engines = self._engines()
        for _, fault, held in due:
            if held:  # return stolen pages to the pool
                engines[fault.replica].allocator.free(held)
            self._drift_rngs.pop(id(fault), None)
        self._sync()

    def expire_all(self) -> None:
        """Retire every active fault and restore the target's surface."""
        self.tick = max(self.tick, max((e[0] for e in self._active), default=0))
        self._expire()

    # -- drive loop -----------------------------------------------------
    def step(self) -> None:
        """One chaos tick: retire expired faults, apply due ones, step the
        target.  A crashed target (bare engine, or a cluster without health
        monitoring whose tick hit the crashed replica) does not step this
        tick — the outage window simply passes."""
        self._expire()
        for fault in self.plan.at(self.tick):
            self._apply(fault)
        try:
            self.target.step()
        except ReplicaCrashed:
            self.crash_ticks += 1
        self.tick += 1

    def run(self, max_ticks: int = 10_000) -> list:
        """Drive until the target drains and every fault has fired/expired
        (or ``max_ticks``); restores the fault surface before returning the
        target's finished list."""
        ticks = 0
        while ticks < max_ticks and (
            self.target.has_work()
            or self._active
            or self.tick < self.plan.horizon
        ):
            self.step()
            ticks += 1
        self.expire_all()
        return self.target.finished

    def summary(self) -> dict:
        """Injection-side counters (the serving-side ones live in
        ``EngineMetrics``/``ClusterMetrics``)."""
        return {
            "plan_faults": len(self.plan.faults),
            "applied": dict(self.counts),
            "skipped": self.skipped,
            "crash_ticks": self.crash_ticks,
            "ticks": self.tick,
        }
