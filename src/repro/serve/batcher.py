"""Static-slot continuous batcher.

The engine runs a fixed-batch decode step (TPU-friendly: one compiled
shape); the batcher multiplexes a request queue onto those slots —
admitting a new request into a slot the moment its occupant finishes
(continuous batching at step granularity).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional


@dataclass
class Request:
    rid: int
    prompt: list  # token ids
    max_new_tokens: int
    out: list = field(default_factory=list)
    done: bool = False


@dataclass
class Slot:
    request: Optional[Request] = None
    pos: int = 0  # next cache position


class Batcher:
    def __init__(self, n_slots: int, max_len: int):
        self.slots = [Slot() for _ in range(n_slots)]
        self.max_len = max_len
        self.queue: list[Request] = []
        self.finished: list[Request] = []

    def submit(self, req: Request):
        self.queue.append(req)

    def admit(self) -> list[tuple[int, Request]]:
        """Fill free slots from the queue; returns [(slot_idx, request)] that
        need prefill."""
        admitted = []
        for i, slot in enumerate(self.slots):
            if slot.request is None and self.queue:
                req = self.queue.pop(0)
                slot.request = req
                slot.pos = len(req.prompt)
                admitted.append((i, req))
        return admitted

    def active(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if s.request is not None]

    def record_token(self, slot_idx: int, token: int, eos_id: Optional[int] = None):
        slot = self.slots[slot_idx]
        req = slot.request
        if req is None:
            return
        req.out.append(int(token))
        slot.pos += 1
        if (
            len(req.out) >= req.max_new_tokens
            or slot.pos >= self.max_len
            or (eos_id is not None and token == eos_id)
        ):
            req.done = True
            self.finished.append(req)
            slot.request = None
            slot.pos = 0

    def idle(self) -> bool:
        return not self.queue and not self.active()
