"""Batched serving engine: per-slot prefill + fused fixed-shape decode step.

One compiled decode step serves all slots every tick; slot admission happens
between ticks (continuous batching).  Per-slot prefill writes the new
request's KV into the shared cache via the model's prefill path at the
slot's batch index.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.api import ModelApi

from .batcher import Batcher, Request
from .sampler import greedy


class ServeEngine:
    def __init__(
        self,
        model: ModelApi,
        params,
        n_slots: int,
        max_len: int,
        sampler=greedy,
        eos_id: Optional[int] = None,
    ):
        self.model = model
        self.params = params
        self.max_len = max_len
        self.sampler = sampler
        self.eos_id = eos_id
        self.batcher = Batcher(n_slots, max_len)
        self.cache = model.init_cache(n_slots, max_len)
        self.last_token = jnp.zeros((n_slots,), jnp.int32)
        self.pos = jnp.zeros((n_slots,), jnp.int32)
        self._decode = jax.jit(model.decode_step)
        self._rid = 0

    # ------------------------------------------------------------------
    def submit(self, prompt: list[int], max_new_tokens: int) -> Request:
        req = Request(self._rid, prompt, max_new_tokens)
        self._rid += 1
        self.batcher.submit(req)
        return req

    # ------------------------------------------------------------------
    def _prefill_slot(self, slot: int, req: Request):
        """Run the prompt through the model one token at a time into this
        slot's cache lane (simple + exact; a production engine would batch
        prefill separately)."""
        toks = jnp.asarray(req.prompt, jnp.int32)
        for t in range(len(req.prompt)):
            tok = self.last_token.at[slot].set(toks[t])
            pos = self.pos.at[slot].set(t)
            logits, self.cache = self._decode(self.params, self.cache, tok, pos)
        self.last_token = self.last_token.at[slot].set(
            self.sampler(logits[slot])
            if logits.ndim == 1
            else self.sampler(logits)[slot]
        )
        self.pos = self.pos.at[slot].set(len(req.prompt))
        req.out.append(int(self.last_token[slot]))

    # ------------------------------------------------------------------
    def step(self):
        """One engine tick: admit, decode, record."""
        for slot, req in self.batcher.admit():
            self._prefill_slot(slot, req)
        active = self.batcher.active()
        if not active:
            return
        logits, self.cache = self._decode(
            self.params, self.cache, self.last_token, self.pos
        )
        next_tok = self.sampler(logits)
        self.last_token = next_tok
        self.pos = self.pos + 1
        for slot in active:
            self.batcher.record_token(slot, int(next_tok[slot]), self.eos_id)

    def run(self, max_ticks: int = 10_000) -> list[Request]:
        ticks = 0
        while not self.batcher.idle() and ticks < max_ticks:
            self.step()
            ticks += 1
        return self.batcher.finished
