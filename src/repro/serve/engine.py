"""Pluggable batched serving engine with paged or dense KV.

One compiled fixed-shape decode step serves all slots every tick; admission
between ticks is delegated to a swappable :class:`~repro.serve.scheduler.
Scheduler`; prompt ingestion runs as *chunked batched prefill* — one compiled
``ModelApi.decode_chunk`` call per chunk, shared across every slot admitted
that tick.  Every tick is measured into :class:`~repro.serve.metrics.
EngineMetrics` and the compiled steps trace under the :class:`EngineConfig`'s
kernel-policy backend, so one engine definition runs the pallas / interpret /
xla paths side by side.

Two KV layouts (see ``docs/serving.md`` for the full architecture guide):

- **dense** (``page_size=None``) — each slot reserves a contiguous
  ``max_len`` KV region; memory is ``n_slots * max_len`` regardless of the
  actual sequence lengths.
- **paged** (``page_size=N``) — KV lives in a global pool of fixed-size
  pages (``repro.models.attention``); each lane holds an ordered page list
  (its *block table* row) and the host-side
  :class:`~repro.serve.paging.PageAllocator` tracks ownership.  Admission is
  page-aware (a request waits when the pool, not the slot grid, is full),
  finished requests return their pages to the pool the same tick, a lane
  that outgrows its pages triggers *recompute preemption* of the
  lowest-priority latest-admitted lane (evicted sessions re-queue and resume
  exactly, replaying prompt+output through prefill), and prompts sharing a
  :meth:`ServeEngine.register_prefix` prefix reference the same physical
  pages copy-on-write — a common system prompt is stored once across every
  session that shares it.

Correctness invariants the paged path maintains:

- gathering a lane's pages reproduces its dense cache exactly, so paged and
  dense decode are token-for-token identical for the same requests,
- a page referenced by more than one owner (another lane or the prefix
  registry) is never written: forks copy the boundary page before their
  first write (CoW at page granularity),
- empty/finished lanes carry the pad position sentinel (``T*page``), which
  writes nothing — a pad lane can never scribble on a live lane's pages.

Robustness (see ``docs/robustness.md``): ``submit(deadline_s=)`` bounds a
request's wall-clock (it finishes with ``finish_reason="deadline"`` and
partial output), every re-queue of drained/preempted/quarantined work goes
through the budgeted :meth:`ServeEngine.requeue` (exponential backoff, typed
:class:`RetryBudgetExceeded`), non-finite logits quarantine the lane and
retry the session (token-exact: the poisoned token is never recorded), and a
compiled-step failure on the pallas path is attributed to a kernel op by the
numerics guard first (``EngineConfig.guard`` — per-op quarantine to the xla
oracle, breaker-style cooldown/revival, shadow-oracle drift checks of the
compiled steps; docs/robustness.md#numerics-guard), falling back to the
whole-engine one-shot ``xla`` degrade (``EngineConfig.degrade``) only when no
op is implicated.  The ``crashed`` / ``step_time_scale`` attributes are the
deterministic fault-injection surface of ``repro.serve.faults``.
"""
from __future__ import annotations

import time
import warnings
from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.sharding import activation_sharding, param_specs
from repro.kernels import guard as kguard
from repro.kernels.api import BACKENDS, current_policy, kernel_policy
from repro.models.api import ModelApi

from .metrics import EngineMetrics
from .paging import PageAllocator, PagePoolExhausted, SharedPrefix
from .sampler import greedy
from .scheduler import Scheduler, make_scheduler
from .session import (
    ACTIVE,
    FINISH_CANCELLED,
    FINISH_DEADLINE,
    FINISH_EOS,
    FINISH_MAX_LEN,
    FINISH_MAX_NEW_TOKENS,
    PREFILL,
    QUEUED,
    Session,
)


#: Model families whose caches are plain attention KV and therefore serve
#: through the batched engine today.  Recurrent families (ssm/xlstm/hybrid)
#: carry per-lane conv/ssm state that cannot yet advance independently inside
#: a shared batch — see the ROADMAP per-lane state isolation item.
SERVABLE_FAMILIES = ("dense", "moe", "vlm")


class UnsupportedFamilyError(NotImplementedError):
    """A model family the engine cannot serve (no ``decode_chunk`` path).

    Raised once, with the family named, wherever the refusal surfaces —
    engine construction, or ``ClusterRouter.submit()`` for clusters whose
    replicas spin up lazily.  ``family`` is the offending
    ``ModelConfig.family``; ``missing`` is the ``ModelApi`` capability that
    is ``None`` for it.
    """

    def __init__(self, family: str, missing: str = "decode_chunk"):
        self.family = family
        self.missing = missing
        super().__init__(
            f"model family {family!r} has no {missing}: recurrent per-lane "
            "state cannot yet advance independently inside a shared batch; "
            f"serve one of the dense-cache families {SERVABLE_FAMILIES} "
            "instead (see the ROADMAP per-lane state isolation item)"
        )


class ReplicaCrashed(RuntimeError):
    """The engine's (simulated) process is down: ``step()`` refuses to run.

    Raised at the very top of :meth:`ServeEngine.step` while the ``crashed``
    flag is set — before any host bookkeeping mutates, so the engine's state
    stays consistent and a later revival (circuit-breaker half-open) resumes
    cleanly.  A :class:`~repro.serve.cluster.ClusterRouter` with health
    monitoring enabled catches this per replica and lets the heartbeat
    timeout drive failover; without health monitoring it propagates.
    """


class RetryBudgetExceeded(RuntimeError):
    """A session was re-queued more times than ``EngineConfig.retry_budget``.

    Raised from :meth:`ServeEngine.requeue` instead of silently looping a
    session through drain/preempt/quarantine forever.  ``session`` is the
    offending request (its partial output is intact).
    """

    def __init__(self, session: Session, budget: int):
        self.session = session
        self.budget = budget
        super().__init__(
            f"session {session.rid} re-queued {session.stats.requeues} times, "
            f"over retry_budget={budget}; partial output "
            f"({len(session.out)} tokens) is intact on the session handle"
        )


@dataclass(frozen=True)
class EngineConfig:
    """Engine-level knobs, separated from the model definition.

    ``backend``/``autotune`` scope a ``kernel_policy`` around the engine's
    compiled steps (applied at trace time), so the same engine definition can
    run every kernel path of a model whose config selects kernel-routed
    implementations (``attn_impl="pallas"``, ``ssm_impl="pallas"``).

    Fields:

    - ``n_slots`` — lanes in the compiled batch (the decode step's B).
    - ``max_len`` — logical cap on prompt+generated length per request.
    - ``prefill_chunk`` — tokens per compiled prefill step (a smaller chunk
      interleaves admission with decode sooner; a larger one amortizes
      dispatch).
    - ``page_size`` — KV slots per page.  ``None`` selects the dense layout;
      set it to enable the paged layout described in the module docstring.
    - ``n_pages`` — page-pool size.  Defaults to
      ``n_slots * ceil(max_len / page_size)`` (worst case: every lane at
      ``max_len`` — same memory as dense).  Set it *lower* to oversubscribe
      slots against real memory: lanes then share the pool and the engine
      admits/preempts on actual usage.  Must hold at least one worst-case
      lane (``ceil(max_len / page_size)``).
    - ``backend`` / ``autotune`` — kernel policy scoped around the engine's
      compiled steps (``None``: ambient policy).
    - ``mesh`` — optional :class:`jax.sharding.Mesh` for tensor-parallel
      decode (see ``docs/scaling.md``).  With a mesh, params are placed by
      the ``dist.sharding`` rules (head-sharded wq/wk/wv, row-parallel wo,
      vocab-sharded embed/lm_head over the ``model`` axis), the KV/page
      cache shards its KV-head dim, and the compiled steps trace inside
      ``activation_sharding(mesh)`` so the model's logical-axis pins apply.
      Sharded decode is token-identical to the single-device path; a dim
      that does not divide the mesh axis stays replicated.
    - ``eos_id`` — sampled token that finishes a request early.
    - ``sampler`` — logits -> token function (greedy default).
    - ``scheduler`` — stock admission policy name used when no
      :class:`Scheduler` instance is injected.
    - ``retry_budget`` / ``retry_backoff`` — bounds on the requeue loop for
      drained/preempted/quarantined sessions: over-budget requeues raise the
      typed :class:`RetryBudgetExceeded`; a nonzero backoff delays the n-th
      re-admission by ``retry_backoff * 2**(n-1)`` engine ticks (0 keeps the
      immediate-retry semantics).
    - ``quarantine_ticks`` — ticks a lane stays out of admission after its
      logits failed the NaN/Inf guard.
    - ``nan_guard`` — check sampled logits rows for non-finite values and
      quarantine + retry instead of emitting garbage tokens.
    - ``degrade`` — on a compiled-step failure under a pallas-like backend,
      fall back once to the ``xla`` backend (token-identical) instead of
      failing the whole engine; a second failure re-raises.
    - ``guard`` — numerics-guard mode for the compiled steps (see
      docs/robustness.md#numerics-guard): ``None`` inherits the ambient
      ``kernel_policy`` guard, ``"off"`` disables, ``"sample"`` shadow-checks
      every ``guard_sample``-th compiled-step output against an xla twin,
      ``"shadow"`` checks every one.  A drifting step attributes to a kernel
      op via ``repro.kernels.guard`` and quarantines *that op* to the oracle
      (whole-engine ``degrade`` stays the fallback when attribution fails);
      the drifting tick is served from the shadow output, keeping the token
      stream exact.
    - ``guard_sample`` — compiled-step sampling stride under
      ``guard="sample"``.
    - ``guard_cooldown`` — engine ticks a quarantined op waits before its
      half-open re-probe (doubling per consecutive failure, capped at 16x).
    """

    n_slots: int
    max_len: int
    prefill_chunk: int = 16  # tokens per compiled prefill step
    page_size: Optional[int] = None  # None: dense per-slot KV regions
    n_pages: Optional[int] = None  # pool size (None: worst-case default)
    backend: Optional[str] = None  # kernel_policy backend (None: ambient)
    # kernel_policy autotune for engine steps (None: ambient; bool: forced)
    autotune: Optional[bool] = None
    # tensor-parallel device mesh for the compiled steps (None: single device)
    mesh: Optional[jax.sharding.Mesh] = None
    eos_id: Optional[int] = None
    sampler: Callable = greedy
    scheduler: str = "fcfs"  # default policy when none is injected
    retry_budget: int = 64  # max requeues per session before the typed error
    retry_backoff: int = 0  # base backoff in ticks (0: immediate re-admission)
    quarantine_ticks: int = 4  # lane bench time after a NaN-guard trip
    nan_guard: bool = True  # quarantine lanes with non-finite logits
    degrade: bool = True  # pallas step failure -> one-shot xla fallback
    guard: Optional[str] = None  # numerics-guard mode (None: ambient policy)
    guard_sample: int = 8  # shadow-check stride under guard="sample"
    guard_cooldown: int = 8  # ticks before a quarantined op re-probes

    def __post_init__(self):
        if self.retry_budget < 1:
            raise ValueError("retry_budget must be >= 1")
        if self.retry_backoff < 0:
            raise ValueError("retry_backoff must be >= 0 ticks")
        if self.quarantine_ticks < 0:
            raise ValueError("quarantine_ticks must be >= 0")
        if self.n_slots < 1:
            raise ValueError("n_slots must be >= 1")
        if self.max_len < 2:
            raise ValueError("max_len must be >= 2 (prompt + one generated token)")
        if self.prefill_chunk < 1:
            raise ValueError("prefill_chunk must be >= 1")
        if self.backend is not None and self.backend not in BACKENDS:
            raise ValueError(f"unknown backend {self.backend!r}; expected {BACKENDS}")
        if self.guard is not None and self.guard not in kguard.GUARD_MODES:
            raise ValueError(
                f"unknown guard mode {self.guard!r}; expected {kguard.GUARD_MODES}"
            )
        if self.guard_sample < 1:
            raise ValueError("guard_sample must be >= 1")
        if self.guard_cooldown < 1:
            raise ValueError("guard_cooldown must be >= 1 tick")
        if self.page_size is not None and self.page_size < 1:
            raise ValueError("page_size must be >= 1")
        if self.n_pages is not None:
            if self.page_size is None:
                raise ValueError("n_pages requires page_size (paged mode)")
            min_pages = -(-self.max_len // self.page_size)
            if self.n_pages < min_pages:
                raise ValueError(
                    f"n_pages={self.n_pages} cannot hold one worst-case lane "
                    f"(max_len {self.max_len} needs {min_pages} pages of "
                    f"{self.page_size})"
                )

    @property
    def table_width(self) -> int:
        """Block-table row length: pages needed for one ``max_len`` lane."""
        if self.page_size is None:
            raise ValueError("table_width is a paged-mode property")
        return -(-self.max_len // self.page_size)


class ServeEngine:
    """Continuous-batching engine over a fixed slot grid.

    ``scheduler`` accepts any :class:`Scheduler` implementation (defaults to
    the config's named stock policy); ``submit`` returns a streaming
    :class:`Session` handle with per-token callbacks, cancellation, and
    request stats.  With ``EngineConfig.page_size`` set, KV is paged (see the
    module docstring): ``register_prefix`` stores a common prompt prefix
    once, admission waits on pages rather than failing, and pool exhaustion
    mid-decode preempts (re-queues) lanes instead of corrupting them.
    """

    def __init__(self, model: ModelApi, params, config: EngineConfig,
                 scheduler: Optional[Scheduler] = None):
        if model.decode_chunk is None:
            raise UnsupportedFamilyError(model.cfg.family)
        self.paged = config.page_size is not None
        if self.paged and (model.decode_step_paged is None
                           or model.decode_chunk_paged is None):
            raise UnsupportedFamilyError(model.cfg.family, missing="decode_chunk_paged")
        self.model = model
        self.mesh = config.mesh
        if self.mesh is not None and params is not None:
            # Place params by the tensor-parallel rules before any compiled
            # step traces: the compiled steps then inherit the layout instead
            # of re-deciding it per trace.
            shapes = jax.tree.map(
                lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), params
            )
            params = jax.device_put(
                params, param_specs(shapes, model.cfg, self.mesh)
            )
        self.params = params
        self.cfg = config
        self.scheduler = scheduler if scheduler is not None else make_scheduler(config.scheduler)
        if not isinstance(self.scheduler, Scheduler):
            raise TypeError(
                f"scheduler {type(self.scheduler).__name__} does not implement "
                "the Scheduler protocol (submit/select/pending)"
            )
        self.slots: list = [None] * config.n_slots
        self.finished: list = []
        self.last_token = jnp.zeros((config.n_slots,), jnp.int32)
        self._lane_pos = [0] * config.n_slots  # host mirror: next cache index
        self._rid = 0
        # -- robustness state (docs/robustness.md) -------------------------
        self.tick = 0  # monotonically increasing step counter
        self.last_step_s = 0.0  # scaled duration of the most recent step()
        # the most recent step() re-traced its compiled fns (quarantine,
        # revival, degradation): health monitors must not score the compile
        # spike as a throttle signature
        self.last_step_recompiled = False
        self._recompiled = False
        # fault-injection surface (repro.serve.faults flips these):
        self.crashed = False  # step() raises ReplicaCrashed while set
        self.step_time_scale = 1.0  # virtual dilation of reported step times
        self._inject_step_error: Optional[Exception] = None  # raised pre-decode
        self._inject_nan_lanes: set = set()  # lanes whose logits are poisoned
        # hardening state:
        self._degraded = False  # compiled steps fell back to the xla backend
        self._quarantined: dict = {}  # lane -> first tick it is usable again
        # numerics-guard state (docs/robustness.md#numerics-guard); the mode
        # must resolve before the _jit_scoped calls below so the compiled
        # steps trace with the guard in their kernel policy
        self._guard_mode = (config.guard if config.guard is not None
                            else (current_policy().guard or "off"))
        self._shadow_decode = None  # lazy xla twins of the compiled steps
        self._shadow_chunk = None
        self._guard_calls = 0  # compiled-step counter (sampling stride)
        self._op_quarantine: dict = {}  # op -> {"since": tick, "fails": n}
        self._nan_attr_tick = -1  # last tick NaN attribution ran (once/tick)
        # fault surface (repro.serve.faults): seeded logits perturbation
        # standing in for a drifting kernel inside the compiled step
        self._inject_drift: Optional[dict] = None  # {"op","scale","rng"}
        self._injected_drift_calls = 0
        if self.paged:
            ps = config.page_size
            self._table_width = config.table_width
            self.n_pages = (config.n_pages if config.n_pages is not None
                            else config.n_slots * self._table_width)
            # pad sentinel: one past the last addressable pool-view slot, so
            # pad lanes/entries write nothing and mask as "see everything"
            self._pad_pos = self._table_width * ps
            self.allocator = PageAllocator(self.n_pages, ps)
            self.page_tables: list = [[] for _ in range(config.n_slots)]
            self._bt = np.zeros((config.n_slots, self._table_width), np.int32)
            self._prefixes: dict = {}  # token tuple -> SharedPrefix
            self.cache = self._place_cache(model.init_paged_cache(self.n_pages, ps),
                                           model.paged_cache_shardings)
            self._decode = self._jit_scoped(model.decode_step_paged)
            self._chunk = self._jit_scoped(model.decode_chunk_paged)
            self._copy_page_fn = jax.jit(
                lambda cache, s, d: jax.tree.map(
                    lambda a: a.at[:, d].set(a[:, s]), cache
                )
            )
            self.pos = jnp.full((config.n_slots,), self._pad_pos, jnp.int32)
        else:
            self.n_pages = 0
            self._pad_pos = config.max_len
            self.cache = self._place_cache(
                model.init_cache(config.n_slots, config.max_len),
                model.cache_shardings,
            )
            self._decode = self._jit_scoped(model.decode_step)
            self._chunk = self._jit_scoped(model.decode_chunk)
            self.pos = jnp.zeros((config.n_slots,), jnp.int32)
        self.metrics = EngineMetrics(config.n_slots, n_pages=self.n_pages)

    # ------------------------------------------------------------------
    def _place_cache(self, cache, shardings_fn: Optional[Callable]):
        """Commit a fresh cache to the engine's mesh (identity without one)."""
        if self.mesh is None or shardings_fn is None:
            return cache
        return jax.device_put(cache, shardings_fn(cache, self.mesh))

    def _jit_scoped(self, fn: Callable, backend: Optional[str] = None) -> Callable:
        """jit ``fn`` so it traces under the config's kernel policy and mesh.

        With a policy or mesh set, jit a per-engine closure (not ``fn``
        itself): jax's trace cache is keyed on function identity, not on the
        policy contextvar or the activation-sharding mesh, so jitting the
        shared ``model.decode_*`` directly would let a second engine with a
        different backend/mesh silently reuse the first engine's trace.

        ``backend`` overrides the config's backend — the graceful-degradation
        path re-jits the steps with ``backend="xla"`` after a pallas failure.
        """
        backend = self.cfg.backend if backend is None else backend
        guard = self._guard_mode if self._guard_mode != "off" else None
        if (backend is None and self.cfg.autotune is None and self.mesh is None
                and guard is None):
            return jax.jit(fn)
        autotune, mesh = self.cfg.autotune, self.mesh

        def scoped(*args):  # fresh object per engine -> own trace cache
            # this body only runs at trace time (cache miss), so it doubles
            # as the compile-spike marker health monitors use to skip the
            # step's duration (see ``last_step_recompiled``)
            self._recompiled = True
            with kernel_policy(backend=backend, autotune=autotune, guard=guard):
                if mesh is None:
                    return fn(*args)
                with activation_sharding(mesh):
                    return fn(*args)

        return jax.jit(scoped)

    # ------------------------------------------------------------------
    # graceful degradation (docs/robustness.md)
    # ------------------------------------------------------------------
    def _backend(self) -> str:
        """Effective kernel backend of the compiled steps right now."""
        if self._degraded:
            return "xla"
        return self.cfg.backend if self.cfg.backend is not None else "pallas"

    @property
    def op_quarantined(self) -> bool:
        """Any kernel op currently quarantined to the oracle backend.  Step
        times are not fleet-comparable while set (part of the engine runs on
        a different backend), so health monitors exclude the replica from
        throttle-signature statistics."""
        return bool(self._op_quarantine)

    def _rejit_steps(self, backend: Optional[str] = None) -> None:
        """Re-jit both compiled steps (per-op quarantine / revival / whole-
        engine degradation all change what a fresh trace dispatches to); the
        lazy shadow twins rebuild on next use."""
        self._recompiled = True
        if self.paged:
            self._decode = self._jit_scoped(self.model.decode_step_paged, backend=backend)
            self._chunk = self._jit_scoped(self.model.decode_chunk_paged, backend=backend)
        else:
            self._decode = self._jit_scoped(self.model.decode_step, backend=backend)
            self._chunk = self._jit_scoped(self.model.decode_chunk, backend=backend)
        self._shadow_decode = self._shadow_chunk = None

    def _degrade(self, err: Exception) -> None:
        """Whole-engine fallback: re-jit decode/prefill on the ``xla``
        backend.  With the numerics guard on this is the *second* line of
        defense — per-op attribution runs first (:meth:`_guard_attribute`).

        Backend parity (the kernels' correctness contract) makes the
        degraded engine token-identical — only kernel dispatch changes, so
        in-flight lanes continue from the same cache without replay."""
        self._degraded = True
        self.metrics.record_degradation()
        self._rejit_steps(backend="xla")
        warnings.warn(
            f"serving engine degraded to the xla backend after a compiled-step "
            f"failure: {err!r}",
            RuntimeWarning,
            stacklevel=4,
        )

    # -- numerics guard (docs/robustness.md#numerics-guard) -------------
    def _op_suppressed(self, err: Exception) -> bool:
        """An injected step error attributed to an op stops firing once that
        op is quarantined — the retried step runs with the op on the oracle."""
        op = getattr(err, "op", None)
        return op is not None and kguard.is_quarantined(op)

    def _perturb(self, out):
        """Apply an injected ``kernel_drift`` fault: seeded additive noise on
        the step's logits, standing in for a drifting kernel inside the
        compiled step.  Quarantining the named op (which routes it to the
        oracle) ends the perturbation, like a real per-op degrade would."""
        inj = self._inject_drift
        if (inj is None or self._backend() == "xla"
                or kguard.is_quarantined(inj["op"])):
            return out
        logits = out[0]
        arr = np.asarray(logits).astype(np.float64)
        noise = inj["rng"].standard_normal(arr.shape)
        scale = inj["scale"] * (float(np.mean(np.abs(arr))) + 1.0)
        self._injected_drift_calls += 1
        perturbed = jnp.asarray(arr + noise * scale, dtype=logits.dtype)
        return (perturbed,) + tuple(out[1:])

    def _guard_attribute(self, err: Exception) -> bool:
        """Attribute a step failure/drift to specific kernel ops via the
        guard's canonical probes; quarantined ops re-jit the steps so fresh
        traces route them to the oracle.  False means no op was implicated
        (the caller falls back to whole-engine handling)."""
        if self._guard_mode == "off":
            return False
        bad = kguard.attribute()
        hinted = getattr(err, "op", None)
        if (hinted is not None and hinted not in bad
                and not kguard.is_quarantined(hinted)):
            kguard.quarantine(hinted, f"engine attribution: {err!r}")
            bad.append(hinted)
        if not bad:
            return False
        for op in bad:
            rec = self._op_quarantine.setdefault(op, {"since": self.tick, "fails": 0})
            rec["since"] = self.tick
            rec["fails"] += 1
        self.metrics.record_op_degradation(len(bad))
        warnings.warn(
            f"numerics guard quarantined kernel op(s) {sorted(bad)} to the "
            f"xla backend (engine stays on {self._backend()!r}): {err!r}",
            RuntimeWarning,
            stacklevel=5,
        )
        self._rejit_steps()
        return True

    def _heal_ops(self) -> None:
        """Half-open re-probe for quarantined ops whose cooldown elapsed:
        a clean canonical probe revives the op (next traces dispatch native
        again); a dirty one doubles the cooldown."""
        healed = False
        for op, rec in list(self._op_quarantine.items()):
            wait = self.cfg.guard_cooldown * 2 ** min(rec["fails"] - 1, 4)
            if self.tick - rec["since"] < wait:
                continue
            if kguard.probe(op):
                kguard.revive(op)
                del self._op_quarantine[op]
                self.metrics.record_op_revival()
                healed = True
            else:
                rec["since"] = self.tick
                rec["fails"] += 1
        if healed:
            self._rejit_steps()

    def _shadow_fn(self, which: str) -> Callable:
        """Lazy xla-backed twin of a compiled step (the shadow oracle)."""
        if which == "decode":
            if self._shadow_decode is None:
                fn = self.model.decode_step_paged if self.paged else self.model.decode_step
                self._shadow_decode = self._jit_scoped(fn, backend="xla")
            return self._shadow_decode
        if self._shadow_chunk is None:
            fn = self.model.decode_chunk_paged if self.paged else self.model.decode_chunk
            self._shadow_chunk = self._jit_scoped(fn, backend="xla")
        return self._shadow_chunk

    def _guard_verify(self, which: str, args: tuple, out):
        """Shadow-oracle check of a compiled-step output: re-run the same
        arguments through the xla twin and compare under the per-dtype
        tolerance ladder.  On drift, attribute to a kernel op (falling back
        to whole-engine degrade) and serve the *shadow* output for this tick
        — the token stream stays exact while the quarantine takes effect."""
        if self._guard_mode == "off" or self._backend() == "xla":
            return out
        self._guard_calls += 1
        due = (self._guard_mode == "shadow"
               or self._guard_calls % self.cfg.guard_sample == 0)
        if not due:
            return out
        shadow = self._shadow_fn(which)(*args)
        self.metrics.record_guard_check()
        ok, detail = kguard.trees_match(out, shadow)
        if ok:
            return out
        self.metrics.record_drift_event()
        err = RuntimeError(
            f"compiled {which} step drifted from its xla shadow: {detail}"
        )
        if not self._guard_attribute(err):
            if self.cfg.degrade:
                self._degrade(err)
            else:
                raise err
        return shadow

    def _call_compiled(self, which: str, *args):
        """Run a compiled step with the guard and degradation boundaries
        around it.

        A failure attributes to a kernel op first (per-op quarantine + retry
        with the op on the oracle); only when attribution finds nothing does
        the whole-engine :meth:`_degrade` fallback fire (or the failure
        re-raise, with ``degrade=False`` or already on xla).  Successful
        outputs pass through the shadow-oracle check of
        :meth:`_guard_verify`.
        """
        while True:
            fn = self._decode if which == "decode" else self._chunk
            try:
                inj = self._inject_step_error
                if (inj is not None and self._backend() != "xla"
                        and not self._op_suppressed(inj)):
                    raise inj
                out = fn(*args)
                out = self._perturb(out)
            except Exception as err:  # guard/degradation boundary
                if self._guard_attribute(err):
                    continue  # op quarantined + steps re-jitted: retry
                if not self.cfg.degrade or self._backend() == "xla":
                    raise
                self._degrade(err)
                continue
            return self._guard_verify(which, args, out)

    # ------------------------------------------------------------------
    def submit(self, prompt, max_new_tokens: int, *, priority: int = 0,
               on_token: Optional[Callable] = None,
               deadline_s: Optional[float] = None) -> Session:
        """Queue a request; returns its streaming :class:`Session` handle.

        ``deadline_s`` bounds the request's wall-clock from this call: a
        session that is still queued or generating when the deadline passes
        finishes with ``finish_reason="deadline"`` and whatever output it
        has (the goodput metrics exclude its tokens).
        """
        prompt = [int(t) for t in prompt]
        if not prompt:
            raise ValueError("empty prompt")
        if len(prompt) >= self.cfg.max_len:
            raise ValueError(
                f"prompt length {len(prompt)} must be < max_len "
                f"{self.cfg.max_len} (no room to generate)"
            )
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if deadline_s is not None and deadline_s <= 0:
            raise ValueError("deadline_s must be positive (or None)")
        session = Session(self._rid, prompt, max_new_tokens,
                          priority=priority, on_token=on_token,
                          deadline_s=deadline_s)
        session.stats.submitted_at = time.perf_counter()
        session._on_queued_cancel = self._record_queued_cancel
        self._rid += 1
        self.scheduler.submit(session)
        return session

    def requeue(self, session: Session) -> None:
        """Budgeted re-queue for drained / preempted / quarantined sessions.

        Every path that puts previously-admitted work back in the queue goes
        through here: recompute preemption, :meth:`drain` (via the cluster's
        failover), and NaN-guard quarantine.  The n-th requeue beyond
        ``retry_budget`` raises :class:`RetryBudgetExceeded`; with
        ``retry_backoff > 0`` re-admission is delayed exponentially
        (``backoff * 2**(n-1)`` ticks, capped at 64x).  Paged pool-misfit
        waits in admission deliberately do **not** count — they recur every
        tick for a merely-waiting request and carry no failure signal.
        """
        session.stats.requeues += 1
        self.metrics.record_requeue()
        if session.stats.requeues > self.cfg.retry_budget:
            raise RetryBudgetExceeded(session, self.cfg.retry_budget)
        if self.cfg.retry_backoff:
            wait = self.cfg.retry_backoff * 2 ** min(session.stats.requeues - 1, 6)
            session._backoff_until = self.tick + wait
        session.status = QUEUED
        session._on_queued_cancel = self._record_queued_cancel
        self.scheduler.submit(session)

    def _record_queued_cancel(self, session: Session) -> None:
        """Queued-cancel accounting: the session never occupies a slot, but
        it must still show up in metrics and the finished list."""
        self.metrics.record_finished(session)
        self.finished.append(session)

    def cancel(self, session: Session) -> None:
        """Alias for ``session.cancel()`` (kept for symmetry with submit)."""
        session.cancel()

    # ------------------------------------------------------------------
    # shared prefixes (paged mode)
    # ------------------------------------------------------------------
    def register_prefix(self, tokens) -> SharedPrefix:
        """Prefill ``tokens`` once into pool pages shared by every future
        request whose prompt starts with them (paged mode only).

        The registry holds a permanent reference on the pages, so they
        survive any individual session; forking sessions re-use the KV for
        all but (at least) the final prompt token and only prefill their
        suffix — a common system prompt costs its pages once, not once per
        lane.  Registration itself runs outside the serving metrics (it is
        one-time setup, typically before traffic).
        """
        if not self.paged:
            raise ValueError("register_prefix requires paged KV (set page_size)")
        tokens = tuple(int(t) for t in tokens)
        if not tokens:
            raise ValueError("empty prefix")
        if len(tokens) >= self.cfg.max_len:
            raise ValueError("prefix must be shorter than max_len")
        if tokens in self._prefixes:
            return self._prefixes[tokens]
        n_t = self.allocator.pages_for(len(tokens))
        if (not self.allocator.can_alloc(n_t)
                or self.allocator.free_pages - n_t < self._table_width):
            raise PagePoolExhausted(
                f"prefix of {len(tokens)} tokens needs {n_t} pages and the "
                f"pool must keep {self._table_width} pages of headroom for "
                f"one worst-case lane ({self.allocator.free_pages} free)"
            )
        pages = self.allocator.alloc(n_t)
        # Prefill the prefix KV through a temporary block-table view: row 0
        # maps to the prefix pages, every other row is pad (writes nothing,
        # reads garbage logits nobody samples) — live lanes are untouched
        # because writes target pool positions, not lanes.
        ps, chunk = self.cfg.page_size, self.cfg.prefill_chunk
        bt = self._bt.copy()
        bt[0, :] = 0
        bt[0, :n_t] = pages
        n_chunks = -(-len(tokens) // chunk)
        toks = np.zeros((self.cfg.n_slots, n_chunks * chunk), np.int32)
        poss = np.full((self.cfg.n_slots, n_chunks * chunk), self._pad_pos, np.int32)
        toks[0, : len(tokens)] = tokens
        poss[0, : len(tokens)] = np.arange(len(tokens), dtype=np.int32)
        bt_dev = jnp.asarray(bt)
        for c in range(n_chunks):
            sl = slice(c * chunk, (c + 1) * chunk)
            _, self.cache = self._chunk(
                self.params, self.cache, bt_dev,
                jnp.asarray(toks[:, sl]), jnp.asarray(poss[:, sl]),
            )
        prefix = SharedPrefix(tokens=tokens, pages=pages)
        self._prefixes[tokens] = prefix
        return prefix

    def unregister_prefix(self, tokens) -> None:
        """Drop a registered prefix: the registry's page references are
        released (pages free once no lane still shares them)."""
        prefix = self._prefixes.pop(tuple(int(t) for t in tokens))
        self.allocator.free(prefix.pages)

    def _fork_plan(self, feed: list) -> tuple:
        """Longest registered prefix under ``feed`` -> (prefix, reuse) where
        ``reuse`` positions of KV are taken from shared pages instead of
        being re-prefilled.  At least the final feed token is always re-fed
        so the fork has a logits row to sample from."""
        best, reuse = None, 0
        for prefix in self._prefixes.values():
            n = min(len(prefix.tokens), len(feed) - 1)
            if n > reuse and feed[: len(prefix.tokens)] == list(prefix.tokens):
                best, reuse = prefix, n
        return best, reuse

    # ------------------------------------------------------------------
    # paged bookkeeping
    # ------------------------------------------------------------------
    def _set_lane_pages(self, lane: int, pages: list) -> None:
        self.page_tables[lane] = pages
        self._bt[lane, :] = 0
        self._bt[lane, : len(pages)] = pages

    def _copy_page(self, src: int, dst: int) -> None:
        """Device-side page copy (all layers): the CoW step of a fork."""
        self.cache = self._copy_page_fn(
            self.cache, jnp.asarray(src, jnp.int32), jnp.asarray(dst, jnp.int32)
        )

    def _release_lane(self, lane: int) -> None:
        """Return a lane's pages to the pool and pad the lane out."""
        if self.paged:
            self.allocator.free(self.page_tables[lane])
            self._set_lane_pages(lane, [])
        self.slots[lane] = None
        self.pos = self.pos.at[lane].set(self._pad_pos if self.paged else 0)

    def _try_admit_paged(self, lane: int, session: Session) -> Optional[tuple]:
        """Build the lane's page table for ``session`` (sharing a registered
        prefix when one matches); returns the prefill assignment or None if
        the pool cannot hold the request right now."""
        feed = session.prompt + session.out  # out non-empty: preempted resume
        ps = self.cfg.page_size
        n_t = self.allocator.pages_for(len(feed))
        prefix, reuse = self._fork_plan(feed)
        m = reuse // ps  # fully-shared pages (never written by this lane)
        cow = reuse % ps != 0  # boundary page: preserved KV + this lane's writes
        if not self.allocator.can_alloc(n_t - m):
            return None
        fresh = self.allocator.alloc(n_t - m)
        shared = prefix.pages[:m] if prefix is not None else []
        if shared:
            self.allocator.share(shared)
        self._set_lane_pages(lane, shared + fresh)
        if cow:
            # copy-on-write: page m holds prefix KV at positions
            # [m*ps, reuse) that this lane reuses but must not share,
            # because its own writes start inside the same page
            self._copy_page(prefix.pages[m], fresh[0])
        if prefix is not None and reuse:
            prefix.hits += 1
            self.metrics.record_prefix_hit(reuse)
        return (lane, session, feed, reuse if prefix is not None else 0)

    def _pick_victim(self, exclude: int) -> Optional[int]:
        """Preemption victim: lowest priority, then latest admitted."""
        candidates = [
            (s.priority, -(s.stats.admitted_at or 0.0), i)
            for i, s in enumerate(self.slots)
            if s is not None and i != exclude
        ]
        if not candidates:
            return None
        return min(candidates)[2]

    def _preempt(self, lane: int) -> None:
        """Recompute preemption: evict the lane, free its pages, and
        re-queue the session.  On re-admission the engine replays
        prompt+output through prefill, which reconstructs the KV exactly —
        the stream resumes with no lost or corrupted tokens."""
        session = self.slots[lane]
        self._release_lane(lane)
        session.stats.preemptions += 1
        self.metrics.record_preemption()
        self.requeue(session)

    def _grow_lane(self, lane: int) -> bool:
        """Ensure the lane owns the page its next KV write lands in,
        preempting other lanes (or, last resort, this one) when the pool is
        exhausted.  Returns False if the lane itself was evicted."""
        ps = self.cfg.page_size
        while len(self.page_tables[lane]) < self._lane_pos[lane] // ps + 1:
            while not self.allocator.can_alloc(1):
                victim = self._pick_victim(exclude=lane)
                if victim is None:
                    self._preempt(lane)
                    return False
                self._preempt(victim)
            page = self.allocator.alloc(1)[0]
            pages = self.page_tables[lane]
            self._set_lane_pages(lane, pages + [page])
        return True

    # ------------------------------------------------------------------
    def _finalize(self, lane: int, session: Session, reason: str) -> None:
        session._finish(reason)
        self.metrics.record_finished(session)
        self.finished.append(session)
        self._release_lane(lane)

    def _finish_reason(self, lane: int, session: Session, token: int) -> str:
        if self.cfg.eos_id is not None and token == self.cfg.eos_id:
            return FINISH_EOS
        if len(session.out) >= session.max_new_tokens:
            return FINISH_MAX_NEW_TOKENS
        if self._lane_pos[lane] >= self.cfg.max_len:
            return FINISH_MAX_LEN  # cache exhausted: nowhere to write the next KV
        return ""

    def _release_cancelled(self) -> None:
        for i, s in enumerate(self.slots):
            if s is not None and s.cancel_requested:
                self._finalize(i, s, FINISH_CANCELLED)

    def _expire_deadlines(self) -> None:
        """Finish in-flight sessions whose wall-clock deadline passed (their
        partial output stays on the handle)."""
        now = time.perf_counter()
        for i, s in enumerate(self.slots):
            if s is not None and s.deadline_expired(now):
                self._finalize(i, s, FINISH_DEADLINE)

    def _quarantine_lane(self, lane: int, session: Session) -> None:
        """NaN-guard response: bench the lane, retry the session elsewhere.

        The poisoned tick's token is never recorded, so the retried session
        replays prompt+output through prefill and resumes token-exact.  The
        lane's pages return to the pool immediately (every KV position is
        rewritten before it is read on re-admission, so a poisoned write
        cannot leak), but the lane itself sits out ``quarantine_ticks``.
        """
        self._release_lane(lane)
        self._quarantined[lane] = self.tick + self.cfg.quarantine_ticks
        self.metrics.record_nan_event()
        self.metrics.record_quarantine()
        self.requeue(session)

    def _admit(self) -> list:
        """Claim free non-quarantined slots for scheduler-selected sessions.

        In paged mode admission is additionally page-aware: a selected
        session that does not fit in the pool right now is re-queued via
        ``scheduler.submit`` (for the stock policies this re-appends it, so
        strict arrival order is traded for progress of smaller requests —
        see docs/serving.md#admission; such waits do not touch the retry
        budget).  Selected sessions that were cancelled while queued finish
        as ``cancelled``, ones whose deadline already passed finish as
        ``deadline``, and ones still inside their requeue backoff window go
        back to the queue untouched.
        """
        free = [
            i for i, s in enumerate(self.slots)
            if s is None and self._quarantined.get(i, 0) <= self.tick
        ]
        if not free:
            return []
        picked = self.scheduler.select(len(free), self.cfg.n_slots)
        if len(picked) > len(free):
            raise RuntimeError(
                f"scheduler returned {len(picked)} sessions for {len(free)} free slots"
            )
        now = time.perf_counter()
        assignments = []
        for session in picked:
            if session.done:  # e.g. cancelled-in-queue under a custom policy
                continue
            if session.cancel_requested:
                session._finish(FINISH_CANCELLED)
                self._record_queued_cancel(session)
                continue
            if session.deadline_expired(now):
                # expires without ever occupying a lane — same accounting
                # as a queued cancel, but reason="deadline"
                session._finish(FINISH_DEADLINE, now=now)
                self.metrics.record_finished(session)
                self.finished.append(session)
                continue
            if session._backoff_until > self.tick:
                self.scheduler.submit(session)  # backoff: not eligible yet
                continue
            lane = free[0]
            if self.paged:
                plan = self._try_admit_paged(lane, session)
                if plan is None:  # pool full: wait without losing the request
                    self.scheduler.submit(session)
                    continue
            else:
                plan = (lane, session, session.prompt + session.out, 0)
            free.pop(0)
            session.status = PREFILL
            session.stats.admitted_at = now
            self.slots[lane] = session
            assignments.append(plan)
        return assignments

    # ------------------------------------------------------------------
    def _prefill(self, assignments: list) -> None:
        """Chunked batched prefill: every admitted prompt advances through
        the same compiled ``decode_chunk`` call, ``prefill_chunk`` tokens per
        step.  Lanes not being prefilled carry the pad position sentinel,
        which writes nothing — mid-generation neighbours are untouched.

        Each assignment is ``(lane, session, feed, start)``: ``feed`` is the
        token stream whose KV the lane must hold (prompt, plus prior output
        for preemption resumes) and ``start`` is the first position actually
        fed — positions below it come from shared prefix pages.
        """
        t0 = time.perf_counter()
        n_slots, chunk = self.cfg.n_slots, self.cfg.prefill_chunk
        spans = {lane: len(feed) - start for lane, _, feed, start in assignments}
        longest = max(spans.values())
        n_chunks = -(-longest // chunk)
        toks = np.zeros((n_slots, n_chunks * chunk), np.int32)
        poss = np.full((n_slots, n_chunks * chunk), self._pad_pos, np.int32)
        for lane, _, feed, start in assignments:
            n = len(feed) - start
            toks[lane, :n] = feed[start:]
            poss[lane, :n] = np.arange(start, len(feed), dtype=np.int32)
        bt_args = (jnp.asarray(self._bt),) if self.paged else ()
        for c in range(n_chunks):
            sl = slice(c * chunk, (c + 1) * chunk)
            logits, self.cache = self._call_compiled(
                "chunk", self.params, self.cache, *bt_args,
                jnp.asarray(toks[:, sl]), jnp.asarray(poss[:, sl]),
            )
            ending = [
                (lane, s, feed) for lane, s, feed, start in assignments
                if c * chunk < len(feed) - start <= (c + 1) * chunk
            ]
            for lane, s, feed in ending:
                row = logits[lane, spans[lane] - 1 - c * chunk]
                if self.cfg.nan_guard and not bool(jnp.all(jnp.isfinite(row))):
                    if self._nan_attr_tick != self.tick:
                        self._nan_attr_tick = self.tick
                        self._guard_attribute(
                            RuntimeError(f"non-finite prefill logits on lane {lane}")
                        )
                    self._quarantine_lane(lane, s)  # retry the session whole
                    continue
                tok = int(self.cfg.sampler(row))
                s.status = ACTIVE
                self.last_token = self.last_token.at[lane].set(tok)
                self.pos = self.pos.at[lane].set(len(feed))
                self._lane_pos[lane] = len(feed)
                s._record_token(tok)  # TTFT stamps here (first admission only)
                reason = self._finish_reason(lane, s, tok)
                if reason:
                    self._finalize(lane, s, reason)
        self.metrics.record_prefill(
            (time.perf_counter() - t0) * self.step_time_scale,
            sum(spans.values()), len(assignments),
        )

    # ------------------------------------------------------------------
    def step(self) -> None:
        """One engine tick: release cancellations, expire deadlines, admit +
        prefill, grow pages (preempting if the pool is dry), decode.

        Raises :class:`ReplicaCrashed` — before any state mutates — while
        the ``crashed`` fault flag is set.  Recorded step times are scaled
        by ``step_time_scale`` (the straggler-fault surface: a throttled
        replica reports dilated ticks without actually sleeping).
        """
        if self.crashed:
            raise ReplicaCrashed(
                f"engine is crashed (fault-injected); tick {self.tick}"
            )
        t_step0 = time.perf_counter()
        self.tick += 1
        if self._op_quarantine:  # quarantined kernel ops due for a re-probe
            self._heal_ops()
        if self._quarantined:  # lanes whose bench time has elapsed come back
            self._quarantined = {
                lane: t for lane, t in self._quarantined.items() if t > self.tick
            }
        self._release_cancelled()
        self._expire_deadlines()
        admitted = self._admit()
        if admitted:
            self._prefill(admitted)
        if self.paged:
            for lane in range(self.cfg.n_slots):
                if self.slots[lane] is not None:
                    self._grow_lane(lane)
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            self.last_step_s = (time.perf_counter() - t_step0) * self.step_time_scale
            self.last_step_recompiled, self._recompiled = self._recompiled, False
            return
        t0 = time.perf_counter()
        bt_args = (jnp.asarray(self._bt),) if self.paged else ()
        logits, self.cache = self._call_compiled(
            "decode", self.params, self.cache, *bt_args, self.last_token, self.pos
        )
        if self._inject_nan_lanes:  # fault surface: poison the real logits
            for lane in sorted(self._inject_nan_lanes):
                if 0 <= lane < self.cfg.n_slots:
                    logits = logits.at[lane].set(jnp.nan)
        bad = []
        if self.cfg.nan_guard:
            finite = np.asarray(jnp.all(jnp.isfinite(logits), axis=-1))
            bad = [i for i in active if not finite[i]]
        if bad and self._nan_attr_tick != self.tick:
            # a kernel op emitting non-finite values shows up in its probe:
            # quarantine it per-op (the lanes still retry below either way)
            self._nan_attr_tick = self.tick
            self._guard_attribute(
                RuntimeError(f"non-finite decode logits on lane(s) {bad}")
            )
        next_tok = self.cfg.sampler(logits)
        jax.block_until_ready(next_tok)
        t_decode = time.perf_counter() - t0
        for i in bad:  # quarantine before pos advances: the lane pads out
            self._quarantine_lane(i, self.slots[i])
        ok = [i for i in active if i not in bad]
        self.last_token = next_tok
        # pad lanes must stay at the sentinel (a pad-lane write would land in
        # pool pages someone else owns); surviving active lanes advance by one
        if self.paged:
            adv = jnp.zeros((self.cfg.n_slots,), jnp.int32)
            for i in ok:
                adv = adv.at[i].set(1)
            self.pos = self.pos + adv
        else:
            self.pos = self.pos + 1
        toks = np.asarray(next_tok)
        for i in ok:
            s = self.slots[i]
            self._lane_pos[i] += 1
            s._record_token(int(toks[i]))
            reason = self._finish_reason(i, s, int(toks[i]))
            if reason:
                self._finalize(i, s, reason)
        scale = self.step_time_scale
        self.metrics.record_tick(
            (time.perf_counter() - t0) * scale, t_decode * scale, len(active)
        )
        if self.paged:
            self.metrics.record_pages(self.allocator.used)
        self.last_step_s = (time.perf_counter() - t_step0) * scale
        self.last_step_recompiled, self._recompiled = self._recompiled, False

    # ------------------------------------------------------------------
    def has_work(self) -> bool:
        return any(s is not None for s in self.slots) or self.scheduler.pending() > 0

    def run(self, max_ticks: int = 10_000) -> list:
        """Drive until drained (or ``max_ticks``); returns finished sessions
        (cancelled ones included, ``finish_reason == "cancelled"``).

        Exhausting the tick budget with work still pending is surfaced — a
        ``RuntimeWarning`` plus the ``tick_budget_exhausted`` metrics counter
        — instead of returning silently with sessions stranded in flight.
        """
        ticks = 0
        while self.has_work() and ticks < max_ticks:
            self.step()
            ticks += 1
        if self.has_work():
            self.metrics.record_tick_budget_exhausted()
            warnings.warn(
                f"run(max_ticks={max_ticks}) stopped with work still pending "
                f"({sum(s is not None for s in self.slots)} active lane(s), "
                f"{self.scheduler.pending()} queued)",
                RuntimeWarning,
                stacklevel=2,
            )
        return self.finished

    def drain(self) -> list:
        """Evict every in-flight and queued session, with output intact.

        Slot lanes are released (paged lanes return their pages) and every
        live session — running or queued — comes back in ``QUEUED`` state.
        Only slot-drained sessions count a preemption: they lose in-flight
        lane state and must replay through prefill, while queue-drained ones
        never held a lane and re-enter exactly as they were.  Because a
        re-admitted session replays prompt+output through prefill (the
        recompute-preemption invariant), the returned sessions can be
        re-submitted to any engine over the same params and resume
        token-exact.  This is the replica-failure path of
        :class:`~repro.serve.cluster.ClusterRouter`.
        """
        drained = []
        for lane, session in enumerate(self.slots):
            if session is not None:
                self._release_lane(lane)
                session.status = QUEUED
                session.stats.preemptions += 1  # evicted mid-flight, will resume
                drained.append(session)
        # Empty the queue via the scheduler's optional drain() extension;
        # otherwise pull through select with n_free clamped up to n_slots so
        # batch-boundary policies release too.  A custom policy that still
        # withholds sessions while claiming pending work would loop forever,
        # so stop when select comes back empty (tested: a withholding
        # scheduler strands its queue but drain() itself must terminate).
        drainer = getattr(self.scheduler, "drain", None)
        if drainer is not None:
            queued = list(drainer())
        else:
            queued = []
            while self.scheduler.pending() > 0:
                batch = self.scheduler.select(
                    max(self.scheduler.pending(), self.cfg.n_slots), self.cfg.n_slots
                )
                if not batch:
                    break
                queued.extend(batch)
        for session in queued:
            session.status = QUEUED  # no lane lost: not a preemption
            drained.append(session)
        return drained

    def summary(self) -> dict:
        return self.metrics.summary()

    def reset_metrics(self) -> None:
        """Discard accumulated telemetry and the finished list (keeps the
        compiled steps warm) — call after a warm-up pass so one-time
        compilation stays out of the measured TTFT/latency records."""
        self.metrics = EngineMetrics(self.cfg.n_slots, n_pages=self.n_pages)
        self.finished = []
        self._guard_calls = 0
        self._injected_drift_calls = 0
