"""Pluggable batched serving engine.

One compiled fixed-shape decode step serves all slots every tick; admission
between ticks is delegated to a swappable :class:`~repro.serve.scheduler.
Scheduler`; prompt ingestion runs as *chunked batched prefill* — one compiled
``ModelApi.decode_chunk`` call per chunk, shared across every slot admitted
that tick — replacing the old per-token Python loop.  Every tick is measured
into :class:`~repro.serve.metrics.EngineMetrics` and the compiled steps trace
under the :class:`EngineConfig`'s kernel-policy backend, so one engine
definition runs the pallas / interpret / xla paths side by side.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.api import BACKENDS, kernel_policy
from repro.models.api import ModelApi

from .metrics import EngineMetrics
from .sampler import greedy
from .scheduler import Scheduler, make_scheduler
from .session import (
    ACTIVE,
    FINISH_CANCELLED,
    FINISH_EOS,
    FINISH_MAX_LEN,
    FINISH_MAX_NEW_TOKENS,
    PREFILL,
    Session,
)


@dataclass(frozen=True)
class EngineConfig:
    """Engine-level knobs, separated from the model definition.

    ``backend``/``autotune`` scope a ``kernel_policy`` around the engine's
    compiled steps (applied at trace time), so the same engine definition can
    run every kernel path of a model whose config selects kernel-routed
    implementations (``attn_impl="pallas"``, ``ssm_impl="pallas"``).
    """

    n_slots: int
    max_len: int
    prefill_chunk: int = 16  # tokens per compiled prefill step
    backend: Optional[str] = None  # kernel_policy backend (None: ambient)
    # kernel_policy autotune for engine steps (None: ambient; bool: forced)
    autotune: Optional[bool] = None
    eos_id: Optional[int] = None
    sampler: Callable = greedy
    scheduler: str = "fcfs"  # default policy when none is injected

    def __post_init__(self):
        if self.n_slots < 1:
            raise ValueError("n_slots must be >= 1")
        if self.max_len < 2:
            raise ValueError("max_len must be >= 2 (prompt + one generated token)")
        if self.prefill_chunk < 1:
            raise ValueError("prefill_chunk must be >= 1")
        if self.backend is not None and self.backend not in BACKENDS:
            raise ValueError(f"unknown backend {self.backend!r}; expected {BACKENDS}")


class ServeEngine:
    """Continuous-batching engine over a fixed slot grid.

    ``scheduler`` accepts any :class:`Scheduler` implementation (defaults to
    the config's named stock policy); ``submit`` returns a streaming
    :class:`Session` handle with per-token callbacks, cancellation, and
    request stats.
    """

    def __init__(self, model: ModelApi, params, config: EngineConfig,
                 scheduler: Optional[Scheduler] = None):
        if model.decode_chunk is None:
            raise NotImplementedError(
                f"family {model.cfg.family!r} has no decode_chunk: recurrent "
                "per-lane state cannot yet advance independently inside a "
                "shared batch; serving currently targets the attention-cache "
                "families (dense/moe/vlm)"
            )
        self.model = model
        self.params = params
        self.cfg = config
        self.scheduler = scheduler if scheduler is not None else make_scheduler(config.scheduler)
        if not isinstance(self.scheduler, Scheduler):
            raise TypeError(
                f"scheduler {type(self.scheduler).__name__} does not implement "
                "the Scheduler protocol (submit/select/pending)"
            )
        self.metrics = EngineMetrics(config.n_slots)
        self.slots: list = [None] * config.n_slots
        self.finished: list = []
        self.cache = model.init_cache(config.n_slots, config.max_len)
        self.last_token = jnp.zeros((config.n_slots,), jnp.int32)
        self.pos = jnp.zeros((config.n_slots,), jnp.int32)
        self._lane_pos = [0] * config.n_slots  # host mirror: next cache index
        self._decode = self._jit_scoped(model.decode_step)
        self._chunk = self._jit_scoped(model.decode_chunk)
        self._rid = 0

    # ------------------------------------------------------------------
    def _jit_scoped(self, fn: Callable) -> Callable:
        """jit ``fn`` so it traces under the config's kernel policy.

        With a policy set, jit a per-engine closure (not ``fn`` itself):
        jax's trace cache is keyed on function identity, not on the policy
        contextvar, so jitting the shared ``model.decode_*`` directly would
        let a second engine with a different backend silently reuse the
        first engine's trace."""
        if self.cfg.backend is None and self.cfg.autotune is None:
            return jax.jit(fn)
        backend, autotune = self.cfg.backend, self.cfg.autotune

        def scoped(*args):  # fresh object per engine -> own trace cache
            with kernel_policy(backend=backend, autotune=autotune):
                return fn(*args)

        return jax.jit(scoped)

    # ------------------------------------------------------------------
    def submit(self, prompt, max_new_tokens: int, *, priority: int = 0,
               on_token: Optional[Callable] = None) -> Session:
        """Queue a request; returns its streaming :class:`Session` handle."""
        prompt = [int(t) for t in prompt]
        if not prompt:
            raise ValueError("empty prompt")
        if len(prompt) >= self.cfg.max_len:
            raise ValueError(
                f"prompt length {len(prompt)} must be < max_len "
                f"{self.cfg.max_len} (no room to generate)"
            )
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        session = Session(self._rid, prompt, max_new_tokens,
                          priority=priority, on_token=on_token)
        session.stats.submitted_at = time.perf_counter()
        session._on_queued_cancel = self._record_queued_cancel
        self._rid += 1
        self.scheduler.submit(session)
        return session

    def _record_queued_cancel(self, session: Session) -> None:
        """Queued-cancel accounting: the session never occupies a slot, but
        it must still show up in metrics and the finished list."""
        self.metrics.record_finished(session)
        self.finished.append(session)

    def cancel(self, session: Session) -> None:
        """Alias for ``session.cancel()`` (kept for symmetry with submit)."""
        session.cancel()

    # ------------------------------------------------------------------
    def _finalize(self, lane: int, session: Session, reason: str) -> None:
        session._finish(reason)
        self.metrics.record_finished(session)
        self.finished.append(session)
        self.slots[lane] = None

    def _finish_reason(self, lane: int, session: Session, token: int) -> str:
        if self.cfg.eos_id is not None and token == self.cfg.eos_id:
            return FINISH_EOS
        if len(session.out) >= session.max_new_tokens:
            return FINISH_MAX_NEW_TOKENS
        if self._lane_pos[lane] >= self.cfg.max_len:
            return FINISH_MAX_LEN  # cache exhausted: nowhere to write the next KV
        return ""

    def _release_cancelled(self) -> None:
        for i, s in enumerate(self.slots):
            if s is not None and s.cancel_requested:
                self._finalize(i, s, FINISH_CANCELLED)

    def _admit(self) -> list:
        free = [i for i, s in enumerate(self.slots) if s is None]
        if not free:
            return []
        picked = self.scheduler.select(len(free), self.cfg.n_slots)
        if len(picked) > len(free):
            raise RuntimeError(
                f"scheduler returned {len(picked)} sessions for {len(free)} free slots"
            )
        now = time.perf_counter()
        assignments = []
        for lane, session in zip(free, picked):
            session.status = PREFILL
            session.stats.admitted_at = now
            self.slots[lane] = session
            assignments.append((lane, session))
        return assignments

    # ------------------------------------------------------------------
    def _prefill(self, assignments: list) -> None:
        """Chunked batched prefill: every admitted prompt advances through
        the same compiled ``decode_chunk`` call, ``prefill_chunk`` tokens per
        step.  Lanes not being prefilled carry the pad position (== max_len),
        which writes nothing — mid-generation neighbours are untouched."""
        t0 = time.perf_counter()
        n_slots, ml, chunk = self.cfg.n_slots, self.cfg.max_len, self.cfg.prefill_chunk
        longest = max(len(s.prompt) for _, s in assignments)
        n_chunks = -(-longest // chunk)
        toks = np.zeros((n_slots, n_chunks * chunk), np.int32)
        poss = np.full((n_slots, n_chunks * chunk), ml, np.int32)
        for lane, s in assignments:
            ln = len(s.prompt)
            toks[lane, :ln] = s.prompt
            poss[lane, :ln] = np.arange(ln, dtype=np.int32)
        for c in range(n_chunks):
            sl = slice(c * chunk, (c + 1) * chunk)
            logits, self.cache = self._chunk(
                self.params, self.cache, jnp.asarray(toks[:, sl]), jnp.asarray(poss[:, sl])
            )
            ending = [
                (lane, s) for lane, s in assignments
                if c * chunk < len(s.prompt) <= (c + 1) * chunk
            ]
            for lane, s in ending:
                row = logits[lane, len(s.prompt) - 1 - c * chunk]
                tok = int(self.cfg.sampler(row))
                s.status = ACTIVE
                self.last_token = self.last_token.at[lane].set(tok)
                self.pos = self.pos.at[lane].set(len(s.prompt))
                self._lane_pos[lane] = len(s.prompt)
                s._record_token(tok)  # TTFT stamps here
                reason = self._finish_reason(lane, s, tok)
                if reason:
                    self._finalize(lane, s, reason)
        self.metrics.record_prefill(
            time.perf_counter() - t0,
            sum(len(s.prompt) for _, s in assignments),
            len(assignments),
        )

    # ------------------------------------------------------------------
    def step(self) -> None:
        """One engine tick: release cancellations, admit + prefill, decode."""
        self._release_cancelled()
        admitted = self._admit()
        if admitted:
            self._prefill(admitted)
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return
        t0 = time.perf_counter()
        logits, self.cache = self._decode(
            self.params, self.cache, self.last_token, self.pos
        )
        next_tok = self.cfg.sampler(logits)
        jax.block_until_ready(next_tok)
        t_decode = time.perf_counter() - t0
        self.last_token = next_tok
        self.pos = self.pos + 1
        toks = np.asarray(next_tok)
        for i in active:
            s = self.slots[i]
            self._lane_pos[i] += 1
            s._record_token(int(toks[i]))
            reason = self._finish_reason(i, s, int(toks[i]))
            if reason:
                self._finalize(i, s, reason)
        self.metrics.record_tick(time.perf_counter() - t0, t_decode, len(active))

    # ------------------------------------------------------------------
    def has_work(self) -> bool:
        return any(s is not None for s in self.slots) or self.scheduler.pending() > 0

    def run(self, max_ticks: int = 10_000) -> list:
        """Drive until drained (or ``max_ticks``); returns finished sessions
        (cancelled ones included, ``finish_reason == "cancelled"``)."""
        ticks = 0
        while self.has_work() and ticks < max_ticks:
            self.step()
            ticks += 1
        return self.finished

    def summary(self) -> dict:
        return self.metrics.summary()

    def reset_metrics(self) -> None:
        """Discard accumulated telemetry and the finished list (keeps the
        compiled steps warm) — call after a warm-up pass so one-time
        compilation stays out of the measured TTFT/latency records."""
        self.metrics = EngineMetrics(self.cfg.n_slots)
        self.finished = []
