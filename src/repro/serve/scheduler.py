"""Admission scheduling behind a small protocol.

The engine owns the slots and the compiled steps; a :class:`Scheduler` owns
only the *order* in which queued sessions claim free slots.  Any object with
``submit`` / ``select`` / ``pending`` plugs in — the stock policies:

- :class:`FCFSScheduler`        arrival order, admit the moment a slot frees
  (continuous batching at step granularity — the default),
- :class:`PriorityScheduler`    highest ``Session.priority`` first (FIFO
  within a priority class), still continuous,
- :class:`StaticBatchScheduler` admit only into an idle engine (classic
  static batching — the measured contrast to continuous admission).

A paged engine also *re-submits* sessions through ``submit``: a selected
session that does not currently fit in the page pool goes back in the queue,
and a preempted session re-enters with its partial output attached.  Stock
policies treat a re-submission like a fresh arrival (appended / re-heaped);
custom schedulers that care about fairness can inspect
``session.stats.preemptions`` or ``session.out`` to prioritise resumes.
"""
from __future__ import annotations

import heapq
from collections import deque
from typing import Protocol, runtime_checkable

from .session import Session


@runtime_checkable
class Scheduler(Protocol):
    """Admission policy: queue sessions, pick which claim free slots."""

    def submit(self, session: Session) -> None:
        """Enqueue a new session."""
        ...

    def select(self, n_free: int, n_slots: int) -> list:
        """Up to ``n_free`` sessions to admit now (``n_slots`` is the engine's
        total slot count, for policies that act on batch boundaries).  Must
        never return cancelled/done sessions."""
        ...

    def pending(self) -> int:
        """Number of live queued sessions."""
        ...

    # Optional extension (not part of the minimal protocol): ``drain() ->
    # list[Session]`` returns every live queued session and empties the
    # queue.  ``ServeEngine.drain`` — the cluster's replica-failure path —
    # uses it when present and otherwise falls back to pulling the queue
    # through ``select``, so custom schedulers only need it if their
    # ``select`` withholds sessions (e.g. batch-boundary policies).


class FCFSScheduler:
    """First-come-first-served continuous batching."""

    def __init__(self):
        self._queue: deque = deque()

    def submit(self, session: Session) -> None:
        self._queue.append(session)

    def drain(self) -> list:
        out = [s for s in self._queue if not s.done]
        self._queue.clear()
        return out

    def _prune(self) -> None:
        while self._queue and self._queue[0].done:
            self._queue.popleft()

    def select(self, n_free: int, n_slots: int) -> list:
        out = []
        self._prune()
        while self._queue and len(out) < n_free:
            out.append(self._queue.popleft())
            self._prune()
        return out

    def pending(self) -> int:
        return sum(1 for s in self._queue if not s.done)


class PriorityScheduler:
    """Highest ``Session.priority`` first; FIFO within a priority class."""

    def __init__(self):
        self._heap: list = []
        self._seq = 0

    def submit(self, session: Session) -> None:
        heapq.heappush(self._heap, (-session.priority, self._seq, session))
        self._seq += 1

    def drain(self) -> list:
        out = [s for _, _, s in sorted(self._heap) if not s.done]
        self._heap.clear()
        return out

    def select(self, n_free: int, n_slots: int) -> list:
        out = []
        while self._heap and len(out) < n_free:
            _, _, s = heapq.heappop(self._heap)
            if not s.done:
                out.append(s)
        return out

    def pending(self) -> int:
        return sum(1 for _, _, s in self._heap if not s.done)


class StaticBatchScheduler(FCFSScheduler):
    """Admit only when the engine is fully idle: requests are served in
    drained batches (the non-continuous baseline the bench suite contrasts
    against)."""

    def select(self, n_free: int, n_slots: int) -> list:
        if n_free < n_slots:
            return []
        return super().select(n_free, n_slots)


SCHEDULERS = {
    "fcfs": FCFSScheduler,
    "priority": PriorityScheduler,
    "static": StaticBatchScheduler,
}


def make_scheduler(name: str) -> Scheduler:
    try:
        return SCHEDULERS[name]()
    except KeyError:
        raise ValueError(
            f"unknown scheduler {name!r}; registered: {sorted(SCHEDULERS)}"
        ) from None
