"""repro.dist — mesh/sharding/ZeRO/compression/pipeline distribution layer.

- ``sharding``  logical activation axes + name-based parameter specs
- ``zero``      ZeRO-1/2/3 state partitioning over the data axis
- ``compress``  int8 gradient compression for cross-pod links
- ``pipeline``  GPipe microbatch pipelining over a mesh axis
"""
from . import _compat  # noqa: F401  (installs jax.shard_map on old jax)
from .compress import dequantize_int8, psum_compressed, quantize_int8
from .pipeline import gpipe_apply
from .sharding import (
    activation_sharding,
    batch_shardings,
    cache_shardings,
    logits_sharding,
    param_specs,
    shard_act,
    shard_params,
)
from .zero import zero1_state_specs

__all__ = [
    "activation_sharding",
    "batch_shardings",
    "cache_shardings",
    "dequantize_int8",
    "gpipe_apply",
    "logits_sharding",
    "param_specs",
    "psum_compressed",
    "quantize_int8",
    "shard_act",
    "shard_params",
    "zero1_state_specs",
]
