"""Gradient compression for slow (cross-pod DCI) links.

int8 symmetric quantization with a per-tensor scale; ``psum_compressed``
implements an all-reduce that ships int8 payloads + one f32 scale per
participant (an 'all-gather quantized, reduce locally' schedule — the sum of
dequantized terms, so the result is exact up to per-sender rounding).  Error
feedback is left to the caller: quantize ``g + err`` and carry
``err = (g + err) - dequant`` (see tests for the canonical loop).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8: returns (q, scale) with x ~= q * scale."""
    s = jnp.max(jnp.abs(x)).astype(jnp.float32) / 127.0
    s = jnp.maximum(s, jnp.float32(1e-30))  # zero tensors stay zero
    q = jnp.clip(jnp.round(x / s), -127, 127).astype(jnp.int8)
    return q, s


def dequantize_int8(q: jax.Array, s: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * s


def psum_compressed(x: jax.Array, axis_name: str, mode: str = "int8") -> jax.Array:
    """All-reduce over ``axis_name`` with compressed payload.

    ``mode="none"`` falls back to an exact psum.  Must be called inside a
    ``shard_map``/collective context where ``axis_name`` is bound.
    """
    if mode in (None, "none"):
        return jax.lax.psum(x, axis_name)
    if mode != "int8":
        raise ValueError(f"unknown compression mode {mode!r}")
    q, s = quantize_int8(x)
    qg = jax.lax.all_gather(q, axis_name)  # (N, *x.shape) int8
    sg = jax.lax.all_gather(s, axis_name)  # (N,) f32
    deq = qg.astype(jnp.float32) * sg.reshape((-1,) + (1,) * q.ndim)
    return jnp.sum(deq, axis=0).astype(x.dtype)
