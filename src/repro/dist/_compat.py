"""JAX version compatibility for the distribution layer.

The repo targets the modern ``jax.shard_map`` entry point (promoted out of
``jax.experimental`` in newer releases).  On older installs it only exists at
``jax.experimental.shard_map.shard_map`` with the same keyword signature, so
we re-export it here and — mirroring the upstream promotion — install it onto
the ``jax`` namespace when the installed version predates it.  Callers (and
tests) can then use ``jax.shard_map`` uniformly.
"""
from __future__ import annotations

import jax

try:
    shard_map = jax.shard_map
except AttributeError:
    from jax.experimental.shard_map import shard_map

    jax.shard_map = shard_map

__all__ = ["shard_map"]
