"""Sharding rules: logical activation axes and name-based parameter specs.

Two layers of API:

- **Activation pinning** (used inside model code): ``shard_act(x, "dp", "sp",
  None)`` constrains an activation with logical axes — ``dp`` (batch, maps to
  the ``pod``+``data`` mesh axes), ``sp`` (sequence parallel, maps to
  ``model``), ``tp`` (tensor parallel, maps to ``model``).  Outside an
  ``activation_sharding(mesh)`` context (single-device tests, examples) both
  ``shard_act`` and ``shard_params`` are identity functions, so models run
  unmodified without a mesh.

- **Parameter specs** (used by the dry-run/launch layer): ``param_specs``
  walks a parameter tree and assigns Megatron-style tensor-parallel specs by
  leaf path: vocab-sharded embedding/lm_head, head-sharded wq/wk/wv,
  row-parallel attention/MLP ``wo``, column-parallel ``wi*``, expert- or
  ffn-sharded MoE weights (``cfg.moe_shard``).  Scanned layer stacks (extra
  leading layer dim) are handled by right-aligning the core spec.

Every axis assignment is divisibility-guarded: a dim that doesn't divide the
mesh axis stays replicated, so reduced CI configs compile on small meshes.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from math import prod

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import _compat  # noqa: F401  (installs jax.shard_map on old jax)

_ctx = threading.local()


def current_mesh() -> Mesh | None:
    return getattr(_ctx, "mesh", None)


@contextmanager
def activation_sharding(mesh: Mesh):
    """Enable ``shard_act``/``shard_params`` constraints while tracing."""
    prev = getattr(_ctx, "mesh", None)
    _ctx.mesh = mesh
    try:
        yield mesh
    finally:
        _ctx.mesh = prev


# ---------------------------------------------------------------------------
# logical -> mesh axis resolution
# ---------------------------------------------------------------------------
def _dp_axes(mesh: Mesh):
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    if not axes:
        return None
    return axes if len(axes) > 1 else axes[0]


def _resolve(mesh: Mesh, logical: str | None):
    if logical is None:
        return None
    if logical == "dp":
        return _dp_axes(mesh)
    if logical in ("tp", "sp", "ep"):
        return "model" if "model" in mesh.shape else None
    raise ValueError(f"unknown logical axis {logical!r}")


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return prod(mesh.shape[a] for a in axis)
    return mesh.shape.get(axis, 1)


def _guarded_spec(mesh: Mesh, shape, axes) -> P:
    """Drop any axis assignment whose mesh size doesn't divide the dim."""
    spec = []
    for dim, ax in zip(shape, axes):
        size = _axis_size(mesh, ax)
        spec.append(ax if ax is not None and size > 1 and dim % size == 0 else None)
    return P(*spec)


# ---------------------------------------------------------------------------
# activation pinning
# ---------------------------------------------------------------------------
def shard_act(x: jax.Array, *logical: str | None) -> jax.Array:
    """Constrain ``x`` with logical axes; identity outside a mesh context."""
    mesh = current_mesh()
    if mesh is None:
        return x
    axes = tuple(_resolve(mesh, l) for l in logical) + (None,) * (x.ndim - len(logical))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, _guarded_spec(mesh, x.shape, axes))
    )


def shard_params(tree, cfg):
    """Pin a (layer) parameter subtree to its rule-derived specs.

    Used inside scanned layer bodies so the sliced layer params — and hence
    their gradients — keep the tensor-parallel layout. Identity without mesh.
    """
    mesh = current_mesh()
    if mesh is None:
        return tree

    def pin(path, leaf):
        axes = _param_axes(_path_str(path), leaf.ndim, cfg)
        return jax.lax.with_sharding_constraint(
            leaf, NamedSharding(mesh, _guarded_spec(mesh, leaf.shape, axes))
        )

    return jax.tree_util.tree_map_with_path(pin, tree)


# ---------------------------------------------------------------------------
# parameter rules
# ---------------------------------------------------------------------------
def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", p)) for p in path)


def _core_spec(path: str, cfg) -> tuple:
    """Tensor-parallel spec for a leaf's trailing 'core' dims, by name."""
    if "router" in path:
        return (None, None)  # (d, E): routing probs need the full expert set
    if "moe" in path:  # expert weights (E, d, f) / (E, f, d)
        if getattr(cfg, "moe_shard", "expert") == "expert":
            return ("model", None, None)  # expert parallel
        if "wo" in path:
            return (None, "model", None)  # TP inside each expert, row-parallel
        return (None, None, "model")
    if "embed" in path:
        return ("model", None)  # (V, d) vocab-sharded
    if "lm_head" in path or "unembed" in path:
        return (None, "model")  # (d, V) vocab-sharded logits
    if "attn" in path:
        if "wo" in path:
            return ("model", None, None)  # (h, hd, d) row-parallel on heads
        if any(w in path for w in ("wq", "wk", "wv")):
            return (None, "model", None)  # (d, h|k, hd) head-sharded
        return ()
    if any(w in path for w in ("wi_gate", "wi_up", "in_proj", "w_in")):
        return (None, "model")  # (d, f) column-parallel
    if "mlp" in path and "wi" in path:
        return (None, "model")
    if ("mlp" in path and "wo" in path) or "out_proj" in path or "w_out" in path:
        return ("model", None)  # (f, d) row-parallel
    return ()  # norms, biases, scalars: replicated


def _param_axes(path: str, ndim: int, cfg) -> tuple:
    core = _core_spec(path, cfg)
    if len(core) > ndim:  # e.g. a bias that matched a weight-name substring
        core = core[-ndim:]
    return (None,) * (ndim - len(core)) + tuple(core)


def param_specs(shapes, cfg, mesh: Mesh):
    """Tree of ``NamedSharding`` for a parameter tree of ShapeDtypeStructs."""

    def one(path, leaf):
        axes = _param_axes(_path_str(path), leaf.ndim, cfg)
        return NamedSharding(mesh, _guarded_spec(mesh, leaf.shape, axes))

    return jax.tree_util.tree_map_with_path(one, shapes)


# ---------------------------------------------------------------------------
# input / output shardings for the launch layer
# ---------------------------------------------------------------------------
def batch_shardings(batch, mesh: Mesh):
    """Batch-dim data-parallel sharding for every input leaf."""
    dp = _dp_axes(mesh)

    def one(leaf):
        axes = (dp,) + (None,) * (leaf.ndim - 1)
        return NamedSharding(mesh, _guarded_spec(mesh, leaf.shape, axes))

    return jax.tree.map(one, batch)


def cache_shardings(cache, cfg, mesh: Mesh):
    """Decode-cache sharding: batch on dp; KV heads on model when divisible.

    Stacked KV caches are (L, B, Smax, K, hd); recurrent-state caches keep
    batch at dim 1 as well — everything else stays replicated.
    """
    dp = _dp_axes(mesh)

    def one(leaf):
        axes = [None] * leaf.ndim
        if leaf.ndim >= 2:
            axes[1] = dp
        if leaf.ndim == 5 and leaf.shape[3] == getattr(cfg, "n_kv_heads", -1):
            axes[3] = "model" if "model" in mesh.shape else None
        return NamedSharding(mesh, _guarded_spec(mesh, leaf.shape, tuple(axes)))

    return jax.tree.map(one, cache)


def paged_cache_shardings(cache, cfg, mesh: Mesh):
    """Paged KV-pool sharding: KV heads on ``model`` when divisible.

    Pool leaves are (L, N_pages, page, K, hd).  The page dim stays
    replicated on purpose — block tables address pages randomly, so sharding
    pages would turn every ``gather_pages`` into a cross-device gather; the
    tensor-parallel axis for decode is the KV-head dim, matching the
    head-sharded wk/wv that produce the entries.
    """

    def one(leaf):
        axes = [None] * leaf.ndim
        if leaf.ndim == 5 and leaf.shape[3] == getattr(cfg, "n_kv_heads", -1):
            axes[3] = "model" if "model" in mesh.shape else None
        return NamedSharding(mesh, _guarded_spec(mesh, leaf.shape, tuple(axes)))

    return jax.tree.map(one, cache)


def logits_sharding(global_batch: int, vocab_size: int, mesh: Mesh) -> NamedSharding:
    """Output-logits sharding: batch-dim dp, vocab gathered for sampling.

    Rank-agnostic (covers (B, S, V) prefill and (B, V) decode): only dim 0 is
    named, trailing dims are replicated.
    """
    dp = _dp_axes(mesh)
    if dp is not None and global_batch % _axis_size(mesh, dp) != 0:
        dp = None
    return NamedSharding(mesh, P(dp))
