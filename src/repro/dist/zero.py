"""ZeRO partitioning: spread replicated state over the data axis.

``zero1_state_specs`` takes the tensor-parallel param specs and additionally
shards, over ``data``, the first dim of each leaf that is still replicated
and divides the data-axis size.  Used for optimizer state (stage 1), grad
accumulators (stage 2), and fp32 master params / FSDP storage (stage 3) —
the staging policy lives in launch.cell.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def zero1_state_specs(shapes, pspecs, mesh: Mesh):
    if "data" not in mesh.shape or mesh.shape["data"] == 1:
        return pspecs
    dsize = mesh.shape["data"]

    def one(leaf, sh: NamedSharding) -> NamedSharding:
        spec = list(sh.spec) + [None] * (leaf.ndim - len(sh.spec))
        for i, dim in enumerate(leaf.shape):
            if spec[i] is None and dim >= dsize and dim % dsize == 0:
                spec[i] = "data"
                break
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, shapes, pspecs)
