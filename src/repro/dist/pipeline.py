"""GPipe pipeline parallelism over a mesh axis.

``gpipe_apply`` runs ``n_stages`` sequential stages (params carry a leading
stage dim) over ``n_micro`` microbatches with the classic GPipe schedule:
each device owns one stage, activations hop stage->stage+1 via ppermute each
step, and the pipeline drains after ``n_micro + n_stages - 1`` steps.  Bubble
steps compute on garbage but are masked out of the output, so the result is
bit-comparable to running the stages sequentially — and the whole schedule is
differentiable (scan + ppermute + where), which is what GPipe training needs.
"""
from __future__ import annotations

import inspect

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ._compat import shard_map


def gpipe_apply(stage_fn, params, x: jax.Array, mesh: Mesh, axis: str = "pod"):
    """Apply a pipeline of stages to microbatched input.

    Args:
      stage_fn: ``(stage_params, h) -> h`` for one stage.
      params: pytree whose leaves have a leading ``n_stages`` dim.
      x: ``(n_micro, microbatch, ...)`` input microbatches.
      mesh: mesh providing the pipeline axis.
      axis: mesh axis name; its size must equal the stage count.
    """
    n_stages = mesh.shape[axis]
    lead = {leaf.shape[0] for leaf in jax.tree.leaves(params)}
    if lead != {n_stages}:
        raise ValueError(f"stage dim {lead} != mesh axis {axis}={n_stages}")
    n_micro = x.shape[0]
    n_steps = n_micro + n_stages - 1
    perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def pipeline(p_shard, x_all):
        p = jax.tree.map(lambda a: a[0], p_shard)  # this device's stage slice
        sidx = jax.lax.axis_index(axis)
        last = n_stages - 1

        def step(carry, t):
            recv, y = carry
            feed = jax.lax.dynamic_index_in_dim(
                x_all, jnp.clip(t, 0, n_micro - 1), axis=0, keepdims=False
            )
            inp = jnp.where(sidx == 0, feed, recv)
            out = stage_fn(p, inp)
            m = jnp.clip(t - last, 0, n_micro - 1)
            cur = jax.lax.dynamic_index_in_dim(y, m, axis=0, keepdims=False)
            write = (sidx == last) & (t >= last)
            y = jax.lax.dynamic_update_index_in_dim(
                y, jnp.where(write, out, cur), m, axis=0
            )
            return (jax.lax.ppermute(out, axis, perm), y), None

        carry0 = (jnp.zeros_like(x_all[0]), jnp.zeros_like(x_all))
        (_, y), _ = jax.lax.scan(step, carry0, jnp.arange(n_steps))
        # only the last stage holds real outputs; replicate via masked psum
        y = jnp.where(sidx == last, y, jnp.zeros_like(y))
        return jax.lax.psum(y, axis)

    # replication checking was renamed check_rep -> check_vma when shard_map
    # was promoted out of jax.experimental; disable under either name (the
    # masked-psum output pattern predates the checker's where/psum support)
    check_kw = (
        "check_rep"
        if "check_rep" in inspect.signature(shard_map).parameters
        else "check_vma"
    )
    fn = shard_map(
        pipeline,
        mesh=mesh,
        in_specs=(jax.tree.map(lambda _: P(axis), params), P()),
        out_specs=P(),
        **{check_kw: False},
    )
    return fn(params, x)
