"""Fault-tolerant training loop.

Wires together: data pipeline (exact skip-ahead), checkpoint manager
(atomic, auto-fallback), heartbeat + straggler monitors, and the jitted
train step.  ``FailureInjector`` lets tests kill the loop at a chosen step
and verify bit-exact resume.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Optional

import jax

from repro.ckpt import CheckpointManager
from repro.ft import HeartbeatMonitor, StragglerDetector

from .step import TrainState


@dataclass
class LoopConfig:
    total_steps: int
    ckpt_every: int = 50
    log_every: int = 10
    worker_name: str = "host0"


@dataclass
class FailureInjector:
    fail_at_step: Optional[int] = None
    fired: bool = False

    def maybe_fail(self, step: int):
        if self.fail_at_step is not None and step == self.fail_at_step and not self.fired:
            self.fired = True
            raise RuntimeError(f"injected failure at step {step}")


def train_loop(
    step_fn: Callable,  # jitted (state, batch) -> (state, metrics)
    state: TrainState,
    pipeline,  # DataPipeline
    ckpt: Optional[CheckpointManager] = None,
    cfg: LoopConfig = LoopConfig(total_steps=100),
    injector: Optional[FailureInjector] = None,
    on_metrics: Optional[Callable] = None,
) -> tuple[TrainState, list[dict]]:
    """Runs to total_steps; resumes from the latest checkpoint if present."""
    start = 0
    if ckpt is not None:
        s, restored = ckpt.restore(jax.eval_shape(lambda: state))
        if s is not None:
            state = jax.tree.map(lambda sd, a: a, jax.eval_shape(lambda: state), restored)
            start = s
            pipeline.seek(start)
    heartbeat = HeartbeatMonitor()
    straggler = StragglerDetector()
    history: list[dict] = []

    for step in range(start, cfg.total_steps):
        if injector is not None:
            injector.maybe_fail(step)
        t0 = time.perf_counter()
        _, batch = next(pipeline)
        state, metrics = step_fn(state, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0
        heartbeat.beat(cfg.worker_name, step)
        straggler.observe(cfg.worker_name, dt)
        rec = {
            "step": step,
            "loss": float(metrics["loss"]),
            "grad_norm": float(metrics["grad_norm"]),
            "lr": float(metrics["lr"]),
            "step_time_s": dt,
        }
        history.append(rec)
        if on_metrics and step % cfg.log_every == 0:
            on_metrics(rec)
        if ckpt is not None and (step + 1) % cfg.ckpt_every == 0:
            ckpt.save(step + 1, state)
    if ckpt is not None:
        ckpt.save(cfg.total_steps, state)
    return state, history
