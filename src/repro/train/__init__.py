"""Training substrate: step functions, microbatching, loop, fault tolerance."""
from .step import make_train_step, TrainState

__all__ = ["make_train_step", "TrainState"]
