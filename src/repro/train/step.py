"""Train step: loss -> grads (optionally microbatched) -> clip -> AdamW.

Microbatching (gradient accumulation via lax.scan) bounds live activation
memory; remat inside the model bounds per-layer memory; ZeRO-1 shardings on
the optimizer state bound state memory.  Together these set the per-device
HBM footprint the dry-run's memory_analysis() verifies.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.optim import AdamW, AdamWState, clip_by_global_norm


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState


def make_train_step(
    loss_fn: Callable,  # (params, batch) -> scalar
    optimizer: AdamW,
    lr_fn: Callable,  # step -> lr
    microbatches: int = 1,
    clip_norm: float = 1.0,
    grad_shardings=None,  # ZeRO-2: store (accumulated) grads data-sharded
):
    """Returns step(state, batch) -> (state, metrics)."""

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn)(params, batch)

    def _constrain(g):
        if grad_shardings is None:
            return g
        return jax.tree.map(jax.lax.with_sharding_constraint, g, grad_shardings)

    def step(state: TrainState, batch) -> tuple[TrainState, dict]:
        params, opt_state = state
        if microbatches == 1:
            loss, grads = grads_of(params, batch)
            grads = _constrain(grads)
        else:

            def split(x):
                b = x.shape[0]
                assert b % microbatches == 0, (b, microbatches)
                return x.reshape((microbatches, b // microbatches) + x.shape[1:])

            mbs = jax.tree.map(split, batch)
            zeros = _constrain(
                jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            )

            def acc(carry, mb):
                loss_sum, gacc = carry
                l, g = grads_of(params, mb)
                # reduce-scatter each microbatch grad into the ZeRO layout
                gacc = _constrain(
                    jax.tree.map(lambda a, b: a + b.astype(jnp.float32), gacc, g)
                )
                return (loss_sum + l, gacc), None

            (loss_sum, gsum), _ = jax.lax.scan(acc, (jnp.zeros((), jnp.float32), zeros), mbs)
            loss = loss_sum / microbatches
            grads = jax.tree.map(lambda g: (g / microbatches), gsum)

        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        lr = lr_fn(opt_state.step)
        updates, opt_state = optimizer.update(grads, opt_state, params, lr)
        params = optimizer.apply(params, updates)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        return TrainState(params, opt_state), metrics

    return step


def init_state(model_init: Callable, optimizer: AdamW, rng) -> TrainState:
    params = model_init(rng)
    return TrainState(params=params, opt=optimizer.init(params))


def state_shapes(model_init: Callable, optimizer: AdamW) -> TrainState:
    """Abstract TrainState (ShapeDtypeStructs) — dry-run: no allocation."""
    return jax.eval_shape(lambda: init_state(model_init, optimizer, jax.random.key(0)))
