"""Atomic checkpoint manager.

Layout per step::

    <dir>/step_000000123/
        manifest.json     # tree structure, shapes, dtypes, checksums, step
        arrays.npz        # flattened leaves (host-gathered)

Write protocol: write into ``.tmp-<step>`` then ``os.replace`` to the final
name — a crash mid-write never corrupts the latest checkpoint.  ``restore``
scans newest-first and skips manifests whose checksums fail (torn writes /
bitrot on a real fleet), implementing automatic fall-back to the last good
checkpoint.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)
    leaves = []
    for path, leaf in flat[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        leaves.append((key, leaf))
    return leaves, flat[1]


class CheckpointManager:
    def __init__(self, directory: str | Path, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: Optional[dict] = None) -> Path:
        leaves, treedef = _flatten_with_paths(tree)
        arrays = {k: np.asarray(v) for k, v in leaves}
        digest = {
            k: hashlib.sha256(a.tobytes()).hexdigest()[:16] for k, a in arrays.items()
        }
        manifest = {
            "step": step,
            "keys": list(arrays.keys()),
            "shapes": {k: list(a.shape) for k, a in arrays.items()},
            "dtypes": {k: str(a.dtype) for k, a in arrays.items()},
            "checksums": digest,
            "extra": extra or {},
        }
        tmp = self.dir / f".tmp-{step}"
        final = self.dir / f"step_{step:09d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        np.savez(tmp / "arrays.npz", **arrays)
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._gc()
        return final

    # ------------------------------------------------------------------
    def steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*"):
            try:
                out.append(int(p.name.split("_")[1]))
            except (IndexError, ValueError):
                continue
        return sorted(out)

    def _verify(self, path: Path) -> Optional[dict]:
        try:
            manifest = json.loads((path / "manifest.json").read_text())
            with np.load(path / "arrays.npz") as z:
                for k in manifest["keys"]:
                    a = z[k]
                    if hashlib.sha256(a.tobytes()).hexdigest()[:16] != manifest["checksums"][k]:
                        return None
            return manifest
        except Exception:  # noqa: BLE001
            return None

    def restore(
        self, like: Any, step: Optional[int] = None, shardings: Any = None
    ) -> tuple[Optional[int], Any]:
        """Restore into the structure of ``like`` (a tree of arrays or
        ShapeDtypeStructs).  Newest-first; corrupt checkpoints are skipped.
        Returns (step, tree) or (None, None)."""
        candidates = self.steps()
        if step is not None:
            candidates = [s for s in candidates if s == step]
        for s in reversed(candidates):
            path = self.dir / f"step_{s:09d}"
            manifest = self._verify(path)
            if manifest is None:
                continue
            leaves, treedef = _flatten_with_paths(like)
            with np.load(path / "arrays.npz") as z:
                vals = []
                ok = True
                for key, leaf in leaves:
                    if key not in z:
                        ok = False
                        break
                    a = z[key]
                    if tuple(a.shape) != tuple(leaf.shape):
                        ok = False
                        break
                    vals.append(a)
                if not ok:
                    continue
                if shardings is not None:
                    flat_sh = [s for _, s in _flatten_with_paths(shardings)[0]]
                    vals = [jax.device_put(a, sh) for a, sh in zip(vals, flat_sh)]
                return s, jax.tree_util.tree_unflatten(treedef, vals)
        return None, None

    def latest_manifest(self) -> Optional[dict]:
        for s in reversed(self.steps()):
            m = self._verify(self.dir / f"step_{s:09d}")
            if m:
                return m
        return None

    def _gc(self):
        steps = self.steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:09d}", ignore_errors=True)
