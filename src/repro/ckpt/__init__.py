"""Checkpointing: atomic sharded numpy checkpoints + elastic resharding."""
from .manager import CheckpointManager
from .reshard import load_resharded

__all__ = ["CheckpointManager", "load_resharded"]
