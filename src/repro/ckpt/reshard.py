"""Elastic resharding: restore a checkpoint saved under mesh A onto mesh B.

Checkpoints store host-gathered (global) arrays, so resharding is just
``device_put`` against the new mesh's shardings — the mechanism that lets a
job restarted on a different pod count (elastic scaling, failed-node
exclusion) resume from the same checkpoint.
"""
from __future__ import annotations

from typing import Any, Optional

from .manager import CheckpointManager


def load_resharded(
    manager: CheckpointManager,
    like: Any,
    new_shardings: Any,
    step: Optional[int] = None,
) -> tuple[Optional[int], Any]:
    """Restore with placement onto a (possibly different) mesh."""
    return manager.restore(like, step=step, shardings=new_shardings)
