"""Perf-over-time: fold per-commit bench artifacts into one trend report.

CI's bench job stamps every run's results file as ``BENCH_<sha>.json`` (the
first 12 hex digits of the commit); tagged jobs add an uppercase infix, e.g.
the chaos job's ``BENCH_CHAOS_<sha>.json``.  This module aggregates a
directory (or explicit list) of those artifacts into one series per record
name — same-sha files merge into one commit point (first file primary,
duplicate record names deduplicated) ordered by each run's ``created_at``
stamp — and renders the trajectory as markdown
(for humans: first/last value, percent delta, direction-aware regression
flag) or JSON (for plotting).  ``python -m repro.bench trend`` is the CLI:

    python -m repro.bench trend artifacts/ --out trend.md
    python -m repro.bench trend artifacts/ --json --benchmark serving

Only rows gated by direction (``better`` = lower/higher) get a regression
flag; ``info`` rows are carried for plotting but never flagged — the same
semantics as the ``compare`` gate.
"""
from __future__ import annotations

import json
import re
from pathlib import Path

from .schema import BenchResult, SchemaError

#: ``BENCH_<sha>.json`` plus tagged variants like ``BENCH_CHAOS_<sha>.json``
_BENCH_FILE = re.compile(
    r"BENCH_(?:(?P<tag>[A-Z][A-Z0-9]*)_)?(?P<sha>[0-9a-fA-F]{4,40})\.json$"
)

#: relative change that earns a direction-aware flag in the markdown view
FLAG_THRESHOLD = 0.10


def discover(paths) -> list:
    """Expand directories to their ``BENCH_*.json`` members; keep files."""
    out = []
    for p in paths:
        p = Path(p)
        if p.is_dir():
            out.extend(sorted(p.glob("BENCH_*.json")))
        else:
            out.append(p)
    return out


def load_commits(files) -> list:
    """``[(sha, BenchResult)]`` ordered by run timestamp (then sha).

    The sha comes from the ``BENCH_<sha>.json`` / ``BENCH_<TAG>_<sha>.json``
    filename; a file named otherwise keeps its stem, so ad-hoc results can
    join a trend.  Multiple files for one sha (the main bench artifact plus
    tagged job artifacts like ``BENCH_CHAOS_<sha>.json``) merge into a
    single commit entry: the first file is primary and later files
    contribute only record names it does not already carry (jobs overlap on
    shared quick suites).  Files that fail schema validation raise — a
    trend over silently-dropped commits would misreport where a regression
    landed.
    """
    commits = []
    by_sha: dict = {}
    for f in files:
        f = Path(f)
        try:
            result = BenchResult.load(f)
        except SchemaError as e:
            raise SchemaError(f"{f}: {e}") from None
        m = _BENCH_FILE.search(f.name)
        sha = m.group("sha") if m else f.stem
        primary = by_sha.get(sha)
        if primary is None:
            by_sha[sha] = result
            commits.append((sha, result))
        else:
            seen = {r.name for r in primary.records}
            primary.records.extend(
                r for r in result.records if r.name not in seen
            )
    commits.sort(key=lambda c: (c[1].created_at, c[0]))
    return commits


def build_trend(commits, benchmarks=None) -> dict:
    """One series per record name over the commit axis.

    ``benchmarks`` filters by benchmark/record-name prefix (the same
    prefix semantics as ``bench run``).  Records absent from some commits
    simply have fewer points — renames show up as one series ending and
    another starting, which is the honest view.
    """
    prefixes = tuple(benchmarks or ())

    def keep(r) -> bool:
        if not prefixes:
            return True
        return any(r.benchmark.startswith(p) or r.name.startswith(p)
                   for p in prefixes)

    series: dict = {}
    for sha, result in commits:
        for r in result.records:
            if not keep(r):
                continue
            s = series.setdefault(r.name, {
                "name": r.name,
                "benchmark": r.benchmark,
                "unit": r.unit,
                "better": r.better,
                "points": [],
            })
            s["points"].append({
                "sha": sha,
                "created_at": result.created_at,
                "value": r.value,
            })
    return {
        "commits": [
            {"sha": sha, "created_at": res.created_at, "mode": res.mode}
            for sha, res in commits
        ],
        "series": [series[k] for k in sorted(series)],
    }


def _delta_pct(points) -> float:
    first, last = points[0]["value"], points[-1]["value"]
    if first == 0:
        return float("inf") if last else 0.0
    return (last - first) / abs(first) * 100.0


def _flag(better: str, delta_pct: float) -> str:
    if better not in ("lower", "higher") or abs(delta_pct) < FLAG_THRESHOLD * 100:
        return ""
    worse = delta_pct > 0 if better == "lower" else delta_pct < 0
    return "regressed" if worse else "improved"


def format_markdown(trend: dict) -> str:
    """Render a trend dict (from :func:`build_trend`) as a markdown report."""
    commits = trend["commits"]
    lines = ["# Bench trend", ""]
    if not commits:
        lines.append("No commits found.")
        return "\n".join(lines) + "\n"
    first, last = commits[0], commits[-1]
    lines.append(
        f"{len(commits)} commit(s): `{first['sha']}` ({first['created_at']}) "
        f"→ `{last['sha']}` ({last['created_at']})"
    )
    lines += [
        "",
        "| record | unit | better | n | first | last | Δ% | flag |",
        "|---|---|---|---:|---:|---:|---:|---|",
    ]
    for s in trend["series"]:
        pts = s["points"]
        d = _delta_pct(pts)
        lines.append(
            f"| {s['name']} | {s['unit']} | {s['better']} | {len(pts)} "
            f"| {pts[0]['value']:.4g} | {pts[-1]['value']:.4g} "
            f"| {d:+.1f} | {_flag(s['better'], d)} |"
        )
    flagged = [s["name"] for s in trend["series"]
               if _flag(s["better"], _delta_pct(s["points"])) == "regressed"]
    lines.append("")
    if flagged:
        lines.append(
            f"**{len(flagged)} record(s) regressed ≥"
            f"{FLAG_THRESHOLD:.0%} first→last:** "
            + ", ".join(f"`{n}`" for n in flagged)
        )
    else:
        lines.append(
            f"No gated record regressed ≥{FLAG_THRESHOLD:.0%} first→last."
        )
    return "\n".join(lines) + "\n"


def format_json(trend: dict) -> str:
    return json.dumps(trend, indent=2)
