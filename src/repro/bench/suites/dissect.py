"""Whole-paper benchmark — the Ch. 3+4 workflow as one registered entry.

Runs the full probe suite (measure mode: the host), fits a HardwareModel,
and reports the fitted summary; then evaluates the analytic TPU v5e model
over the same grid.  The detailed per-probe curves live in the other suites;
this entry gates the *fitted* quantities the rest of the stack consumes
(stream bandwidth, matmul peak, per-level latency).
"""
from __future__ import annotations

from repro.core.dissect import dissect_measure, dissect_model
from repro.core.registry import register

from ..schema import BenchRecord


@register(
    "dissect",
    paper_ref="Ch. 3+4 (Tab 3.1 workflow)",
    description="probe suite -> fitted HardwareModel",
    quick={"quick": True},
    full={"quick": False},
)
def bench_dissect(quick=True) -> list:
    rep = dissect_measure(quick=quick)
    recs = [
        BenchRecord(
            name="dissect_host_stream_bw",
            benchmark="dissect",
            x="measured-host",
            value=rep.hardware.main_memory_Bps / 1e9,
            unit="GB/s",
            info="fitted main-memory streaming bandwidth",
        ),
        BenchRecord(
            name="dissect_host_matmul_peak",
            benchmark="dissect",
            x="measured-host",
            value=rep.hardware.peak("float32") / 1e9,
            unit="GFLOP/s",
            info="fitted f32 matmul peak",
        ),
        BenchRecord(
            name="dissect_host_n_levels",
            benchmark="dissect",
            x="measured-host",
            value=float(len(rep.detected_levels)),
            unit="levels",
            better="info",
            info="detected memory-hierarchy plateaus",
        ),
    ]
    for i, (lat, cap) in enumerate(rep.detected_levels):
        recs.append(
            BenchRecord(
                name=f"dissect_host_level{i}_latency",
                benchmark="dissect",
                x=i,
                value=float(lat),
                unit="ns",
                better="info",  # plateau segmentation varies across hosts
                metrics={"capacity_bytes": int(cap) if cap else 0},
            )
        )
    model = dissect_model()
    hw = model.hardware
    recs += [
        BenchRecord(
            name="dissect_tpu_model_hbm_bw",
            benchmark="dissect",
            x=hw.name,
            value=hw.main_memory_Bps / 1e9,
            unit="GB/s",
            measured=False,
            info="modeled HBM bandwidth",
        ),
        BenchRecord(
            name="dissect_tpu_model_bf16_peak",
            benchmark="dissect",
            x=hw.name,
            value=hw.peak("bfloat16") / 1e12,
            unit="TFLOP/s",
            measured=False,
            info="modeled MXU bf16 peak",
        ),
    ]
    return recs
