"""Benchmark suites — importing this package registers every paper-table
benchmark with ``repro.core.registry``.

Paper map (table/figure -> registered name):

    Fig 1.1            axpy        access-width sweep on bandwidth-bound axpy
    Tab 2.1            scheduler   work-unit/execution-unit occupancy
    Fig 3.5 / Tab 3.1  memhier     pointer-chase hierarchy dissection
    Tab 3.2/3.4,
    Fig 3.12/3.13      bandwidth   per-level streaming bandwidth
    Tab 4.1            instr       dependent-issue op latency
    Tab 4.2 / Fig 4.1  atomics     scatter contention
    Fig 4.2 / Tab 4.3  gemm        matmul throughput across dtypes
    Tab 3.1 / Tab 4.3  gemm_lp     low-precision TensorCore ladder vs spec DB
    Fig 4.3-4.5        throttle    power/thermal clock governor
    Ch. 3+4 (whole)    dissect     probe suite -> fitted HardwareModel
    Ch. 1 + Fig 4.3    serving     engine TTFT/latency/throughput sweep
    Ch. 1 (scale-out)  serving_scaled  cluster sweep over tp x replicas
    §4.5 (contrast)    serving_chaos   goodput/availability, clean vs faulted
"""
from . import (  # noqa: F401  (import side effect: registration)
    atomics,
    axpy,
    bandwidth,
    dissect,
    gemm,
    gemm_lp,
    instr,
    memhier,
    scheduler,
    serving,
    serving_chaos,
    serving_scaled,
    throttle,
)
