"""Fig 1.1 analogue — `?axpy` access-width sweep.

The paper: cublasSaxpy's 64-bit loads vs. hand-vectorized 128-bit loads ->
~2x on large arrays.  TPU restatement: the bandwidth-bound axpy kernel swept
over VMEM tile widths (narrow tiles under-utilize the HBM streaming path the
way narrow loads under-utilized Turing's LSUs), plus the XLA-fused baseline
(the "library" implementation) and the HardwareModel-predicted TPU bound.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.hw import TPU_V5E
from repro.core.registry import register
from repro.core.timing import time_fn
from repro.kernels import api

from ..schema import BenchRecord


@register(
    "axpy",
    paper_ref="Fig 1.1",
    description="access-width sweep on bandwidth-bound axpy",
    quick={"sizes": (1 << 18, 1 << 20), "widths": (128, 256, 512, 1024)},
    full={"sizes": (1 << 18, 1 << 20, 1 << 22), "widths": (128, 256, 512, 1024, 2048)},
)
def bench_axpy(sizes=(1 << 18, 1 << 20), widths=(128, 256, 512, 1024)) -> list:
    recs = []
    for n in sizes:
        cols_base = 512
        x = jnp.ones((n // cols_base, cols_base), jnp.float32)
        y = jnp.ones((n // cols_base, cols_base), jnp.float32)
        bytes_moved = 3 * n * 4  # 2 reads + 1 write

        t_lib = time_fn(jax.jit(lambda a, b: 2.5 * a + b), x, y, warmup=2, reps=5)
        recs.append(
            BenchRecord(
                name=f"axpy_xla_baseline_n{n}",
                benchmark="axpy",
                x=n,
                value=bytes_moved / t_lib.min_s / 1e9,
                unit="GB/s",
                metrics={"us_per_call": t_lib.min_s * 1e6},
                info="XLA-fused library baseline",
            )
        )
        for w in widths:
            xv = jnp.ones((n // w, w), jnp.float32)
            yv = jnp.ones((n // w, w), jnp.float32)
            t = time_fn(
                api.axpy.bound(xv, yv, 2.5, block_rows=8, block_cols=w),
                xv, yv, 2.5, warmup=2, reps=5,
            )
            recs.append(
                BenchRecord(
                    name=f"axpy_pallas_n{n}_w{w}",
                    benchmark="axpy",
                    x=w,
                    value=bytes_moved / t.min_s / 1e9,
                    unit="GB/s",
                    metrics={"us_per_call": t.min_s * 1e6, "size": n},
                    info=f"Pallas tile width {w}",
                )
            )
        recs.append(
            BenchRecord(
                name=f"axpy_tpu_modeled_n{n}",
                benchmark="axpy",
                x=n,
                value=TPU_V5E.main_memory_Bps / 1e9,
                unit="GB/s",
                measured=False,
                metrics={"us_per_call": bytes_moved / TPU_V5E.main_memory_Bps * 1e6},
                info="HBM-bandwidth-bound TPU v5e model",
            )
        )
    return recs


@register(
    "axpy",
    backends=("pallas", "xla"),
    paper_ref="Fig 1.1",
    description="access-width axpy sweep through the kernel dispatch API",
    quick={"size": 1 << 18, "widths": (256, 512)},
    full={"size": 1 << 20, "widths": (128, 256, 512, 1024)},
)
def bench_axpy_backend(size=1 << 20, widths=(256, 512), backend="xla") -> list:
    """Same measurement, one registered variant per kernel backend: the Pallas
    rows vary with tile width, the XLA rows are the width-insensitive library
    baseline — the paper's Fig 1.1 comparison as a results-file diff."""
    recs = []
    bytes_moved = 3 * size * 4
    for w in widths:
        x = jnp.ones((size // w, w), jnp.float32)
        y = jnp.ones((size // w, w), jnp.float32)
        t = time_fn(
            api.axpy.bound(x, y, 2.5, block_rows=8, block_cols=w, backend=backend),
            x, y, 2.5, warmup=2, reps=5,
        )
        recs.append(
            BenchRecord(
                name=f"axpy_dispatch_n{size}_w{w}",
                benchmark="axpy",
                x=w,
                value=bytes_moved / t.min_s / 1e9,
                unit="GB/s",
                metrics={"us_per_call": t.min_s * 1e6, "size": size},
                info=f"{backend} backend, tile width {w}",
            )
        )
    return recs
