"""Fig 4.3 / 4.4 / 4.5 analogue — clock throttling under sustained load.

Runs the fitted power/thermal governor model for the paper's T4
parameterization (validating the published curve shape: brief full clock ->
power-limit plateau -> thermal step at 85 C) and for the TPU v5e envelope
used by the straggler detector.  Entirely deterministic (model outputs), so
the baseline gate holds these rows to a tight threshold.
"""
from __future__ import annotations

import numpy as np

from repro.core.registry import register
from repro.core.throttle import T4_THROTTLE, V5E_THROTTLE, simulate, steady_state_clock

from ..schema import BenchRecord, finite


@register(
    "throttle",
    paper_ref="Fig 4.3-4.5",
    description="power/thermal clock governor",
    quick={"duration_s": 300, "dt": 0.5, "utils": (0.6, 0.8, 1.0)},
    full={"duration_s": 900, "dt": 0.25, "utils": (0.5, 0.6, 0.7, 0.8, 0.9, 1.0)},
)
def bench_throttle(duration_s=300, dt=0.5, utils=(0.6, 0.8, 1.0)) -> list:
    recs = []
    for name, p in (("t4", T4_THROTTLE), ("v5e", V5E_THROTTLE)):
        out = simulate(p, utilization=1.0, duration_s=duration_s, dt=dt)
        clock, temp, power = out["clock_hz"], out["temp_c"], out["power_w"]
        idx = np.argmax(clock < 0.95 * p.f_max_hz)
        t_derate = out["t"][idx] if clock.min() < 0.95 * p.f_max_hz else float("inf")
        recs += [
            BenchRecord(
                name=f"throttle_{name}_time_to_derate",
                benchmark="throttle",
                x=name,
                value=finite(t_derate, duration_s),
                unit="s",
                better="higher",  # longer at full clock is better
                measured=False,
                info=f"time to first 5% derate (capped at {duration_s}s)",
            ),
            BenchRecord(
                name=f"throttle_{name}_steady_clock",
                benchmark="throttle",
                x=name,
                value=clock[-1] / 1e6,
                unit="MHz",
                measured=False,
                info=f"steady-state clock (max {p.f_max_hz / 1e6:.0f} MHz)",
            ),
            BenchRecord(
                name=f"throttle_{name}_steady_power",
                benchmark="throttle",
                x=name,
                value=float(power[-40:].mean()),
                unit="W",
                better="info",
                measured=False,
                info=f"steady-state power (limit {p.power_limit_w:.0f} W)",
            ),
            BenchRecord(
                name=f"throttle_{name}_max_temp",
                benchmark="throttle",
                x=name,
                value=float(temp.max()),
                unit="C",
                better="info",
                measured=False,
                info=f"peak temperature (cap {p.max_temp_c:.0f} C)",
            ),
        ]
        for u in utils:
            recs.append(
                BenchRecord(
                    name=f"throttle_{name}_clock_u{int(u * 100)}",
                    benchmark="throttle",
                    x=u,
                    value=steady_state_clock(p, u) / 1e6,
                    unit="MHz",
                    measured=False,
                    info=f"sustained clock at {u:.0%} utilization",
                )
            )
    return recs
