"""Tab 4.1 analogue — dependent-issue op latency table.

The paper measures SASS instruction latencies with control-word stall
tuning; the TPU/JAX analogue is a dependent-chain per-primitive latency
(chain of fori_loop iterations, loop overhead subtracted)."""
from __future__ import annotations

from repro.core import probes
from repro.core.registry import register

from ..schema import BenchRecord


@register(
    "instr",
    paper_ref="Tab 4.1",
    description="dependent-issue op latency",
    quick={"chain": 1024},
    full={"chain": 8192},
)
def bench_instr(chain=1024) -> list:
    res = probes.probe_op_latency(chain=chain)
    recs = [
        BenchRecord(
            name=f"oplat_{name}",
            benchmark="instr",
            x=name,
            value=lat,
            unit="ns/op",
            metrics={"us_per_call": lat * 1e-3},
            info="dependent-issue",
        )
        for name, lat in zip(res.x, res.y)
    ]
    recs.append(
        BenchRecord(
            name="oplat_loop_overhead",
            benchmark="instr",
            x="baseline",
            value=res.meta["base_ns"],
            unit="ns/op",
            info="fori_loop overhead baseline (subtracted from op rows)",
        )
    )
    return recs
