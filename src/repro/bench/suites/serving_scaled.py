"""Cluster scale-out benchmark — the serving sweep over a device-count axis.

Where ``serving`` measures one engine, this suite drives
:class:`~repro.serve.cluster.ClusterRouter` across a tensor-parallel ×
data-parallel grid: ``tp`` shards each replica's decode over a ``model``
mesh axis, ``replicas`` fans requests out data-parallel, and every sweep
point reports the pooled :class:`~repro.serve.metrics.ClusterMetrics` rows
(TTFT, p95 inter-token latency, throughput, slot-weighted occupancy) with
``x`` set to the point's device count — the scale-out curve.

Points whose ``tp`` exceeds the available device count are skipped (the
full grid is meant for the forced-host-device CI job; the quick grid fits a
single device).  A ``failover`` contrast point kills one of two replicas
mid-run and serves the drained sessions to completion on the survivor —
its ``requeued_sessions`` metric is the resilience headline.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core.registry import register

from .serving import _build_model


def _drive_cluster(cfg, model, params, *, backend, tp, n_replicas, n_slots,
                   prompt_len, out_len, requests, prefill_chunk,
                   page_size=None, router="least_loaded", seed=0,
                   fail_after: int = 0):
    """One measured cluster run: warm-up batch through the same replicas
    (compiled steps are per-engine), telemetry reset, then the measured
    batch.  ``fail_after > 0`` fails replica 0 after that many measured
    ticks and lets the survivors finish the drained sessions."""
    from repro.serve import ClusterConfig, ClusterRouter, EngineConfig

    cluster = ClusterRouter(model, params, ClusterConfig(
        engine=EngineConfig(
            n_slots=n_slots,
            max_len=prompt_len + out_len + 1,
            prefill_chunk=prefill_chunk,
            page_size=page_size,
            backend=backend,
        ),
        n_replicas=n_replicas,
        tp=tp,
        router=router,
    ))
    rng = np.random.default_rng(seed)

    def batch(n, fail_after=0):
        sessions = [
            cluster.submit(
                [int(t) for t in rng.integers(1, cfg.vocab_size, prompt_len)],
                max_new_tokens=out_len,
            )
            for _ in range(n)
        ]
        if fail_after:
            for _ in range(fail_after):
                cluster.step()
            cluster.fail_replica(0)
        cluster.run(max_ticks=50 * max(n, 1) * out_len)
        done = sum(s.done for s in sessions)
        if done != n:
            raise RuntimeError(f"cluster served {done}/{n} requests")

    batch(min(2, requests))  # warm-up: compile each replica's steps
    cluster.reset_metrics()
    batch(requests, fail_after=fail_after)
    return cluster


@register(
    "serving_scaled",
    backends=("pallas", "xla"),
    paper_ref="Ch.1 (inference board scale-out)",
    description="cluster TTFT/latency/throughput over a tp x replicas device sweep",
    quick={"tps": (1,), "replicas": (1, 2), "n_slots": 2, "prompt_len": 8,
           "out_len": 6, "requests": 4, "prefill_chunk": 4,
           "page_sizes": (4,), "failover": True},
    full={"tps": (1, 2, 4), "replicas": (1, 2), "n_slots": 2, "prompt_len": 8,
          "out_len": 8, "requests": 8, "prefill_chunk": 4,
          "page_sizes": (4,), "failover": True},
)
def bench_serving_scaled(tps=(1,), replicas=(1, 2), n_slots=2, prompt_len=8,
                         out_len=6, requests=4, prefill_chunk=4,
                         page_sizes=(4,), router="least_loaded",
                         backend="xla", failover=True) -> list:
    """Each (tp, replicas) point drives a fresh cluster over seeded prompts
    — dense KV plus a paged twin per entry of ``page_sizes`` — and reports
    its pooled cluster rows with ``x`` = devices used.  A warm-up pass per
    point keeps per-replica compilation out of TTFT."""
    cfg, model, params = _build_model()
    n_dev = len(jax.devices())
    recs = []
    for tp in tps:
        if tp > n_dev:
            continue  # full grid point; needs the forced-device CI job
        for nr in replicas:
            devices_used = min(tp * nr, n_dev)
            for ps in (None,) + tuple(page_sizes):
                cluster = _drive_cluster(
                    cfg, model, params, backend=backend, tp=tp, n_replicas=nr,
                    n_slots=n_slots, prompt_len=prompt_len, out_len=out_len,
                    requests=requests, prefill_chunk=prefill_chunk,
                    page_size=ps, router=router,
                )
                prefix = f"serving_scaled_tp{tp}_r{nr}" + (f"_ps{ps}" if ps else "")
                recs.extend(cluster.to_records(
                    "serving_scaled", prefix, x=devices_used
                ))
    if failover and min(tps) <= n_dev:
        cluster = _drive_cluster(
            cfg, model, params, backend=backend, tp=min(tps), n_replicas=2,
            n_slots=n_slots, prompt_len=prompt_len, out_len=out_len,
            requests=requests, prefill_chunk=prefill_chunk,
            page_size=page_sizes[0] if page_sizes else None, router=router,
            fail_after=2,
        )
        recs.extend(cluster.to_records(
            "serving_scaled", "serving_scaled_failover",
            x=min(min(tps) * 2, n_dev),
        ))
    return recs
