"""Serving hot-path benchmark — the paper's sustained-load methodology
applied to the engine itself.

The T4 is an inference board and the paper's recipe is measuring the *same*
workload under steady load across hardware paths; this suite restates that
for the serving stack: one engine definition driven over slot-count ×
prompt-length × output-length × KV-layout sweeps, registered once per kernel
backend (``serving[pallas]`` / ``serving[xla]``), emitting TTFT, per-token
latency percentiles, throughput, and slot/page occupancy as schema-v1
records.  Three KV-layout contrasts ride on the common sweep:

- **paged vs dense** at the same slot count (``serving_*_ps{k}`` vs the
  unsuffixed rows): same tokens, paged overhead isolated,
- **equal-memory** (``serving_eqmem_*``): a dense engine and a paged engine
  holding the *same KV pool bytes*, the paged one oversubscribing slots
  against it — its ``concurrency`` row (mean active lanes) is the headline
  paging win,
- **shared prefix** (``serving_prefix_*``): every prompt shares a registered
  system-prompt prefix; the ``page_occupancy`` row's ``prefix_tokens_reused``
  metric counts prompt tokens served from shared pages instead of prefill.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core.registry import register


def _build_model():
    from repro.configs import get_config
    from repro.models import build_model

    # The decode/chunk-prefill hot path is jnp today, so the per-variant
    # kernel policy exercises the dispatch scoping (and any kernel-routed
    # model internals a config selects) rather than distinct decode kernels;
    # the two variants bound the engine's dispatch overhead against each other.
    cfg = get_config("gemma-2b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _drive(cfg, model, params, *, backend, n_slots, prompt_len, out_len,
           requests, prefill_chunk, scheduler, seed=0, max_len=None,
           page_size=None, n_pages=None, prefix_len=0):
    """One measured engine run.  Warm-up requests go through the SAME engine
    (its compiled steps are per-engine closures, so a throwaway engine would
    not pre-compile anything) and their telemetry is discarded before the
    measured batch.  ``page_size`` switches the engine to paged KV;
    ``prefix_len`` registers a shared prefix that every prompt then starts
    with (paged only)."""
    from repro.serve import EngineConfig, ServeEngine

    engine = ServeEngine(
        model,
        params,
        EngineConfig(
            n_slots=n_slots,
            max_len=max_len if max_len is not None else prompt_len + out_len + 1,
            prefill_chunk=prefill_chunk,
            page_size=page_size,
            n_pages=n_pages,
            backend=backend,
            scheduler=scheduler,
        ),
    )
    rng = np.random.default_rng(seed)
    prefix = []
    if prefix_len:
        prefix = [int(t) for t in rng.integers(1, cfg.vocab_size, prefix_len)]
        engine.register_prefix(prefix)

    def batch(n):
        for _ in range(n):
            tail = [int(t) for t in rng.integers(1, cfg.vocab_size, prompt_len)]
            engine.submit(prefix + tail, max_new_tokens=out_len)
        finished = engine.run(max_ticks=50 * max(n, 1) * out_len)
        if len(finished) != n:
            raise RuntimeError(f"served {len(finished)}/{n} requests")

    batch(min(2, requests))  # warm-up: compile prefill-chunk + decode steps
    engine.reset_metrics()
    batch(requests)
    return engine


@register(
    "serving",
    backends=("pallas", "xla"),
    paper_ref="Ch.1 + Fig 4.3 (inference board under sustained load)",
    description="serving-engine TTFT/latency/throughput sweep (dense + paged KV)",
    quick={"slots": (2,), "prompt_lens": (8,), "out_lens": (8,), "requests": 4,
           "prefill_chunk": 4, "page_sizes": (4,), "oversub": 3,
           "prefix_len": 6},
    full={"slots": (2, 4), "prompt_lens": (8, 32), "out_lens": (16,), "requests": 12,
          "prefill_chunk": 8, "page_sizes": (4, 16), "oversub": 3,
          "prefix_len": 16},
)
def bench_serving(slots=(2,), prompt_lens=(8,), out_lens=(8,), requests=4,
                  prefill_chunk=4, scheduler="fcfs", backend="xla",
                  page_sizes=(), oversub=3, prefix_len=0) -> list:
    """Each sweep point drives a fresh engine over seeded prompts and reports
    its :class:`~repro.serve.metrics.EngineMetrics` rows.  A warm-up pass per
    point keeps one-time compilation out of TTFT.

    ``page_sizes`` adds a paged twin per sweep point (same workload, paged
    KV) plus, for the first page size, the equal-memory and shared-prefix
    contrasts described in the module docstring.  ``oversub`` is the slot
    multiplier the equal-memory paged engine runs at.
    """
    cfg, model, params = _build_model()
    recs = []
    for ns in slots:
        for pl in prompt_lens:
            for ol in out_lens:
                common = dict(backend=backend, n_slots=ns, prompt_len=pl,
                              out_len=ol, prefill_chunk=prefill_chunk,
                              scheduler=scheduler, requests=requests)
                engine = _drive(cfg, model, params, **common)
                recs.extend(
                    engine.metrics.to_records(
                        benchmark="serving",
                        prefix=f"serving_s{ns}_p{pl}_o{ol}",
                        x=f"s{ns}:p{pl}:o{ol}",
                    )
                )
                for ps in page_sizes:
                    engine = _drive(cfg, model, params, page_size=ps, **common)
                    recs.extend(
                        engine.metrics.to_records(
                            benchmark="serving",
                            prefix=f"serving_s{ns}_p{pl}_o{ol}_ps{ps}",
                            x=f"s{ns}:p{pl}:o{ol}:ps{ps}",
                        )
                    )
    if page_sizes:
        ps = page_sizes[0]
        ns, pl, ol = slots[0], prompt_lens[0], out_lens[0]
        recs.extend(
            _eqmem_contrast(cfg, model, params, backend=backend, n_slots=ns,
                            prompt_len=pl, out_len=ol, page_size=ps,
                            oversub=oversub, prefill_chunk=prefill_chunk,
                            scheduler=scheduler, requests=max(requests, 2 * ns))
        )
        if prefix_len:
            engine = _drive(cfg, model, params, backend=backend, n_slots=ns,
                            prompt_len=pl, out_len=ol, page_size=ps,
                            prefix_len=prefix_len, prefill_chunk=prefill_chunk,
                            scheduler=scheduler, requests=requests,
                            max_len=prefix_len + pl + ol + 1)
            recs.extend(
                engine.metrics.to_records(
                    benchmark="serving",
                    prefix=f"serving_prefix_s{ns}_ps{ps}",
                    x=f"prefix{prefix_len}:s{ns}:ps{ps}",
                )
            )
    return recs


def _eqmem_contrast(cfg, model, params, *, backend, n_slots, prompt_len,
                    out_len, page_size, oversub, prefill_chunk, scheduler,
                    requests):
    """Dense vs paged at EQUAL KV memory.

    Both engines hold KV for ``n_slots * max_len`` positions, with
    ``max_len`` sized well above the actual request length (the realistic
    regime: max_len is a cap, typical requests are shorter).  Dense commits a
    full ``max_len`` region per lane, so it runs ``n_slots`` lanes; the paged
    engine spends the same pool on ``oversub * n_slots`` slots whose lanes
    only consume pages they actually touch.  The ``concurrency`` rows (mean
    active lanes, ``better="higher"``) are the comparison: more of the same
    memory doing useful work at once.
    """
    seq = prompt_len + out_len + 1
    max_len = max(oversub * seq, 2 * seq)  # headroom: requests << max_len
    pages_per_lane = -(-max_len // page_size)
    n_pages = n_slots * pages_per_lane  # exactly dense's KV footprint
    common = dict(backend=backend, prompt_len=prompt_len, out_len=out_len,
                  prefill_chunk=prefill_chunk, scheduler=scheduler,
                  requests=requests, max_len=max_len)
    recs = []
    dense = _drive(cfg, model, params, n_slots=n_slots, **common)
    recs.extend(
        dense.metrics.to_records(
            benchmark="serving",
            prefix=f"serving_eqmem_dense_s{n_slots}",
            x=f"eqmem:dense:s{n_slots}",
        )
    )
    paged = _drive(cfg, model, params, n_slots=oversub * n_slots,
                   page_size=page_size, n_pages=n_pages, **common)
    recs.extend(
        paged.metrics.to_records(
            benchmark="serving",
            prefix=f"serving_eqmem_paged_s{oversub * n_slots}_ps{page_size}",
            x=f"eqmem:paged:s{oversub * n_slots}:ps{page_size}",
        )
    )
    return recs
