"""Serving hot-path benchmark — the paper's sustained-load methodology
applied to the engine itself.

The T4 is an inference board and the paper's recipe is measuring the *same*
workload under steady load across hardware paths; this suite restates that
for the serving stack: one engine definition driven over slot-count ×
prompt-length × output-length sweeps, registered once per kernel backend
(``serving[pallas]`` / ``serving[xla]``), emitting TTFT, per-token latency
percentiles, throughput, and slot occupancy as schema-v1 records.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core.registry import register


def _build_model():
    from repro.configs import get_config
    from repro.models import build_model

    # The decode/chunk-prefill hot path is jnp today, so the per-variant
    # kernel policy exercises the dispatch scoping (and any kernel-routed
    # model internals a config selects) rather than distinct decode kernels;
    # the two variants bound the engine's dispatch overhead against each other.
    cfg = get_config("gemma-2b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _drive(cfg, model, params, *, backend, n_slots, prompt_len, out_len,
           requests, prefill_chunk, scheduler, seed=0):
    """One measured engine run.  Warm-up requests go through the SAME engine
    (its compiled steps are per-engine closures, so a throwaway engine would
    not pre-compile anything) and their telemetry is discarded before the
    measured batch."""
    from repro.serve import EngineConfig, ServeEngine

    engine = ServeEngine(
        model,
        params,
        EngineConfig(
            n_slots=n_slots,
            max_len=prompt_len + out_len + 1,
            prefill_chunk=prefill_chunk,
            backend=backend,
            scheduler=scheduler,
        ),
    )
    rng = np.random.default_rng(seed)

    def batch(n):
        for _ in range(n):
            prompt = [int(t) for t in rng.integers(1, cfg.vocab_size, prompt_len)]
            engine.submit(prompt, max_new_tokens=out_len)
        finished = engine.run(max_ticks=50 * max(n, 1) * out_len)
        if len(finished) != n:
            raise RuntimeError(f"served {len(finished)}/{n} requests")

    batch(min(2, requests))  # warm-up: compile prefill-chunk + decode steps
    engine.reset_metrics()
    batch(requests)
    return engine


@register(
    "serving",
    backends=("pallas", "xla"),
    paper_ref="Ch.1 + Fig 4.3 (inference board under sustained load)",
    description="serving-engine TTFT/latency/throughput sweep",
    quick={"slots": (2,), "prompt_lens": (8,), "out_lens": (8,), "requests": 4,
           "prefill_chunk": 4},
    full={"slots": (2, 4), "prompt_lens": (8, 32), "out_lens": (16,), "requests": 12,
          "prefill_chunk": 8},
)
def bench_serving(slots=(2,), prompt_lens=(8,), out_lens=(8,), requests=4,
                  prefill_chunk=4, scheduler="fcfs", backend="xla") -> list:
    """Each sweep point drives a fresh engine over seeded prompts and reports
    its :class:`~repro.serve.metrics.EngineMetrics` rows.  A warm-up pass per
    point keeps one-time compilation out of TTFT."""
    cfg, model, params = _build_model()
    recs = []
    for ns in slots:
        for pl in prompt_lens:
            for ol in out_lens:
                engine = _drive(
                    cfg, model, params, backend=backend, n_slots=ns,
                    prompt_len=pl, out_len=ol, prefill_chunk=prefill_chunk,
                    scheduler=scheduler, requests=requests,
                )
                recs.extend(
                    engine.metrics.to_records(
                        benchmark="serving",
                        prefix=f"serving_s{ns}_p{pl}_o{ol}",
                        x=f"s{ns}:p{pl}:o{ol}",
                    )
                )
    return recs
