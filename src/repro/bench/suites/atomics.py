"""Tab 4.2 / Fig 4.1 analogue — update throughput under contention.

TPU has no hardware atomics; colliding scatter-adds serialize inside the
XLA scatter, so throughput vs. collision multiplicity plays the role of the
paper's atomicAdd contention scenarios."""
from __future__ import annotations

from repro.core import probes
from repro.core.registry import register

from ..schema import BenchRecord


@register(
    "atomics",
    paper_ref="Tab 4.2 / Fig 4.1",
    description="scatter-add contention",
    quick={"n_updates": 1 << 14, "collisions": (1, 2, 4, 8, 16, 32)},
    full={"n_updates": 1 << 18, "collisions": (1, 2, 4, 8, 16, 32)},
)
def bench_atomics(n_updates=1 << 14, collisions=(1, 2, 4, 8, 16, 32)) -> list:
    res = probes.probe_scatter_contention(n_updates=n_updates, collisions=collisions)
    return [
        BenchRecord(
            name=f"scatter_contention_x{c}",
            benchmark="atomics",
            x=c,
            value=r,
            unit="Mupdates/s",
            metrics={"us_per_call": n_updates / (r * 1e6) if r else 0.0},
            info=f"{c} colliding updates per address",
        )
        for c, r in zip(res.x, res.y)
    ]
