"""Fig 3.5 / Tab 3.1 / Fig 3.6 analogue — memory-hierarchy dissection via
fine-grained pointer chase.

Measured on the live backend (recovers the HOST's L1/L2/L3/DRAM — the
end-to-end validation of the Mei&Chu methodology), plus the modeled TPU v5e
hierarchy (VMEM/HBM) from the HardwareModel.
"""
from __future__ import annotations

from repro.core import probes
from repro.hw import TPU_V5E
from repro.core.registry import register

from ..schema import BenchRecord


@register(
    "memhier",
    paper_ref="Fig 3.5 / Tab 3.1",
    description="pointer-chase hierarchy dissection",
    quick={"min_pow": 12, "max_pow": 25, "steps": 1 << 14},
    full={"min_pow": 12, "max_pow": 28, "steps": 1 << 14},
)
def bench_memhier(min_pow=12, max_pow=25, steps=1 << 14) -> list:
    sizes = [1 << p for p in range(min_pow, max_pow)]
    res = probes.probe_pointer_chase(sizes, steps=steps)
    plats, caps = probes.analyze_pointer_chase(res)
    recs = [
        BenchRecord(
            name=f"pchase_host_{s >> 10}KiB",
            benchmark="memhier",
            x=s,
            value=lat,
            unit="ns/load",
            metrics={"us_per_call": lat * 1e-3},
        )
        for s, lat in zip(res.x, res.y)
    ]
    for i, p in enumerate(plats):
        recs.append(
            BenchRecord(
                name=f"pchase_host_level{i}",
                benchmark="memhier",
                x=i,
                value=p.latency,
                unit="ns",
                better="info",  # plateau count/capacity varies across hosts
                metrics={"capacity_bytes": int(p.end_size)},
                info=f"capacity~{p.end_size >> 10}KiB latency {p.latency:.2f}ns",
            )
        )
    for lvl in TPU_V5E.levels:
        recs.append(
            BenchRecord(
                name=f"pchase_tpu_model_{lvl.name}",
                benchmark="memhier",
                x=lvl.name,
                value=lvl.latency_ns,
                unit="ns/load",
                measured=False,
                metrics={"size_bytes": lvl.size_bytes},
                info=f"size {lvl.size_bytes >> 20}MiB lat {lvl.latency_ns:.0f}ns",
            )
        )
    return recs


@register(
    "memhier",
    backends=("pallas", "xla"),
    paper_ref="Fig 3.5 / Tab 3.1",
    description="pointer-chase latency through the kernel dispatch API",
    quick={"min_pow": 12, "max_pow": 16, "steps": 1 << 12},
    full={"min_pow": 12, "max_pow": 22, "steps": 1 << 14},
)
def bench_memhier_backend(min_pow=12, max_pow=16, steps=1 << 12, backend="xla") -> list:
    """The dependent-load walk once per kernel backend — the paper's
    fine-grained-pchase-vs-library contrast (§3.1) as ``memhier[pallas]`` vs
    ``memhier[xla]`` rows in one results file."""
    sizes = [1 << p for p in range(min_pow, max_pow)]
    res = probes.probe_pointer_chase(sizes, steps=steps, backend=backend)
    return [
        BenchRecord(
            name=f"pchase_dispatch_{s >> 10}KiB",
            benchmark="memhier",
            x=s,
            value=lat,
            unit="ns/load",
            metrics={"us_per_call": lat * 1e-3},
            info=f"{backend} backend",
        )
        for s, lat in zip(res.x, res.y)
    ]
