"""Chaos serving benchmark — availability and goodput under injected faults.

The paper's methodology is contrast under controlled stress (the same
workload measured clean vs. thermally throttled, §4.5); this suite restates
that for the hardened serving stack.  One seeded workload runs twice against
a health-monitored two-replica cluster:

- **clean** (``serving_chaos_clean_*``): no faults — the baseline rows,
- **faulted** (``serving_chaos_faulted_*``): the same prompts driven through
  a fixed :class:`~repro.serve.faults.FaultPlan` that crashes a replica,
  dilates another's step times by the §4.5 throttle signature (straggler
  failover), raises one simulated pallas kernel fault (graceful ``xla``
  degradation), poisons one lane's logits with NaN (quarantine + retry),
  and steals free KV pages (admission pressure).

Both runs emit the full cluster row set — TTFT, latency, throughput, plus
the robustness rows (``*_goodput``, ``*_availability``, ``*_faults``) whose
clean-vs-faulted delta is the headline.  The driver *asserts* the chaos
contract before reporting: zero lost sessions, and token-exact output for
every non-deadline session against the clean run.  Fault injection is
host-side flag flipping (no sleeps, no wall-clock coupling), so the faulted
rows are as reproducible as the clean ones.
"""
from __future__ import annotations

import numpy as np

from repro.core.registry import register

from .serving import _build_model


def _fault_plan():
    """Fixed schedule exercising every fault kind (see module docstring).

    Ticks are injector ticks: the crash lands while prompts are mid-decode,
    the straggler window overlaps the crash outage (the skip-last-replica
    guard keeps the cluster alive), and the NaN/kernel/page faults hit the
    surviving replica once failover has concentrated load on it.
    """
    from repro.serve import Fault, FaultPlan

    return FaultPlan(faults=(
        Fault(tick=2, kind="crash", replica=0, duration=4),
        # explicit factor: with 2 replicas the fleet median sits between the
        # healthy and dilated step times, so the throttle-signature default
        # (~1.35x) lands below its own threshold — 4x detects unambiguously
        Fault(tick=3, kind="straggler", replica=1, duration=4, factor=4.0),
        Fault(tick=8, kind="kernel_fault", replica=1),
        Fault(tick=10, kind="nan_logits", replica=1, lanes=(0,), duration=1),
        Fault(tick=11, kind="page_pressure", replica=1, pages=2, duration=3),
    ))


def _drive_chaos(cfg, model, params, *, backend, n_slots, prompt_len, out_len,
                 requests, prefill_chunk, page_size, seed=0, plan=None):
    """One measured cluster run over seeded prompts; ``plan`` switches the
    measured batch from a plain ``run()`` to a fault-injected drive.  The
    warm-up batch also ages each replica past the straggler warm-up gate so
    the measured run's detector is armed.  Returns ``(cluster, sessions)``.
    """
    from repro.serve import (
        ClusterConfig,
        ClusterRouter,
        EngineConfig,
        FaultInjector,
        HealthConfig,
    )

    cluster = ClusterRouter(model, params, ClusterConfig(
        engine=EngineConfig(
            n_slots=n_slots,
            max_len=prompt_len + out_len + 1,
            prefill_chunk=prefill_chunk,
            page_size=page_size,
            backend=backend,
        ),
        n_replicas=2,
        router="round_robin",  # deterministic placement for the contrast
        health=HealthConfig(heartbeat_timeout=2, min_samples=3,
                            margin=0.25, cooldown=6, warmup_ticks=6),
    ))
    rng = np.random.default_rng(seed)

    def submit_batch(n):
        return [
            cluster.submit(
                [int(t) for t in rng.integers(1, cfg.vocab_size, prompt_len)],
                max_new_tokens=out_len,
            )
            for _ in range(n)
        ]

    warm = submit_batch(min(2, requests))
    cluster.run(max_ticks=50 * max(len(warm), 1) * out_len)
    cluster.reset_metrics()
    sessions = submit_batch(requests)
    if plan is None:
        cluster.run(max_ticks=50 * requests * out_len)
    else:
        FaultInjector(plan, cluster).run(max_ticks=50 * requests * out_len)
    done = sum(s.done for s in sessions)
    if done != requests:
        raise RuntimeError(f"cluster served {done}/{requests} requests")
    return cluster, sessions


@register(
    "serving_chaos",
    backends=("pallas", "xla"),
    paper_ref="§4.5 (same workload, clean vs throttled contrast)",
    description="cluster goodput/availability under a fixed fault schedule vs clean",
    quick={"n_slots": 2, "prompt_len": 8, "out_len": 8, "requests": 6,
           "prefill_chunk": 4, "page_size": 4},
    full={"n_slots": 2, "prompt_len": 8, "out_len": 12, "requests": 10,
          "prefill_chunk": 4, "page_size": 4},
)
def bench_serving_chaos(n_slots=2, prompt_len=8, out_len=8, requests=6,
                        prefill_chunk=4, page_size=4, seed=0,
                        backend="xla") -> list:
    """Clean and faulted runs over the same seeded workload; the faulted
    run must lose nothing and stay token-exact (non-deadline sessions)
    before its rows are reported."""
    cfg, model, params = _build_model()
    common = dict(backend=backend, n_slots=n_slots, prompt_len=prompt_len,
                  out_len=out_len, requests=requests,
                  prefill_chunk=prefill_chunk, page_size=page_size, seed=seed)
    clean, clean_sessions = _drive_chaos(cfg, model, params, **common)
    faulted, faulted_sessions = _drive_chaos(
        cfg, model, params, plan=_fault_plan(), **common
    )
    # the chaos contract gates reporting: same prompts, same tokens
    for ref, s in zip(clean_sessions, faulted_sessions):
        if s.finish_reason == "deadline":
            continue
        if s.out != ref.out:
            raise RuntimeError(
                f"chaos run diverged from clean run on rid {s.rid}: "
                f"{s.out} != {ref.out}"
            )
    recs = []
    recs.extend(clean.to_records(
        "serving_chaos", "serving_chaos_clean", x="clean"))
    recs.extend(faulted.to_records(
        "serving_chaos", "serving_chaos_faulted", x="faulted"))
    return recs
