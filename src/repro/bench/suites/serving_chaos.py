"""Chaos serving benchmark — availability and goodput under injected faults.

The paper's methodology is contrast under controlled stress (the same
workload measured clean vs. thermally throttled, §4.5); this suite restates
that for the hardened serving stack.  One seeded workload runs twice against
a health-monitored two-replica cluster:

- **clean** (``serving_chaos_clean_*``): no faults — the baseline rows,
- **faulted** (``serving_chaos_faulted_*``): the same prompts driven through
  a fixed :class:`~repro.serve.faults.FaultPlan` that crashes a replica,
  dilates another's step times by the §4.5 throttle signature (straggler
  failover), raises one simulated pallas kernel fault (graceful ``xla``
  degradation), poisons one lane's logits with NaN (quarantine + retry),
  and steals free KV pages (admission pressure).

On pallas-like backends two **guarded** legs run the same workload with the
numerics guard armed (``EngineConfig(guard="shadow")``), each inside
:func:`repro.kernels.guard.isolated` so intentional injections never leak
into the process-global guard state:

- **guarded-clean**: shadow-checks every compiled step against the ``xla``
  oracle and must report *zero* drift and token-exact output — the
  false-positive gate for the tolerance ladder,
- **guarded-faulted** (``serving_chaos_guarded_*``): an op-targeted
  :func:`_guard_fault_plan` injects a seeded numeric drift on ``matmul``
  and a simulated pallas fault on ``flash_attention``; the guard must
  detect every injected drift call, quarantine exactly those two ops (no
  whole-engine degradation), revive them once the faults expire, and still
  emit tokens exactly matching the clean run.

Both runs emit the full cluster row set — TTFT, latency, throughput, plus
the robustness rows (``*_goodput``, ``*_availability``, ``*_faults``) whose
clean-vs-faulted delta is the headline.  The driver *asserts* the chaos
contract before reporting: zero lost sessions, and token-exact output for
every non-deadline session against the clean run.  Fault injection is
host-side flag flipping (no sleeps, no wall-clock coupling), so the faulted
rows are as reproducible as the clean ones.
"""
from __future__ import annotations

import numpy as np

from repro.core.registry import register

from .serving import _build_model


def _fault_plan():
    """Fixed schedule exercising every fault kind (see module docstring).

    Ticks are injector ticks: the crash lands while prompts are mid-decode,
    the straggler window overlaps the crash outage (the skip-last-replica
    guard keeps the cluster alive), and the NaN/kernel/page faults hit the
    surviving replica once failover has concentrated load on it.
    """
    from repro.serve import Fault, FaultPlan

    return FaultPlan(faults=(
        Fault(tick=2, kind="crash", replica=0, duration=4),
        # explicit factor: with 2 replicas the fleet median sits between the
        # healthy and dilated step times, so the throttle-signature default
        # (~1.35x) lands below its own threshold — 4x detects unambiguously
        Fault(tick=3, kind="straggler", replica=1, duration=4, factor=4.0),
        Fault(tick=8, kind="kernel_fault", replica=1),
        Fault(tick=10, kind="nan_logits", replica=1, lanes=(0,), duration=1),
        Fault(tick=11, kind="page_pressure", replica=1, pages=2, duration=3),
    ))


def _guard_fault_plan():
    """Op-targeted schedule for the guarded legs: a seeded numeric drift on
    ``matmul`` (caught by the shadow oracle, attributed, quarantined) and a
    simulated pallas fault on ``flash_attention`` (attributed to the op
    instead of triggering a whole-engine degrade).  Both expire mid-run so
    the breaker's cooldown + half-open probe revives the ops before the
    drive ends.
    """
    from repro.serve import Fault, FaultPlan

    return FaultPlan(seed=7, faults=(
        Fault(tick=3, kind="kernel_drift", replica=1, duration=2,
              op="matmul", drift_scale=0.25),
        Fault(tick=7, kind="kernel_fault", replica=1, op="flash_attention"),
    ))


def _drive_chaos(cfg, model, params, *, backend, n_slots, prompt_len, out_len,
                 requests, prefill_chunk, page_size, seed=0, plan=None,
                 guard=None):
    """One measured cluster run over seeded prompts; ``plan`` switches the
    measured batch from a plain ``run()`` to a fault-injected drive, and
    ``guard`` arms the engines' numerics guard (short re-probe cooldown so
    quarantined ops revive within the drive).  The warm-up batch also ages
    each replica past the straggler warm-up gate so the measured run's
    detector is armed.  Returns ``(cluster, sessions)``.
    """
    from repro.serve import (
        ClusterConfig,
        ClusterRouter,
        EngineConfig,
        FaultInjector,
        HealthConfig,
    )

    cluster = ClusterRouter(model, params, ClusterConfig(
        engine=EngineConfig(
            n_slots=n_slots,
            max_len=prompt_len + out_len + 1,
            prefill_chunk=prefill_chunk,
            page_size=page_size,
            backend=backend,
            guard=guard,
            guard_cooldown=2 if guard else 8,
        ),
        n_replicas=2,
        router="round_robin",  # deterministic placement for the contrast
        # guarded legs shadow-execute every step, which reshapes wall-clock
        # step times; they gate numerics, not timing, so the (inherently
        # wall-clock) straggler detector stays off there for determinism
        health=HealthConfig(heartbeat_timeout=2, min_samples=3,
                            margin=0.25, cooldown=6, warmup_ticks=6,
                            straggler=guard is None),
    ))
    rng = np.random.default_rng(seed)

    def submit_batch(n):
        return [
            cluster.submit(
                [int(t) for t in rng.integers(1, cfg.vocab_size, prompt_len)],
                max_new_tokens=out_len,
            )
            for _ in range(n)
        ]

    warm = submit_batch(min(2, requests))
    cluster.run(max_ticks=50 * max(len(warm), 1) * out_len)
    cluster.reset_metrics()
    sessions = submit_batch(requests)
    if plan is None:
        cluster.run(max_ticks=50 * requests * out_len)
    else:
        FaultInjector(plan, cluster).run(max_ticks=50 * requests * out_len)
    done = sum(s.done for s in sessions)
    if done != requests:
        raise RuntimeError(f"cluster served {done}/{requests} requests")
    return cluster, sessions


@register(
    "serving_chaos",
    backends=("pallas", "xla"),
    paper_ref="§4.5 (same workload, clean vs throttled contrast)",
    description="cluster goodput/availability under a fixed fault schedule vs clean",
    quick={"n_slots": 2, "prompt_len": 8, "out_len": 8, "requests": 6,
           "prefill_chunk": 4, "page_size": 4},
    full={"n_slots": 2, "prompt_len": 8, "out_len": 12, "requests": 10,
          "prefill_chunk": 4, "page_size": 4},
)
def bench_serving_chaos(n_slots=2, prompt_len=8, out_len=8, requests=6,
                        prefill_chunk=4, page_size=4, seed=0,
                        backend="xla") -> list:
    """Clean and faulted runs over the same seeded workload; the faulted
    run must lose nothing and stay token-exact (non-deadline sessions)
    before its rows are reported.  On pallas-like backends two guarded
    legs additionally prove the numerics guard's contract (zero drift on
    clean, 100% detection + op-scoped quarantine on injected drift)."""
    cfg, model, params = _build_model()
    common = dict(backend=backend, n_slots=n_slots, prompt_len=prompt_len,
                  out_len=out_len, requests=requests,
                  prefill_chunk=prefill_chunk, page_size=page_size, seed=seed)
    clean, clean_sessions = _drive_chaos(cfg, model, params, **common)
    faulted, faulted_sessions = _drive_chaos(
        cfg, model, params, plan=_fault_plan(), **common
    )
    # the chaos contract gates reporting: same prompts, same tokens
    for ref, s in zip(clean_sessions, faulted_sessions):
        if s.finish_reason == "deadline":
            continue
        if s.out != ref.out:
            raise RuntimeError(
                f"chaos run diverged from clean run on rid {s.rid}: "
                f"{s.out} != {ref.out}"
            )
    recs = []
    recs.extend(clean.to_records(
        "serving_chaos", "serving_chaos_clean", x="clean"))
    recs.extend(faulted.to_records(
        "serving_chaos", "serving_chaos_faulted", x="faulted"))
    # the guarded legs' fixed inject->detect->quarantine->heal schedule
    # needs enough measured ticks to play out; trimmed smoke workloads
    # (tier-1's sweep overrides) skip them — tests/test_guard.py covers the
    # same contract at engine scale
    if backend != "xla" and requests * out_len >= 32:
        recs.extend(_guarded_legs(cfg, model, params, clean_sessions, common))
    return recs


def _guarded_legs(cfg, model, params, clean_sessions, common) -> list:
    """Run the guarded-clean and guarded-faulted legs and assert the guard
    contract (see module docstring).  Each leg isolates the process-global
    guard state so intentional injections cannot leak into other suites or
    the runner's clean-run drift gate.
    """
    from repro.kernels import guard as kguard

    with kguard.isolated():
        gclean, gclean_sessions = _drive_chaos(
            cfg, model, params, guard="shadow", **common)
        gclean_sum = gclean.summary()
    for ref, s in zip(clean_sessions, gclean_sessions):
        if s.out != ref.out:
            raise RuntimeError(
                f"guarded clean run diverged from clean run on rid {s.rid}: "
                f"{s.out} != {ref.out}"
            )
    if gclean_sum["guard_checks"] == 0:
        raise RuntimeError("guarded clean run performed no shadow checks")
    if gclean_sum["drift_events"] or gclean_sum["op_degradations"]:
        raise RuntimeError(
            "numerics guard flagged a clean run: "
            f"{gclean_sum['drift_events']} drift event(s), "
            f"{gclean_sum['op_degradations']} op degradation(s)"
        )

    with kguard.isolated():
        guarded, guarded_sessions = _drive_chaos(
            cfg, model, params, plan=_guard_fault_plan(), guard="shadow",
            **common)
        gsum = guarded.summary()
        gmetrics = kguard.metrics()
        injected = sum(
            r.engine._injected_drift_calls for r in guarded.replicas
        )
    for ref, s in zip(clean_sessions, guarded_sessions):
        if s.finish_reason == "deadline":
            continue
        if s.out != ref.out:
            raise RuntimeError(
                f"guarded faulted run diverged from clean run on rid "
                f"{s.rid}: {s.out} != {ref.out}"
            )
    if injected < 1:
        raise RuntimeError("guard fault plan injected no drift calls")
    if gsum["drift_events"] != injected:
        raise RuntimeError(
            f"guard detected {gsum['drift_events']} of {injected} "
            "injected drift call(s)"
        )
    if gmetrics.quarantined_ops != {"matmul", "flash_attention"}:
        raise RuntimeError(
            "guard quarantined "
            f"{sorted(gmetrics.quarantined_ops)}, expected exactly "
            "['flash_attention', 'matmul']"
        )
    if gsum["degradations"]:
        raise RuntimeError(
            "guarded run fell back to whole-engine degradation "
            f"({gsum['degradations']}x) instead of per-op quarantine"
        )
    if gsum["op_revivals"] < 1:
        raise RuntimeError(
            "breaker never revived a quarantined op within the drive"
        )
    recs = list(guarded.to_records(
        "serving_chaos", "serving_chaos_guarded", x="guarded"))
    recs.extend(gmetrics.to_records(
        "serving_chaos", "serving_chaos_guard", x="guarded"))
    return recs
