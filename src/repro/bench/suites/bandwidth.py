"""Tab 3.2 / Tab 3.4 / Fig 3.12 / Fig 3.13 analogue — per-level streaming
bandwidth + block-shape (access-width) sweep."""
from __future__ import annotations

from repro.core import probes
from repro.hw import TPU_V5E
from repro.core.registry import register

from ..schema import BenchRecord


@register(
    "bandwidth",
    paper_ref="Tab 3.2/3.4, Fig 3.12/3.13",
    description="per-level streaming bandwidth",
    quick={"min_pow": 18, "max_pow": 24, "block_footprint": 1 << 22},
    full={"min_pow": 18, "max_pow": 28, "block_footprint": 1 << 22},
)
def bench_bandwidth(min_pow=18, max_pow=24, block_footprint=1 << 22) -> list:
    recs = []
    res = probes.probe_stream_bandwidth([1 << p for p in range(min_pow, max_pow)])
    for f, bw in zip(res.x, res.y):
        recs.append(
            BenchRecord(
                name=f"streambw_host_{f >> 10}KiB",
                benchmark="bandwidth",
                x=f,
                value=bw,
                unit="GB/s",
                metrics={"us_per_call": f / (bw * 1e9) * 1e6},
            )
        )
    blk = probes.probe_block_shape_bandwidth(footprint=block_footprint)
    for w, bw in zip(blk.x, blk.y):
        recs.append(
            BenchRecord(
                name=f"axpybw_host_width{w}",
                benchmark="bandwidth",
                x=w,
                value=bw,
                unit="GB/s",
                metrics={"us_per_call": block_footprint * 12 / (bw * 1e9) * 1e6},
            )
        )
    for lvl in TPU_V5E.levels:
        if lvl.bandwidth_Bps:
            recs.append(
                BenchRecord(
                    name=f"streambw_tpu_model_{lvl.name}",
                    benchmark="bandwidth",
                    x=lvl.name,
                    value=lvl.bandwidth_Bps / 1e9,
                    unit="GB/s",
                    measured=False,
                    info=f"{lvl.name} modeled sustained bandwidth",
                )
            )
    return recs


@register(
    "bandwidth",
    backends=("pallas", "xla"),
    paper_ref="Tab 3.2/3.4, Fig 3.12/3.13",
    description="streaming bandwidth through the kernel dispatch API",
    quick={"min_pow": 18, "max_pow": 21},
    full={"min_pow": 18, "max_pow": 25},
)
def bench_bandwidth_backend(min_pow=18, max_pow=21, backend="xla") -> list:
    """The same streaming-reduce measurement once per kernel backend —
    ``bandwidth[pallas]`` vs ``bandwidth[xla]`` restates the paper's
    hand-kernel-vs-library bandwidth columns on one results file."""
    res = probes.probe_stream_bandwidth(
        [1 << p for p in range(min_pow, max_pow)], backend=backend
    )
    return [
        BenchRecord(
            name=f"streambw_dispatch_{f >> 10}KiB",
            benchmark="bandwidth",
            x=f,
            value=bw,
            unit="GB/s",
            metrics={"us_per_call": f / (bw * 1e9) * 1e6},
            info=f"{backend} backend",
        )
        for f, bw in zip(res.x, res.y)
    ]
