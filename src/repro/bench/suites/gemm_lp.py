"""Tab 3.1 / 4.3 low-precision GEMM suite — the paper's TensorCore story.

The headline of the T4 dissection is the per-dtype throughput ladder:
fp16 TensorCore matmul runs ~5.8x fp32, int8 ~1.8x fp16 (Table 4.3).  This
suite reproduces that contrast as *measured* schema-v1 records — a dtype x
size sweep through the kernel-dispatch API where each dot accumulates via
``preferred_element_type`` (int8 -> int32, floats -> fp32) — plus the
*modeled* ladder for a reference part from the :mod:`repro.hw` spec
database, so a results file carries both the measurement and the
paper-anchored ratios it is validated against.

Registered per backend (``gemm_lp[pallas]`` / ``gemm_lp[xla]``): the Pallas
kernel path and the XLA library path measure the same sweep side by side.
Dtypes the current backend/platform cannot multiply (e.g. fp8 on CPU XLA)
are skipped with a note rather than failing the suite.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

import repro.hw as hw_db
from repro.core.registry import register
from repro.core.timing import time_fn
from repro.kernels import api

from ..schema import BenchRecord

_JNP_DTYPES = {
    "float32": jnp.float32,
    "bfloat16": jnp.bfloat16,
    "float16": jnp.float16,
    "int8": jnp.int8,
    "float8_e4m3fn": getattr(jnp, "float8_e4m3fn", None),
}
_ACC_DTYPES = {"int8": jnp.int32}  # everything else accumulates in fp32

# ratio records anchor each precision against fp32 (the paper's Tab 4.3
# presentation: "fp16 runs 5.8x fp32, int8 10.4x"), plus the int8-vs-fp16
# TensorCore step the T4 story highlights
_RATIO_ANCHOR = "float32"
_EXTRA_RATIOS = (("int8", "float16"),)


def _measure_one(n: int, dtype: str, backend: str):
    """GFLOP/s of an n^3 matmul in ``dtype`` on ``backend`` (None if the
    dtype cannot run there)."""
    jdt = _JNP_DTYPES.get(dtype)
    if jdt is None:
        return None
    acc = _ACC_DTYPES.get(dtype, jnp.float32)
    a = jnp.ones((n, n), jdt)
    b = jnp.ones((n, n), jdt)
    try:
        if backend == "xla":
            fn = jax.jit(
                lambda a, b: jax.lax.dot_general(
                    a, b, (((1,), (0,)), ((), ())), preferred_element_type=acc
                )
            )
        else:
            fn = api.matmul.bound(a, b, out_dtype=acc, backend=backend)
        t = time_fn(fn, a, b, warmup=2, reps=5)
    except Exception:  # unsupported dtype on this backend/platform
        return None
    return 2 * n**3 / t.min_s / 1e9


@register(
    "gemm_lp",
    backends=("pallas", "xla"),
    paper_ref="Tab 3.1 / Tab 4.3 (TensorCore dtypes)",
    description="low-precision matmul throughput: dtype x size sweep + modeled ladder",
    quick={"sizes": (128, 256), "dtypes": ("float32", "bfloat16", "int8")},
    full={
        "sizes": (256, 512, 1024),
        "dtypes": ("float32", "bfloat16", "float16", "int8", "float8_e4m3fn"),
    },
)
def bench_gemm_lp(
    sizes=(128, 256),
    dtypes=("float32", "bfloat16", "int8"),
    hw="T4",
    backend="xla",
) -> list:
    part = hw_db.resolve(hw)
    recs, skipped, measured = [], [], {}
    for dt in dtypes:
        for n in sizes:
            g = _measure_one(n, dt, backend)
            if g is None:
                skipped.append(f"{dt}:{n}")
                continue
            measured[(dt, n)] = g
            recs.append(
                BenchRecord(
                    name=f"gemm_lp_{dt}:{n}",
                    benchmark="gemm_lp",
                    x=f"{dt}:{n}",
                    value=g,
                    unit="GFLOP/s",
                    metrics={"us_per_call": 2 * n**3 / (g * 1e9) * 1e6},
                    info=f"{backend} backend, preferred_element_type accumulate",
                )
            )
    # measured dtype ratios at the largest size — the host's own ladder
    # (info rows: host CPUs have no TensorCores, so these won't match the
    # GPU ladder; the point is that the *record shape* matches the model's)
    top = max(sizes)
    for dt in dtypes:
        if dt != _RATIO_ANCHOR and (dt, top) in measured and (_RATIO_ANCHOR, top) in measured:
            recs.append(
                BenchRecord(
                    name=f"gemm_lp_measured_ratio_{dt}_over_{_RATIO_ANCHOR}",
                    benchmark="gemm_lp",
                    x=f"{dt}/{_RATIO_ANCHOR}",
                    value=measured[(dt, top)] / measured[(_RATIO_ANCHOR, top)],
                    unit="x",
                    better="info",
                    info=f"measured host ladder at n={top}",
                )
            )
    # the modeled ladder from the spec DB: per-dtype peaks for the reference
    # part and the paper-anchored ratios the validation test asserts on
    for dt in part.dtypes():
        recs.append(
            BenchRecord(
                name=f"gemm_lp_model_{part.name}_{dt}",
                benchmark="gemm_lp",
                x=dt,
                value=part.peak(dt) / 1e12,
                unit="TFLOP/s",
                measured=False,
                info=f"spec-DB peak ({part.source})",
            )
        )
    ratio_pairs = [
        (dt, _RATIO_ANCHOR) for dt in part.dtypes() if dt != _RATIO_ANCHOR
    ] + list(_EXTRA_RATIOS)
    for hi, lo in ratio_pairs:
        if part.supports(lo) and part.supports(hi):
            recs.append(
                BenchRecord(
                    name=f"gemm_lp_model_{part.name}_ratio_{hi}_over_{lo}",
                    benchmark="gemm_lp",
                    x=f"{hi}/{lo}",
                    value=part.peak(hi) / part.peak(lo),
                    unit="x",
                    better="info",
                    measured=False,
                    info="modeled dtype ladder (paper Tab 4.3 for T4)",
                )
            )
    if skipped:
        recs.append(
            BenchRecord(
                name="gemm_lp_skipped",
                benchmark="gemm_lp",
                x=None,
                value=float(len(skipped)),
                unit="points",
                better="info",
                info="unsupported on this backend/platform: " + ", ".join(skipped),
            )
        )
    return recs
