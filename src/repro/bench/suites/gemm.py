"""Fig 4.2 / Tab 4.3 analogue — matmul arithmetic throughput across dtypes
and sizes (Tensor Core study -> MXU study).

Host-measured XLA numbers validate the harness; the modeled TPU columns
report the roofline-bounded MXU throughput from the HardwareModel, including
the paper-table comparison (T4 measured peaks from Tab 4.3 in T4_PAPER)."""
from __future__ import annotations

from repro.core import probes
from repro.core.autotune import choose_matmul_tiles
from repro.hw import T4_PAPER, TPU_V5E
from repro.core.registry import register

from ..schema import BenchRecord


@register(
    "gemm",
    paper_ref="Fig 4.2 / Tab 4.3",
    description="matmul throughput across dtypes",
    quick={"sizes": (256, 512)},
    full={"sizes": (256, 512, 1024, 2048)},
)
def bench_gemm(sizes=(256, 512)) -> list:
    res = probes.probe_matmul_throughput(sizes=sizes, dtypes=("float32",))
    recs = []
    for key, g in zip(res.x, res.y):
        n = int(key.split(":")[1])
        recs.append(
            BenchRecord(
                name=f"gemm_host_{key}",
                benchmark="gemm",
                x=key,
                value=g,
                unit="GFLOP/s",
                metrics={"us_per_call": 2 * n**3 / (g * 1e9) * 1e6},
            )
        )
    for dt in ("bfloat16", "int8"):
        peak = TPU_V5E.peak(dt)
        eb = 2 if dt == "bfloat16" else 1
        for n in (1024, 4096, 8192):
            flops = 2 * n**3
            t = max(flops / peak, 3 * n * n * eb / TPU_V5E.main_memory_Bps)
            tile = choose_matmul_tiles(n, n, n, dt)
            recs.append(
                BenchRecord(
                    name=f"gemm_tpu_model_{dt}_{n}",
                    benchmark="gemm",
                    x=f"{dt}:{n}",
                    value=flops / t / 1e12,
                    unit="TFLOP/s",
                    measured=False,
                    metrics={"us_per_call": t * 1e6},
                    info=f"roofline-bounded MXU, tiles=({tile.bm},{tile.bk},{tile.bn})",
                )
            )
    for dt, v in T4_PAPER.peak_flops.items():
        recs.append(
            BenchRecord(
                name=f"gemm_t4_paper_{dt}",
                benchmark="gemm",
                x=dt,
                value=v / 1e12,
                unit="TFLOP/s",
                better="info",
                measured=False,
                info="paper Tab 4.3 measured T4 peak (cross-check anchor)",
            )
        )
    return recs


@register(
    "gemm",
    backends=("pallas", "xla"),
    paper_ref="Fig 4.2 / Tab 4.3",
    description="matmul throughput through the kernel dispatch API",
    quick={"sizes": (256, 512)},
    full={"sizes": (256, 512, 1024)},
)
def bench_gemm_backend(sizes=(256, 512), backend="xla") -> list:
    """The same GEMM measurement registered once per kernel backend —
    ``gemm[pallas]`` vs ``gemm[xla]`` is the paper's Tensor-Core-vs-CUDA-core
    side-by-side, restated as Pallas-kernel vs XLA-library on one results
    file.  Tiles come from ``core.autotune`` via the policy."""
    from repro.kernels.api import kernel_policy

    with kernel_policy(autotune=True):
        res = probes.probe_matmul_throughput(
            sizes=sizes, dtypes=("float32",), backend=backend
        )
    recs = []
    for key, g in zip(res.x, res.y):
        n = int(key.split(":")[1])
        recs.append(
            BenchRecord(
                name=f"gemm_dispatch_{key}",
                benchmark="gemm",
                x=key,
                value=g,
                unit="GFLOP/s",
                metrics={"us_per_call": 2 * n**3 / (g * 1e9) * 1e6},
                info=f"{backend} backend",
            )
        )
    return recs
