"""Tab 2.1 analogue — work-unit <-> execution-unit mapping.

The paper shows warps colliding on a Turing scheduler (same index mod 4)
halve throughput.  TPU grid cells execute sequentially on the core, so
throughput/program must stay FLAT — this probe demonstrates that contrast
(and catches any surprise serialization cliffs)."""
from __future__ import annotations

from repro.core import probes
from repro.core.registry import register

from ..schema import BenchRecord


@register(
    "scheduler",
    paper_ref="Tab 2.1",
    description="work-unit/execution-unit occupancy",
    quick={"rows_per_program": 64, "programs": (1, 2, 3, 4, 6, 8)},
    full={"rows_per_program": 256, "programs": (1, 2, 3, 4, 6, 8)},
)
def bench_scheduler(rows_per_program=64, programs=(1, 2, 3, 4, 6, 8)) -> list:
    res = probes.probe_grid_occupancy(
        rows_per_program=rows_per_program, programs=programs
    )
    base = res.y[0] or 1.0
    return [
        BenchRecord(
            name=f"grid_occupancy_p{p}",
            benchmark="scheduler",
            x=p,
            value=bw,
            unit="GB/s",
            metrics={"ratio_vs_1program": bw / base},
            info=f"{bw / base:.2f}x of 1-program",
        )
        for p, bw in zip(res.x, res.y)
    ]


@register(
    "scheduler",
    backends=("pallas", "xla"),
    paper_ref="Tab 2.1",
    description="grid occupancy through the kernel dispatch API",
    quick={"rows_per_program": 32, "programs": (1, 2, 4)},
    full={"rows_per_program": 256, "programs": (1, 2, 3, 4, 6, 8)},
)
def bench_scheduler_backend(rows_per_program=32, programs=(1, 2, 4), backend="xla") -> list:
    """Occupancy sweep once per kernel backend: the Pallas grid is the
    work-unit axis under study; the XLA rows are the fused-library baseline
    with no grid at all — the Tab 2.1 contrast as a results-file diff."""
    res = probes.probe_grid_occupancy(
        rows_per_program=rows_per_program, programs=programs, backend=backend
    )
    base = res.y[0] or 1.0
    return [
        BenchRecord(
            name=f"grid_occupancy_dispatch_p{p}",
            benchmark="scheduler",
            x=p,
            value=bw,
            unit="GB/s",
            metrics={"ratio_vs_1program": bw / base},
            info=f"{backend} backend, {bw / base:.2f}x of 1-program",
        )
        for p, bw in zip(res.x, res.y)
    ]
