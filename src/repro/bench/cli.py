"""``python -m repro.bench`` — run | list | compare | baseline | trend.

    run       execute registered benchmarks, write schema-versioned JSON
    list      show registered benchmarks with paper refs and sweep grids
    compare   gate a results file against the checked-in baselines
    baseline  (re)generate baseline files from a results file
    trend     aggregate per-commit BENCH_<sha>.json artifacts into a
              perf-over-time report (markdown or JSON)

Exit codes: ``run`` is non-zero if any benchmark errored, or — under
``--guard`` — if the numerics guard saw any drift or saturation on what
should be a clean run; ``compare`` is non-zero if the gate fails (unless
``--warn-only``); ``trend`` is non-zero only on input errors (it reports,
it does not gate).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.core import registry

from . import baseline as bl
from . import runner
from . import trend as trend_mod
from .schema import BenchResult, SchemaError


def _cmd_list(args) -> int:
    runner.load_suites()
    specs = registry.specs()
    if args.json:
        print(
            json.dumps(
                [
                    {
                        "name": s.name,
                        "backend": s.backend,
                        "paper_ref": s.paper_ref,
                        "description": s.description,
                        "quick": s.quick,
                        "full": s.full,
                    }
                    for s in specs
                ],
                indent=2,
                default=str,
            )
        )
        return 0
    w = max((len(s.name) for s in specs), default=4)
    bw = max((len(s.backend) for s in specs), default=0)
    for s in specs:
        tag = f"{s.backend:<{bw}}  " if bw else ""
        print(f"{s.name:<{w}}  {tag}{s.paper_ref:<24}  {s.description}")
    return 0


def _cmd_run(args) -> int:
    mode = args.mode or ("full" if args.full else "quick")
    only = list(args.benchmarks or []) + list(args.only or [])
    if only and not runner.select(only):
        print(
            f"error: {' '.join(only)} matches no registered benchmark "
            f"(have: {', '.join(runner.select())})",
            file=sys.stderr,
        )
        return 2
    result = runner.run_benchmarks(
        only=only or None, mode=mode, out_path=args.out, verbose=args.verbose,
        guard=args.guard,
    )
    if args.csv:
        print("name,value,unit,derived")
        for r in result.records:
            print(f"{r.name},{r.value:.4f},{r.unit},{r.info.replace(',', ';')}")
    elif not args.out:
        print(result.to_json())
    else:
        print(
            f"wrote {args.out}: {len(result.records)} records from "
            f"{len(result.benchmarks())} benchmarks, {len(result.errors)} errors"
        )
    for name, err in sorted(result.errors.items()):
        print(f"ERROR {name}: {err}", file=sys.stderr)
    if args.guard:
        from repro.kernels import guard as kguard

        m = kguard.metrics()
        print(
            f"guard[{args.guard}]: {m.checks} checks, {m.drift_events} drift, "
            f"{m.saturation_events} saturation, {m.faults} faults, "
            f"quarantined={sorted(m.quarantined_ops) or '[]'}",
            file=sys.stderr,
        )
        if m.drift_events or m.saturation_events:
            print(
                "guard: drift/saturation detected on a clean run — failing",
                file=sys.stderr,
            )
            return 1
    return 1 if result.errors else 0


def _cmd_compare(args) -> int:
    report = bl.compare_files(
        args.results, args.baselines, threshold_scale=args.threshold_scale
    )
    if args.json:
        print(json.dumps(report.to_dict(), indent=2))
    else:
        print(report.format())
    if args.warn_only:
        return 0
    return 0 if report.passed else 1


def _cmd_baseline(args) -> int:
    result = BenchResult.load(args.results)
    paths = bl.write_baselines(result, args.out_dir)
    for p in paths:
        print(f"wrote {p}")
    return 0


def _cmd_trend(args) -> int:
    files = trend_mod.discover(args.paths)
    if not files:
        print(
            f"error: no BENCH_*.json files under {' '.join(args.paths)}",
            file=sys.stderr,
        )
        return 2
    commits = trend_mod.load_commits(files)
    report = trend_mod.build_trend(commits, benchmarks=args.benchmark)
    rendered = (
        trend_mod.format_json(report) if args.json
        else trend_mod.format_markdown(report)
    )
    if args.out:
        Path(args.out).write_text(
            rendered if rendered.endswith("\n") else rendered + "\n"
        )
        print(
            f"wrote {args.out}: {len(report['series'])} series over "
            f"{len(report['commits'])} commit(s)"
        )
    else:
        print(rendered, end="" if rendered.endswith("\n") else "\n")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.bench", description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("list", help="show registered benchmarks")
    p.add_argument("--json", action="store_true")
    p.set_defaults(fn=_cmd_list)

    p = sub.add_parser("run", help="execute benchmarks, emit JSON results")
    p.add_argument(
        "benchmarks", nargs="*",
        help="benchmark name prefixes to run (prefixes sweep up [backend] variants)",
    )
    g = p.add_mutually_exclusive_group()
    g.add_argument("--quick", action="store_true", help="quick grids (default)")
    g.add_argument("--full", action="store_true", help="full paper-scale grids")
    g.add_argument("--mode", choices=("quick", "full"), help="alias for --quick/--full")
    p.add_argument("--only", nargs="*", help="benchmark name prefixes to run (legacy alias)")
    p.add_argument("--out", help="write JSON results to this path")
    p.add_argument("--csv", action="store_true", help="print legacy CSV to stdout")
    p.add_argument(
        "--guard", choices=("sample", "shadow"),
        help="run under the numerics guard; exit 1 on any drift/saturation "
             "(clean-run zero-drift gate)",
    )
    p.add_argument("-v", "--verbose", action="store_true")
    p.set_defaults(fn=_cmd_run)

    p = sub.add_parser("compare", help="gate results against baselines")
    p.add_argument("results", help="results JSON from `run --out`")
    p.add_argument("baselines", help="baseline directory (benchmarks/baselines/)")
    p.add_argument("--threshold-scale", type=float, default=1.0)
    p.add_argument("--warn-only", action="store_true", help="report but exit 0")
    p.add_argument("--json", action="store_true")
    p.set_defaults(fn=_cmd_compare)

    p = sub.add_parser("baseline", help="write baseline files from results")
    p.add_argument("results")
    p.add_argument("--out-dir", default="benchmarks/baselines")
    p.set_defaults(fn=_cmd_baseline)

    p = sub.add_parser(
        "trend", help="aggregate per-commit BENCH_<sha>.json into a report"
    )
    p.add_argument(
        "paths", nargs="+",
        help="directories (scanned for BENCH_*.json) and/or result files",
    )
    p.add_argument(
        "--benchmark", nargs="*",
        help="benchmark/record-name prefixes to include (default: all)",
    )
    p.add_argument("--json", action="store_true", help="emit JSON instead of markdown")
    p.add_argument("--out", help="write the report to this path")
    p.set_defaults(fn=_cmd_trend)

    args = ap.parse_args(argv)
    try:
        return args.fn(args)
    except (SchemaError, FileNotFoundError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 2


if __name__ == "__main__":
    sys.exit(main())
