"""repro.bench — unified benchmark subsystem.

The paper's methodology, made systematic: every paper-table benchmark
registers with :mod:`repro.core.registry` (``@bench.register`` with paper
ref + quick/full sweep grids), the runner executes them into one versioned
JSON schema (:mod:`.schema`), and the baseline store (:mod:`.baseline`)
gates regressions in CI.

    python -m repro.bench list
    python -m repro.bench run --quick --out results.json
    python -m repro.bench compare results.json benchmarks/baselines/
"""
from repro.core.registry import BenchSpec, register

from .baseline import CompareReport, compare, compare_files, load_baselines, write_baselines
from .runner import load_suites, run_benchmarks, select
from .schema import (
    SCHEMA_VERSION,
    BenchRecord,
    BenchResult,
    EnvFingerprint,
    SchemaError,
    validate_result,
)

__all__ = [
    "SCHEMA_VERSION",
    "BenchRecord",
    "BenchResult",
    "BenchSpec",
    "CompareReport",
    "EnvFingerprint",
    "SchemaError",
    "compare",
    "compare_files",
    "load_baselines",
    "load_suites",
    "register",
    "run_benchmarks",
    "select",
    "validate_result",
    "write_baselines",
]
