"""Legacy adapters for the pre-registry ``benchmarks/`` CSV interface.

The old harness passed around ``{"name", "us_per_call", "derived"}`` dicts;
the ``benchmarks/bench_*`` shims call :func:`legacy_rows` so existing
callers keep working while the registry/schema path is the source of truth.
"""
from __future__ import annotations

from .runner import load_suites
from .schema import BenchRecord


def legacy_row(r: BenchRecord) -> dict:
    us = r.metrics.get("us_per_call")
    if us is None:
        if r.unit in ("us", "us/call"):
            us = r.value
        elif r.unit in ("ns", "ns/load", "ns/op"):
            us = r.value * 1e-3
        elif r.unit == "s":
            us = r.value * 1e6
        else:
            us = 0.0
    derived = r.info or f"{r.value:.2f} {r.unit}"
    return {"name": r.name, "us_per_call": float(us), "derived": derived}


def legacy_rows(benchmark: str, quick: bool = True, **overrides) -> list:
    """Run a registered benchmark; return old-style CSV row dicts."""
    from repro.core import registry

    load_suites()
    spec = registry.get(benchmark)
    return [
        legacy_row(r) for r in spec.run("quick" if quick else "full", overrides or None)
    ]
