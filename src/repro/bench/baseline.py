"""Baseline store + regression gate.

Baselines live one file per benchmark in ``benchmarks/baselines/<name>.json``
(schema-versioned, with the env fingerprint of the run that produced them).
``compare`` matches result records to baseline records by name and flags a
regression when the relative change in the worse direction exceeds the
record's threshold:

- ``better="lower"``  (latencies): regression = (cur - base) / base
- ``better="higher"`` (rates):     regression = (base - cur) / base
- ``better="info"``   rows are never gated.

Default thresholds: wall-clock measurements get a wide 0.75 (CI machines
vary; an injected 2x slowdown = 1.0 still trips), deterministic model
outputs get a tight 0.02.  Per-record ``threshold`` overrides are honored,
and ``threshold_scale`` loosens/tightens the whole gate at once.
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field
from pathlib import Path
from typing import Optional

from .schema import SCHEMA_VERSION, BenchResult, SchemaError

DEFAULT_THRESHOLD_MEASURED = 0.75
DEFAULT_THRESHOLD_MODELED = 0.02
_CAP = 1e6  # JSON-safe stand-in for an unbounded regression (rate hit zero)


@dataclass(frozen=True)
class BaselineRecord:
    name: str
    value: float
    unit: str
    better: str
    measured: bool = True
    threshold: Optional[float] = None  # None -> default by `measured`

    def effective_threshold(self, scale: float = 1.0) -> float:
        base = (
            self.threshold
            if self.threshold is not None
            else DEFAULT_THRESHOLD_MEASURED
            if self.measured
            else DEFAULT_THRESHOLD_MODELED
        )
        return base * scale


@dataclass
class Delta:
    name: str
    benchmark: str
    baseline: float
    current: float
    unit: str
    better: str
    regression: float  # relative change in the worse direction
    threshold: float

    @property
    def exceeded(self) -> bool:
        return self.regression > self.threshold

    def describe(self) -> str:
        arrow = "slower" if self.better == "lower" else "lower-throughput"
        return (
            f"{self.name}: {self.baseline:.4g} -> {self.current:.4g} {self.unit} "
            f"({self.regression * 100:+.1f}% {arrow}, threshold {self.threshold * 100:.0f}%)"
        )


@dataclass
class CompareReport:
    regressions: list = field(default_factory=list)  # Delta, exceeded
    improvements: list = field(default_factory=list)  # Delta, better than -threshold
    within: int = 0  # gated records inside the threshold band
    new_records: list = field(default_factory=list)  # in results, no baseline
    missing_records: list = field(default_factory=list)  # in baseline, not in results
    zero_baselines: list = field(default_factory=list)  # baseline value 0: ungateable
    errors: dict = field(default_factory=dict)  # benchmark errors from the run

    @property
    def passed(self) -> bool:
        return not self.regressions and not self.errors

    def to_dict(self) -> dict:
        return {
            "passed": self.passed,
            "regressions": [asdict(d) for d in self.regressions],
            "improvements": [asdict(d) for d in self.improvements],
            "within_threshold": self.within,
            "new_records": self.new_records,
            "missing_records": self.missing_records,
            "zero_baselines": self.zero_baselines,
            "errors": dict(self.errors),
        }

    def format(self) -> str:
        lines = []
        if self.errors:
            lines.append(f"ERRORS ({len(self.errors)} benchmarks failed to run):")
            lines += [f"  {k}: {v}" for k, v in sorted(self.errors.items())]
        if self.regressions:
            lines.append(f"REGRESSIONS ({len(self.regressions)}):")
            lines += [f"  {d.describe()}" for d in self.regressions]
        if self.improvements:
            lines.append(f"improvements ({len(self.improvements)}):")
            lines += [f"  {d.describe()}" for d in self.improvements]
        lines.append(
            f"{self.within} records within threshold, "
            f"{len(self.new_records)} new, {len(self.missing_records)} missing baseline"
        )
        if self.zero_baselines:
            lines.append(
                f"warning: {len(self.zero_baselines)} zero-valued baselines cannot be "
                f"gated: {', '.join(self.zero_baselines)}"
            )
        lines.append("PASS" if self.passed else "FAIL")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# store
# ---------------------------------------------------------------------------
def write_baselines(result: BenchResult, out_dir) -> list:
    """Write one baseline file per benchmark from a results document.

    Only gate-able rows (better != info) are stored.  Returns written paths.
    """
    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    paths = []
    for bench in result.benchmarks():
        recs = [
            {
                "name": r.name,
                "value": r.value,
                "unit": r.unit,
                "better": r.better,
                "measured": r.measured,
            }
            for r in result.records
            # value 0 cannot anchor a relative threshold — don't store it
            if r.benchmark == bench and r.better != "info" and r.value != 0
        ]
        doc = {
            "schema_version": SCHEMA_VERSION,
            "benchmark": bench,
            "generated_from": {"mode": result.mode, "env": asdict(result.env)},
            "records": recs,
        }
        p = out / f"{bench}.json"
        p.write_text(json.dumps(doc, indent=2) + "\n")
        paths.append(p)
    return paths


def load_baselines(baseline_dir) -> dict:
    """Load every baseline file in a directory -> {record name: (benchmark, BaselineRecord)}."""
    d = Path(baseline_dir)
    if not d.is_dir():
        raise SchemaError(f"baseline directory {d} does not exist")
    table = {}
    for p in sorted(d.glob("*.json")):
        doc = json.loads(p.read_text())
        if doc.get("schema_version") != SCHEMA_VERSION:
            raise SchemaError(
                f"{p}: schema_version {doc.get('schema_version')} != {SCHEMA_VERSION}"
            )
        bench = doc.get("benchmark", p.stem)
        for r in doc.get("records", []):
            table[r["name"]] = (bench, BaselineRecord(**r))
    return table


# ---------------------------------------------------------------------------
# gate
# ---------------------------------------------------------------------------
def compare(
    result: BenchResult, baselines: dict, threshold_scale: float = 1.0
) -> CompareReport:
    report = CompareReport(errors=dict(result.errors))
    seen = set()
    for rec in result.records:
        if rec.better == "info":
            continue
        entry = baselines.get(rec.name)
        if entry is None:
            report.new_records.append(rec.name)
            continue
        _, base = entry
        seen.add(rec.name)
        if base.value == 0:
            report.zero_baselines.append(rec.name)  # ungateable; surfaced, not silent
            continue
        # symmetric slowdown ratio: a 2x slowdown is 1.0 whether the unit is
        # time-like (value doubles) or rate-like (value halves)
        if base.better == "lower":
            regression = (rec.value - base.value) / abs(base.value)
        elif rec.value <= 0:
            regression = _CAP
        else:
            regression = min((base.value - rec.value) / abs(rec.value), _CAP)
        delta = Delta(
            name=rec.name,
            benchmark=rec.benchmark,
            baseline=base.value,
            current=rec.value,
            unit=rec.unit,
            better=base.better,
            regression=regression,
            threshold=base.effective_threshold(threshold_scale),
        )
        if delta.exceeded:
            report.regressions.append(delta)
        elif regression < -delta.threshold:
            report.improvements.append(delta)
        else:
            report.within += 1
    report.missing_records = sorted(set(baselines) - seen)
    report.regressions.sort(key=lambda d: -d.regression)
    return report


def compare_files(
    results_path, baseline_dir, threshold_scale: float = 1.0
) -> CompareReport:
    return compare(
        BenchResult.load(results_path), load_baselines(baseline_dir), threshold_scale
    )
