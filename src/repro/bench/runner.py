"""Benchmark runner: execute registered benchmarks, collect a BenchResult.

Importing :mod:`repro.bench.suites` (done lazily here) registers every
paper-table benchmark; the runner then executes the requested subset with the
grid for the requested mode and assembles one schema-versioned result.  A
benchmark that raises is recorded in ``result.errors`` and does not abort the
rest of the run.
"""
from __future__ import annotations

import time
import traceback
from typing import Optional, Sequence

from repro.core import registry

from .schema import BenchResult, EnvFingerprint


def load_suites() -> None:
    """Import the suite package (idempotent registration side effect)."""
    from . import suites  # noqa: F401


def select(only: Optional[Sequence[str]] = None) -> list:
    """Registered benchmark names, optionally filtered by prefix list."""
    load_suites()
    names = registry.names()
    if only:
        names = [n for n in names if any(n.startswith(p) for p in only)]
    return names


def run_benchmarks(
    only: Optional[Sequence[str]] = None,
    mode: str = "quick",
    out_path: Optional[str] = None,
    verbose: bool = False,
) -> BenchResult:
    names = select(only)
    records, errors, timings = [], {}, {}
    for name in names:
        spec = registry.get(name)
        t0 = time.perf_counter()
        try:
            recs = spec.run(mode)
        except Exception as e:
            errors[name] = f"{type(e).__name__}: {e}"
            if verbose:
                traceback.print_exc()
            continue
        finally:
            timings[name] = time.perf_counter() - t0
        for r in recs:
            if r.benchmark != name:
                errors[name] = f"record {r.name!r} claims benchmark {r.benchmark!r}"
                break
        else:
            records.extend(recs)
        if verbose:
            print(f"  {name}: {len(recs)} records in {timings[name]:.1f}s")
    result = BenchResult(
        mode=mode,
        env=EnvFingerprint.capture(),
        records=records,
        errors=errors,
        timings=timings,
    )
    if out_path:
        result.save(out_path)
    return result
