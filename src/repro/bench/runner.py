"""Benchmark runner: execute registered benchmarks, collect a BenchResult.

Importing :mod:`repro.bench.suites` (done lazily here) registers every
paper-table benchmark; the runner then executes the requested subset with the
grid for the requested mode and assembles one schema-versioned result.  A
benchmark that raises is recorded in ``result.errors`` and does not abort the
rest of the run.
"""
from __future__ import annotations

import time
import traceback
from typing import Optional, Sequence

from repro.core import registry

from .schema import BenchResult, EnvFingerprint


def load_suites() -> None:
    """Import the suite package (idempotent registration side effect)."""
    from . import suites  # noqa: F401


def select(only: Optional[Sequence[str]] = None) -> list:
    """Registered benchmark names, optionally filtered by prefix list."""
    load_suites()
    names = registry.names()
    if only:
        names = [n for n in names if any(n.startswith(p) for p in only)]
    return names


def run_benchmarks(
    only: Optional[Sequence[str]] = None,
    mode: str = "quick",
    out_path: Optional[str] = None,
    verbose: bool = False,
    guard: Optional[str] = None,
) -> BenchResult:
    """Run the selected benchmarks; with ``guard`` set (``"sample"`` /
    ``"shadow"``) the whole run executes under the numerics guard
    (``kernel_policy(guard=...)``): a fresh guard state, a canonical
    shadow-verification sweep of every probe-registered kernel op up front
    (timing loops use ``op.bound()`` and are deliberately guard-free, so the
    sweep is what makes a clean-run drift gate meaningful), and the guard's
    schema-v1 activity records appended to the result.  Suites that inject
    faults on purpose isolate their guard state, so a clean run reports
    zero drift.
    """
    names = select(only)
    records, errors, timings = [], {}, {}
    if guard is not None:
        from repro.kernels import api as _kapi
        from repro.kernels import guard as _kguard

        _kguard.reset()
        sweep = _kguard.verify_ops()
        if verbose:
            ok = sum(r.ok for r in sweep.values())
            print(f"  guard: verified {ok}/{len(sweep)} kernel ops clean")
    for name in names:
        spec = registry.get(name)
        t0 = time.perf_counter()
        try:
            if guard is not None:
                with _kapi.kernel_policy(guard=guard):
                    recs = spec.run(mode)
            else:
                recs = spec.run(mode)
        except Exception as e:
            errors[name] = f"{type(e).__name__}: {e}"
            if verbose:
                traceback.print_exc()
            continue
        finally:
            timings[name] = time.perf_counter() - t0
        for r in recs:
            if r.benchmark != name:
                errors[name] = f"record {r.name!r} claims benchmark {r.benchmark!r}"
                break
        else:
            records.extend(recs)
        if verbose:
            print(f"  {name}: {len(recs)} records in {timings[name]:.1f}s")
    if guard is not None:
        records.extend(
            _kguard.metrics().to_records("guard", "guard", x=guard)
        )
    result = BenchResult(
        mode=mode,
        env=EnvFingerprint.capture(),
        records=records,
        errors=errors,
        timings=timings,
    )
    if out_path:
        result.save(out_path)
    return result
