"""Versioned benchmark-result schema.

One serialization shared by the benchmark runner and the dissect report:
every measurement is a ``BenchRecord`` (name, sweep coordinate, primary
value + unit, regression direction, derived metrics); a run is a
``BenchResult`` (schema version, mode, env fingerprint, records, per-
benchmark errors/timings).  The JSON layout is what CI artifacts, the
baseline store, and the ``compare`` gate all consume.

Regression direction (``better``) is inferred from the unit when not given:
time-like units gate on increases, rate-like units on decreases, and
``"info"`` rows (paper cross-checks, detected capacities) are never gated.
``measured`` distinguishes wall-clock measurements (noisy across machines,
wide default threshold) from deterministic model outputs (tight threshold).
"""
from __future__ import annotations

import json
import time
from dataclasses import asdict, dataclass, field
from pathlib import Path
from typing import Union

from repro.core.serialization import (  # noqa: F401  (re-exported schema surface)
    SCHEMA_VERSION,
    EnvFingerprint,
    finite,
    probe_to_dict,
)

_LOWER_BETTER_UNITS = {"s", "ms", "us", "ns", "us/call", "ns/load", "ns/op"}
_HIGHER_BETTER_UNITS = {"GB/s", "TB/s", "GFLOP/s", "TFLOP/s", "Mupdates/s", "MHz"}

BETTER_VALUES = ("lower", "higher", "info")


class SchemaError(ValueError):
    """A results document does not conform to the schema."""


def better_for_unit(unit: str) -> str:
    if unit in _LOWER_BETTER_UNITS:
        return "lower"
    if unit in _HIGHER_BETTER_UNITS:
        return "higher"
    return "info"


@dataclass(frozen=True)
class BenchRecord:
    """One measurement row (one point of a benchmark's sweep)."""

    name: str  # unique row id within a run, e.g. "axpy_pallas_n1048576_w512"
    benchmark: str  # registered benchmark that produced it
    x: Union[float, int, str, None]  # sweep coordinate
    value: float  # primary metric
    unit: str
    better: str = ""  # "lower" | "higher" | "info"; inferred from unit if ""
    measured: bool = True  # wall-clock measurement vs deterministic model
    metrics: dict = field(default_factory=dict)  # derived metrics, numeric
    info: str = ""  # human-readable annotation

    def __post_init__(self):
        if not self.better:
            object.__setattr__(self, "better", better_for_unit(self.unit))
        if self.better not in BETTER_VALUES:
            raise SchemaError(f"{self.name}: bad better={self.better!r}")


@dataclass
class BenchResult:
    """A full benchmark run, ready for JSON round-trip."""

    mode: str  # "quick" | "full"
    env: EnvFingerprint
    records: list  # list[BenchRecord]
    errors: dict = field(default_factory=dict)  # benchmark -> error string
    timings: dict = field(default_factory=dict)  # benchmark -> seconds
    created_at: str = ""
    schema_version: int = SCHEMA_VERSION

    def __post_init__(self):
        if not self.created_at:
            self.created_at = time.strftime("%Y-%m-%dT%H:%M:%S%z")

    # -- accessors ---------------------------------------------------------
    def benchmarks(self) -> list:
        return sorted({r.benchmark for r in self.records})

    def by_name(self) -> dict:
        return {r.name: r for r in self.records}

    # -- serialization -----------------------------------------------------
    def to_dict(self) -> dict:
        return {
            "schema_version": self.schema_version,
            "created_at": self.created_at,
            "mode": self.mode,
            "env": asdict(self.env),
            "records": [asdict(r) for r in self.records],
            "errors": dict(self.errors),
            "timings": {k: round(v, 3) for k, v in self.timings.items()},
        }

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    def save(self, path) -> None:
        Path(path).write_text(self.to_json() + "\n")

    @staticmethod
    def from_dict(d: dict) -> "BenchResult":
        validate_result(d)
        return BenchResult(
            mode=d["mode"],
            env=EnvFingerprint(**d["env"]),
            records=[BenchRecord(**r) for r in d["records"]],
            errors=dict(d.get("errors", {})),
            timings=dict(d.get("timings", {})),
            created_at=d.get("created_at", ""),
            schema_version=d["schema_version"],
        )

    @staticmethod
    def from_json(s: str) -> "BenchResult":
        return BenchResult.from_dict(json.loads(s))

    @staticmethod
    def load(path) -> "BenchResult":
        return BenchResult.from_json(Path(path).read_text())


# ---------------------------------------------------------------------------
# validation
# ---------------------------------------------------------------------------
_RESULT_KEYS = {"schema_version", "mode", "env", "records"}
_RECORD_KEYS = {"name", "benchmark", "value", "unit"}


def validate_result(d: dict) -> None:
    """Raise SchemaError if ``d`` is not a valid results document."""
    if not isinstance(d, dict):
        raise SchemaError(f"results document must be an object, got {type(d).__name__}")
    missing = _RESULT_KEYS - set(d)
    if missing:
        raise SchemaError(f"missing result keys: {sorted(missing)}")
    if d["schema_version"] != SCHEMA_VERSION:
        raise SchemaError(
            f"schema_version {d['schema_version']} != supported {SCHEMA_VERSION}"
        )
    if not isinstance(d["records"], list):
        raise SchemaError("records must be a list")
    seen = set()
    for i, r in enumerate(d["records"]):
        missing = _RECORD_KEYS - set(r)
        if missing:
            raise SchemaError(f"record[{i}] missing keys: {sorted(missing)}")
        if not isinstance(r["value"], (int, float)):
            raise SchemaError(f"record {r['name']!r}: value must be numeric")
        if r.get("better", "") not in BETTER_VALUES + ("",):
            raise SchemaError(f"record {r['name']!r}: bad better={r['better']!r}")
        if r["name"] in seen:
            raise SchemaError(f"duplicate record name {r['name']!r}")
        seen.add(r["name"])
