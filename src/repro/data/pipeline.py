"""Host-side data pipeline: background prefetch + device placement.

- double-buffered prefetch thread (depth configurable),
- per-batch device placement against a NamedSharding (the host in a real
  multi-host run places only its addressable shard; jax.device_put handles
  both cases uniformly),
- ``seek(step)`` for exact restart after failure (counter-mode source).
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Optional

import jax


class DataPipeline:
    def __init__(
        self,
        batch_fn: Callable[[int], dict],  # step -> host batch (numpy trees)
        sharding=None,  # NamedSharding for device placement (or None)
        prefetch: int = 2,
        start_step: int = 0,
    ):
        self._batch_fn = batch_fn
        self._sharding = sharding
        self._step = start_step
        self._prefetch = prefetch
        self._q: Optional[queue.Queue] = None
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        if prefetch > 0:
            self._start_thread()

    # ------------------------------------------------------------------
    def _produce(self, step: int) -> dict:
        batch = self._batch_fn(step)
        if self._sharding is not None:
            shardings = self._sharding
            if not isinstance(shardings, dict):
                shardings = {k: shardings for k in batch}
            batch = {
                k: jax.device_put(v, shardings[k]) if k in shardings else v
                for k, v in batch.items()
            }
        return batch

    def _start_thread(self):
        self._q = queue.Queue(maxsize=self._prefetch)
        self._stop.clear()

        def worker(start: int):
            s = start
            while not self._stop.is_set():
                try:
                    item = (s, self._produce(s))
                except Exception as e:  # noqa: BLE001
                    self._q.put(("error", e))
                    return
                while not self._stop.is_set():
                    try:
                        self._q.put(item, timeout=0.1)
                        break
                    except queue.Full:
                        continue
                s += 1

        self._thread = threading.Thread(target=worker, args=(self._step,), daemon=True)
        self._thread.start()

    # ------------------------------------------------------------------
    def __next__(self) -> tuple[int, dict]:
        if self._q is None:
            step = self._step
            self._step += 1
            return step, self._produce(step)
        item = self._q.get()
        if item[0] == "error":
            raise item[1]
        self._step = item[0] + 1
        return item

    def __iter__(self):
        return self

    def seek(self, step: int):
        """Exact restart: next batch returned is for ``step``."""
        if self._thread is not None:
            self._stop.set()
            self._thread.join(timeout=5)
            while self._q is not None and not self._q.empty():
                self._q.get_nowait()
        self._step = step
        if self._prefetch > 0:
            self._start_thread()

    def close(self):
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5)
