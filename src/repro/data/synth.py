"""Deterministic synthetic LM data.

Tokens are a counter-mode hash of (seed, step, position): any batch for any
step can be regenerated without consuming an RNG stream — the property that
makes fault-tolerant resume exact (skip-ahead is O(1), no replay).

The marginal distribution is Zipf-like (real-vocab shape) and the sequence
has local structure (next token depends on the previous one) so models can
actually reduce loss on it — the end-to-end example trains against this.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


def _splitmix64(x: np.ndarray) -> np.ndarray:
    x = (x + np.uint64(0x9E3779B97F4A7C15)).astype(np.uint64)
    z = x
    z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return z ^ (z >> np.uint64(31))


def zipf_tokens(shape: tuple, vocab: int, seed: int, alpha: float = 1.1) -> np.ndarray:
    """Zipf-distributed tokens via inverse-CDF over a hashed uniform."""
    n = int(np.prod(shape))
    idx = np.arange(n, dtype=np.uint64) + (np.uint64(seed) << np.uint64(32))
    u = (_splitmix64(idx) >> np.uint64(11)).astype(np.float64) / float(1 << 53)
    # approximate Zipf inverse CDF: rank ~ u^(-1/(alpha-1)) truncated
    ranks = np.minimum(
        (u ** (-1.0 / (alpha - 1.0)) - 1.0).astype(np.int64), vocab - 1
    )
    return ranks.reshape(shape).astype(np.int32)


@dataclass(frozen=True)
class SyntheticLM:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    structure: int = 17  # mixing multiplier for local structure

    def batch_at(self, step: int) -> dict:
        """Batch for ``step`` — pure function of (seed, step)."""
        b, s = self.global_batch, self.seq_len
        base = zipf_tokens((b, s + 1), self.vocab_size, self.seed ^ (step * 2654435761 % (1 << 31)))
        # inject predictable structure: with p~0.5, next = f(prev)
        nxt = (base[:, :-1] * self.structure + 1) % self.vocab_size
        gate = (base[:, :-1] & 1).astype(bool)
        tokens = base[:, :-1]
        targets = np.where(gate, nxt, base[:, 1:]).astype(np.int32)
        return {"tokens": tokens, "targets": targets}
