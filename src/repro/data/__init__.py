"""Data pipeline: deterministic synthetic token streams, host sharding,
background prefetch, exact skip-ahead for fault-tolerant resume."""
from .synth import SyntheticLM, zipf_tokens
from .pipeline import DataPipeline

__all__ = ["SyntheticLM", "zipf_tokens", "DataPipeline"]
