"""Optimizer substrate: AdamW (ZeRO-shardable), schedules, clipping."""
from .adamw import AdamW, AdamWState
from .schedule import cosine_with_warmup
from .clip import clip_by_global_norm

__all__ = ["AdamW", "AdamWState", "cosine_with_warmup", "clip_by_global_norm"]
