"""AdamW, functional, ZeRO-1-shardable (state mirrors the param tree so the
same sharding-rule machinery applies; dist.zero adds the data-axis shard)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array  # scalar int32
    mu: Any  # first moment (param tree)
    nu: Any  # second moment (param tree)


@dataclass(frozen=True)
class AdamW:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1

    def init(self, params) -> AdamWState:
        zeros = lambda p: jnp.zeros_like(p)
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree.map(zeros, params),
            nu=jax.tree.map(zeros, params),
        )

    def update(self, grads, state: AdamWState, params, lr) -> tuple[Any, AdamWState]:
        step = state.step + 1
        b1, b2 = self.b1, self.b2
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g), state.nu, grads)

        def upd(m, v, p):
            mhat = m / c1
            vhat = v / c2
            u = mhat / (jnp.sqrt(vhat) + self.eps)
            if self.weight_decay:
                u = u + self.weight_decay * p
            return -lr * u

        updates = jax.tree.map(upd, mu, nu, params)
        return updates, AdamWState(step=step, mu=mu, nu=nu)

    def apply(self, params, updates):
        return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)
