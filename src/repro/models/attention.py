"""GQA attention: blockwise (memory-efficient online-softmax), naive, and
Pallas flash paths; KV-cache decode.

The blockwise path is the XLA analogue of the flash kernel in
``repro.kernels.flash_attention`` — dry-runs compile this path (it lowers on
any backend); real TPU runs can select the Pallas kernel via
``cfg.attn_impl == "pallas"``.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from .common import Params, dense_init, split_keys

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------
def attn_init(rng, cfg, d_in: int | None = None, dtype=jnp.float32) -> Params:
    d = d_in if d_in is not None else cfg.d_model
    h, k, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    kq, kk, kv, ko = split_keys(rng, 4)
    p = {
        "wq": dense_init(kq, (d, h, hd), fan_in=d, dtype=dtype),
        "wk": dense_init(kk, (d, k, hd), fan_in=d, dtype=dtype),
        "wv": dense_init(kv, (d, k, hd), fan_in=d, dtype=dtype),
        "wo": dense_init(ko, (h, hd, cfg.d_model), fan_in=h * hd, dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), dtype)
        p["bk"] = jnp.zeros((k, hd), dtype)
        p["bv"] = jnp.zeros((k, hd), dtype)
    return p


def qkv_proj(params: Params, x: jax.Array, cfg) -> tuple[jax.Array, jax.Array, jax.Array]:
    """x: (B, S, d) -> q (B,S,H,hd), k/v (B,S,K,hd)."""
    q = jnp.einsum("bsd,dhx->bshx", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dkx->bskx", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dkx->bskx", x, params["wv"].astype(x.dtype))
    if "bq" in params:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    return q, k, v


def out_proj(params: Params, o: jax.Array, x_dtype) -> jax.Array:
    return jnp.einsum("bshx,hxd->bsd", o, params["wo"].astype(x_dtype))


# ---------------------------------------------------------------------------
# core attention maths
# ---------------------------------------------------------------------------
def _group(q: jax.Array, n_kv: int) -> jax.Array:
    """(B,S,H,hd) -> (B,K,G,S,hd)."""
    b, s, h, hd = q.shape
    g = h // n_kv
    return q.reshape(b, s, n_kv, g, hd).transpose(0, 2, 3, 1, 4)


def _ungroup(o: jax.Array) -> jax.Array:
    """(B,K,G,S,hd) -> (B,S,H,hd)."""
    b, k, g, s, hd = o.shape
    return o.transpose(0, 3, 1, 2, 4).reshape(b, s, k * g, hd)


def naive_attention(q, k, v, *, causal: bool, q_offset: int = 0) -> jax.Array:
    """Reference O(S^2)-memory attention.  q (B,S,H,hd), k/v (B,Skv,K,hd)."""
    n_kv = k.shape[2]
    qg = _group(q, n_kv)  # (B,K,G,Sq,hd)
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bkgsh,btkh->bkgst", qg.astype(jnp.float32), k.astype(jnp.float32)) * scale
    if causal:
        sq, skv = s.shape[-2], s.shape[-1]
        qi = q_offset + jnp.arange(sq)[:, None]
        ki = jnp.arange(skv)[None, :]
        s = jnp.where(ki <= qi, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,btkh->bkgsh", p.astype(v.dtype), v)
    return _ungroup(o)


def blockwise_attention(q, k, v, *, causal: bool, chunk: int, q_offset: int = 0) -> jax.Array:
    """Online-softmax attention, scanned over KV chunks (flash-style in XLA).

    q (B,Sq,H,hd), k/v (B,Skv,K,hd).  Memory is O(Sq * Skv/chunk-free):
    no (Sq, Skv) tensor is ever materialized beyond one (Sq, chunk) tile.
    """
    n_kv = k.shape[2]
    skv = k.shape[1]
    chunk = min(chunk, skv)
    n_chunks = -(-skv // chunk)
    pad = n_chunks * chunk - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))

    qg = _group(q, n_kv).astype(jnp.float32)  # (B,K,G,Sq,hd)
    b, kk, g, sq, hd = qg.shape
    scale = hd ** -0.5
    kc = k.reshape(b, n_chunks, chunk, n_kv, hd).transpose(1, 0, 3, 2, 4)  # (N,B,K,C,hd)
    vc = v.reshape(b, n_chunks, chunk, n_kv, hd).transpose(1, 0, 3, 2, 4)

    qi = q_offset + jnp.arange(sq)

    def body(carry, inp):
        m, l, acc = carry
        idx, k_j, v_j = inp
        s = jnp.einsum("bkgsh,bkch->bkgsc", qg, k_j.astype(jnp.float32)) * scale
        ki = idx * chunk + jnp.arange(chunk)
        valid = ki < skv
        if causal:
            valid = valid[None, :] & (ki[None, :] <= qi[:, None])
        else:
            valid = jnp.broadcast_to(valid[None, :], (sq, chunk))
        s = jnp.where(valid[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgsc,bkch->bkgsh", p, v_j.astype(jnp.float32)
        )
        return (m_new, l_new, acc_new), None

    # checkpoint the chunk body: backward re-derives the (Sq, chunk) score
    # tile instead of stashing one per chunk (flash-attention memory shape)
    body = jax.checkpoint(body)

    m0 = jnp.full((b, kk, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kk, g, sq), jnp.float32)
    a0 = jnp.zeros((b, kk, g, sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (jnp.arange(n_chunks), kc, vc)
    )
    o = acc / jnp.maximum(l[..., None], 1e-30)
    return _ungroup(o).astype(q.dtype)


def pallas_attention(q, k, v, *, causal: bool, chunk: int, q_offset: int = 0) -> jax.Array:
    """Flash-attention kernel path via the dispatch API (interpret off-TPU).

    ``cfg.attn_chunk`` becomes the KV block size; a ``kernel_policy`` in
    scope can re-route the backend or autotune the tiles instead.
    """
    from repro.kernels import api

    n_kv = k.shape[2]
    g = q.shape[2] // n_kv
    if g > 1:  # kernel takes matched head counts; expand kv (still exact)
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    return api.flash_attention(q, k, v, causal=causal, q_offset=q_offset, bk=chunk)


def attention_impl(cfg):
    if cfg.attn_impl == "naive":
        return partial(naive_attention)
    if cfg.attn_impl == "pallas":
        return partial(pallas_attention, chunk=cfg.attn_chunk)
    return partial(blockwise_attention, chunk=cfg.attn_chunk)


# ---------------------------------------------------------------------------
# full-sequence layer (train / prefill / encoder / cross)
# ---------------------------------------------------------------------------
def attention_block(
    params: Params,
    x: jax.Array,
    cfg,
    positions: jax.Array,
    *,
    causal: bool = True,
    use_rope: bool = True,
    kv_x: Optional[jax.Array] = None,
    kv_positions: Optional[jax.Array] = None,
) -> jax.Array:
    """Full attention sub-layer (no residual/norm — caller owns those).

    ``kv_x`` switches to cross-attention (keys/values from encoder states).
    """
    xq = x if kv_x is None else x
    xkv = x if kv_x is None else kv_x
    q = jnp.einsum("bsd,dhx->bshx", xq, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dkx->bskx", xkv, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dkx->bskx", xkv, params["wv"].astype(x.dtype))
    if "bq" in params:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    if use_rope:
        from .common import apply_rope

        q = apply_rope(q, positions, cfg.rope_theta)
        kpos = positions if kv_positions is None else kv_positions
        k = apply_rope(k, kpos, cfg.rope_theta)
    o = attention_impl(cfg)(q, k, v, causal=causal)
    return out_proj(params, o, x.dtype)


# ---------------------------------------------------------------------------
# KV cache + decode
# ---------------------------------------------------------------------------
def init_cache(cfg, batch: int, max_len: int, n_layers: int, dtype=jnp.bfloat16):
    """Stacked KV cache (L, B, Smax, K, hd) pair — works under scanned layers."""
    shape = (n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def cache_specs(cfg, batch: int, max_len: int, n_layers: int, dtype=jnp.bfloat16):
    shape = (n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return {
        "k": jax.ShapeDtypeStruct(shape, dtype),
        "v": jax.ShapeDtypeStruct(shape, dtype),
    }


# ---------------------------------------------------------------------------
# paged KV cache: a global page pool indexed through per-lane block tables
# ---------------------------------------------------------------------------
# Layout: the dense (L, B, Smax, K, hd) per-lane cache becomes one global
# pool (L, N_pages, page, K, hd) shared by every lane.  A lane's cache is the
# ordered page list in its block-table row: logical position t lives in page
# ``bt[lane, t // page]`` at offset ``t % page``, so a gather of the row
# reconstructs the dense per-lane layout exactly (gathered index == logical
# position).  Lanes share read-only pages (common prefixes) by listing the
# same page id; the host-side allocator (repro.serve.paging) guarantees a
# page referenced by more than one owner is never written.
def init_paged_cache(cfg, n_pages: int, page_size: int, n_layers: int, dtype=jnp.bfloat16):
    """Global KV page pool (L, N_pages, page, K, hd) pair."""
    shape = (n_layers, n_pages, page_size, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def paged_cache_specs(cfg, n_pages: int, page_size: int, n_layers: int, dtype=jnp.bfloat16):
    shape = (n_layers, n_pages, page_size, cfg.n_kv_heads, cfg.head_dim)
    return {
        "k": jax.ShapeDtypeStruct(shape, dtype),
        "v": jax.ShapeDtypeStruct(shape, dtype),
    }


def gather_pages(pool: jax.Array, block_table: jax.Array) -> jax.Array:
    """Reconstruct the dense per-lane cache view from the page pool.

    pool (N_pages, page, K, hd); block_table (B, T) int32 page ids ->
    (B, T*page, K, hd) where gathered index t IS logical position t.
    Unallocated table slots (id 0 by convention) gather garbage the
    attention masks drop (queries never look past their own position).
    """
    b, t = block_table.shape
    g = pool[block_table]  # (B, T, page, K, hd)
    return g.reshape(b, t * pool.shape[1], *pool.shape[2:])


def paged_write(pool: jax.Array, block_table: jax.Array, positions: jax.Array,
                val: jax.Array) -> jax.Array:
    """Write new KV entries through the block table into the pool.

    pool (N_pages, page, K, hd); block_table (B, T); positions (B, C) logical
    slots (>= T*page is padding: no write); val (B, C, K, hd).  The write is a
    one-hot select over flattened pool slots, not a scatter — the same
    GSPMD-friendly trick as the dense decode write.  Distinct (lane, entry)
    pairs must target distinct slots: the allocator never maps two writers to
    one page, and a lane's positions are distinct by construction.
    """
    n, page = pool.shape[0], pool.shape[1]
    t = block_table.shape[1]
    pi = jnp.clip(positions // page, 0, t - 1)
    pages = jnp.take_along_axis(block_table, pi, axis=1)  # (B, C)
    flat = pages * page + positions % page
    flat = jnp.where(positions < t * page, flat, n * page)  # pad -> out of range
    onehot = flat[..., None] == jnp.arange(n * page, dtype=jnp.int32)  # (B,C,NP)
    write = onehot.any(axis=(0, 1))[:, None, None]  # (NP,1,1)
    new = jnp.einsum(
        "bcn,bckd->nkd", onehot.astype(pool.dtype), val.astype(pool.dtype)
    )
    flat_pool = pool.reshape(n * page, *pool.shape[2:])
    return jnp.where(write, new, flat_pool).reshape(pool.shape)


def paged_decode_attention(
    params: Params,
    x: jax.Array,
    cfg,
    pool_k: jax.Array,
    pool_v: jax.Array,
    block_table: jax.Array,
    pos: jax.Array,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One decode step for one layer against the paged pool.

    Same contract as :func:`decode_attention` but the cache is the global
    (N_pages, page, K, hd) pool plus this batch's (B, T) block table; the new
    token's KV is written through the table, then the lane's pages are
    gathered back to the dense layout and attended exactly as the dense path.
    Lanes with ``pos >= T*page`` (empty/pad lanes) write nothing.
    """
    b, _ = x.shape
    q = jnp.einsum("bd,dhx->bhx", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bd,dkx->bkx", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bd,dkx->bkx", x, params["wv"].astype(x.dtype))
    if "bq" in params:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    from .common import apply_rope

    q = apply_rope(q[:, None], pos[:, None], cfg.rope_theta)[:, 0]
    k = apply_rope(k[:, None], pos[:, None], cfg.rope_theta)[:, 0]

    pool_k = paged_write(pool_k, block_table, pos[:, None], k[:, None])
    pool_v = paged_write(pool_v, block_table, pos[:, None], v[:, None])
    ck = gather_pages(pool_k, block_table)  # (B, T*page, K, hd)
    cv = gather_pages(pool_v, block_table)

    hd = cfg.head_dim
    g = cfg.n_heads // cfg.n_kv_heads
    qg = q.reshape(b, cfg.n_kv_heads, g, hd).astype(jnp.float32)
    s = jnp.einsum("bkgh,bskh->bkgs", qg, ck.astype(jnp.float32)) * hd**-0.5
    mask = jnp.arange(ck.shape[1])[None] <= pos[:, None]  # (B, T*page)
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    o = jnp.einsum("bkgs,bskh->bkgh", p, cv.astype(jnp.float32))
    o = o / jnp.maximum(p.sum(-1)[..., None], 1e-30)
    o = o.reshape(b, cfg.n_heads, hd).astype(x.dtype)
    y = jnp.einsum("bhx,hxd->bd", o, params["wo"].astype(x.dtype))
    return y, pool_k, pool_v


def decode_attention(
    params: Params,
    x: jax.Array,
    cfg,
    cache_k: jax.Array,
    cache_v: jax.Array,
    pos: jax.Array,
    *,
    use_rope: bool = True,
    update_cache: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One decode step for one layer.

    x: (B, d) new-token hidden; cache_k/v: (B, Smax, K, hd); pos: (B,) int32
    (index where the new token lands).  Returns (y (B, d), new_k, new_v).
    """
    b, d = x.shape
    k_heads, hd = cfg.n_kv_heads, cfg.head_dim
    q = jnp.einsum("bd,dhx->bhx", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bd,dkx->bkx", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bd,dkx->bkx", x, params["wv"].astype(x.dtype))
    if "bq" in params:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    if use_rope:
        from .common import apply_rope

        q = apply_rope(q[:, None], pos[:, None], cfg.rope_theta)[:, 0]
        k = apply_rope(k[:, None], pos[:, None], cfg.rope_theta)[:, 0]

    if update_cache:
        # mask-based in-place write (elementwise select, not scatter: keeps
        # a sequence-sharded cache sharded under GSPMD — a scatter on the
        # sharded dim would force replication)
        smax_ = cache_k.shape[1]
        write = (jnp.arange(smax_, dtype=jnp.int32)[None, :] == pos[:, None])[
            :, :, None, None
        ]  # (B, Smax, 1, 1)
        cache_k = jnp.where(write, k[:, None].astype(cache_k.dtype), cache_k)
        cache_v = jnp.where(write, v[:, None].astype(cache_v.dtype), cache_v)

    g = cfg.n_heads // k_heads
    qg = q.reshape(b, k_heads, g, hd).astype(jnp.float32)
    scale = hd ** -0.5
    s = jnp.einsum("bkgh,bskh->bkgs", qg, cache_k.astype(jnp.float32)) * scale
    smax = cache_k.shape[1]
    mask = jnp.arange(smax)[None] <= pos[:, None]  # (B, Smax)
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    o = jnp.einsum("bkgs,bskh->bkgh", p, cache_v.astype(jnp.float32))
    o = o / jnp.maximum(p.sum(-1)[..., None], 1e-30)
    o = o.reshape(b, cfg.n_heads, hd).astype(x.dtype)
    y = jnp.einsum("bhx,hxd->bd", o, params["wo"].astype(x.dtype))
    return y, cache_k, cache_v
