"""MLP variants (swiglu / geglu / gelu) and the MoE block.

MoE uses the sort-free scatter dispatch: top-k routing, position-within-expert
via one-hot cumsum, capacity-bounded scatter into an (E, C, d) buffer, batched
expert matmuls, weighted scatter-combine.  Experts shard on the ``model`` mesh
axis (EP) by default; ``cfg.moe_shard == "ffn"`` instead TP-shards d_ff.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard_act

from .common import Params, dense_init, split_keys


# ---------------------------------------------------------------------------
# dense MLP
# ---------------------------------------------------------------------------
def mlp_init(rng, cfg, d_in: int | None = None, dtype=jnp.float32) -> Params:
    d = d_in if d_in is not None else cfg.d_model
    f = cfg.d_ff
    if cfg.mlp_variant in ("swiglu", "geglu"):
        k1, k2, k3 = split_keys(rng, 3)
        return {
            "wi_gate": dense_init(k1, (d, f), dtype=dtype),
            "wi_up": dense_init(k2, (d, f), dtype=dtype),
            "wo": dense_init(k3, (f, cfg.d_model), fan_in=f, dtype=dtype),
        }
    k1, k2 = split_keys(rng, 2)
    return {
        "wi": dense_init(k1, (d, f), dtype=dtype),
        "wo": dense_init(k2, (f, cfg.d_model), fan_in=f, dtype=dtype),
    }


def _act(cfg, x):
    if cfg.mlp_variant == "swiglu":
        return jax.nn.silu(x)
    return jax.nn.gelu(x, approximate=True)


def mlp(params: Params, x: jax.Array, cfg) -> jax.Array:
    dt = x.dtype
    if "wi_gate" in params:
        g = _act(cfg, x @ params["wi_gate"].astype(dt))
        u = x @ params["wi_up"].astype(dt)
        return (g * u) @ params["wo"].astype(dt)
    h = _act(cfg, x @ params["wi"].astype(dt))
    return h @ params["wo"].astype(dt)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------
def moe_init(rng, cfg, dtype=jnp.float32) -> Params:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    kr, k1, k2, k3 = split_keys(rng, 4)
    return {
        "router": dense_init(kr, (d, e), dtype=dtype),
        "wi_gate": dense_init(k1, (e, d, f), fan_in=d, dtype=dtype),
        "wi_up": dense_init(k2, (e, d, f), fan_in=d, dtype=dtype),
        "wo": dense_init(k3, (e, f, d), fan_in=f, dtype=dtype),
    }


def moe_capacity(cfg, tokens: int) -> int:
    """Per-expert capacity over a token group."""
    cap = int(tokens * cfg.experts_per_token * cfg.capacity_factor / cfg.n_experts)
    return max(8, -(-cap // 8) * 8)  # round up to 8


def moe_block(params: Params, x: jax.Array, cfg) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (y, aux_loss).

    Group-local capacity-bounded dispatch (GShard semantics): each batch row
    is a routing group, so positions/capacity are computed per-group with a
    sequence-length-long cumsum that stays LOCAL under data-parallel batch
    sharding — dispatch buffers are (B, E, C, d), sharded batch-on-dp and
    expert-on-model, never global-token-sized.  Over-capacity assignments
    drop (capacity_factor 1.25).
    """
    dt = x.dtype
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.experts_per_token

    logits = (x @ params["router"].astype(dt)).astype(jnp.float32)  # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)  # (B, S, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch-style), computed globally
    me = probs.mean(axis=(0, 1))  # (E,)
    ce = jnp.sum(
        jax.nn.one_hot(top_e, e, dtype=jnp.float32), axis=(0, 1, 2)
    ) / (b * s * k)
    aux = e * jnp.sum(me * ce)

    cap = moe_capacity(cfg, s)
    # pin dispatch tensors expert-sharded for full sequences only — for
    # single-token decode the tensors are tiny and ANY pin forces harmful
    # resharding (measured +6x memory term in §Perf)
    pin = cfg.moe_shard == "expert" and s > 1

    def _pin(a, *axes):
        return shard_act(a, *axes) if pin else a

    flat_e = top_e.reshape(b, s * k)  # (B, S*k) expert id per assignment
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)  # (B, S*k, E)
    # keep the (big) one-hot/position tensors expert-sharded: without the
    # pin, GSPMD replicates the S*k x E cumsum across the model axis and the
    # dispatch collectives dwarf the expert math (measured in §Perf)
    onehot = _pin(onehot, "dp", None, "tp")
    pos_in_e = (jnp.cumsum(onehot, axis=1) - 1) * onehot
    pos_in_e = _pin(pos_in_e, "dp", None, "tp")
    pos = pos_in_e.sum(-1)  # (B, S*k) slot within (group, expert)
    keep = pos < cap
    dump = jnp.where(keep, pos, cap)  # dropped -> scratch slot `cap`

    # scatter tokens into (B, E, C+1, d).  vmap over the group dim keeps the
    # batch a true scatter-batching dim, so GSPMD shards it on dp instead of
    # replicating the operand (explicit batch index arrays defeat it).
    xr = jnp.repeat(x, k, axis=1)  # (B, S*k, d): token value per assignment

    def scatter_one(xg, eg, pg):
        return jnp.zeros((e, cap + 1, d), dt).at[eg, pg].set(xg)

    buf = jax.vmap(scatter_one)(xr, flat_e, dump)[:, :, :cap]  # (B, E, C, d)
    buf = _pin(buf, "dp", "tp", None, None)

    g = _act(cfg, jnp.einsum("becd,edf->becf", buf, params["wi_gate"].astype(dt)))
    u = jnp.einsum("becd,edf->becf", buf, params["wi_up"].astype(dt))
    out = jnp.einsum("becf,efd->becd", g * u, params["wo"].astype(dt))  # (B,E,C,d)
    out = _pin(out, "dp", "tp", None, None)

    out = jnp.pad(out, ((0, 0), (0, 0), (0, 1), (0, 0)))  # scratch slot reads 0
    gathered = jax.vmap(lambda og, eg, pg: og[eg, pg])(out, flat_e, dump)  # (B,S*k,d)
    # (B, S*k, d) -> (B, S, k, d); combine with renormalized router weights
    gathered = gathered.reshape(b, s, k, d)
    w = (top_p * keep.reshape(b, s, k)).astype(dt)  # (B, S, k)
    y = jnp.einsum("bskd,bsk->bsd", gathered, w)
    return y, aux


def moe_block_dense(params: Params, x: jax.Array, cfg) -> tuple[jax.Array, jax.Array]:
    """Reference MoE: every token through every expert, mask-combined.

    O(T * E * d * f) compute — exact (no drops), used as the oracle in tests
    when capacity is ample.
    """
    dt = x.dtype
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.experts_per_token
    xf = x.reshape(-1, d)
    logits = (xf @ params["router"].astype(dt)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    comb = jnp.zeros_like(probs).at[jnp.arange(xf.shape[0])[:, None], top_e].set(top_p)

    g = _act(cfg, jnp.einsum("td,edf->tef", xf, params["wi_gate"].astype(dt)))
    u = jnp.einsum("td,edf->tef", xf, params["wi_up"].astype(dt))
    o = jnp.einsum("tef,efd->ted", g * u, params["wo"].astype(dt))
    y = jnp.einsum("ted,te->td", o, comb.astype(dt))

    me = probs.mean(axis=0)
    ce = jnp.zeros((e,), jnp.float32).at[top_e.reshape(-1)].add(1.0) / top_e.size
    aux = e * jnp.sum(me * ce)
    return y.reshape(b, s, d), aux
