"""Whisper-style encoder-decoder backbone.

The conv/mel frontend is a STUB per the assignment: ``input_specs`` supplies
precomputed frame embeddings (B, frontend_len, d_model).  Sinusoidal absolute
positions on both encoder and decoder (deviation: real Whisper uses learned
decoder positions; sinusoidal keeps parameters shape-independent so one
param set serves every shape cell).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard_act, shard_params

from . import attention as attn
from . import mlp as mlps
from .common import (
    Params,
    mask_vocab_pad,
    as_dtype,
    embed_init,
    layernorm,
    layernorm_init,
    sinusoidal_positions,
    softmax_xent,
    split_keys,
)


def _enc_block_init(rng, cfg, dtype) -> Params:
    k1, k2 = split_keys(rng, 2)
    return {
        "attn_norm": layernorm_init(cfg.d_model, dtype),
        "attn": attn.attn_init(k1, cfg, dtype=dtype),
        "mlp_norm": layernorm_init(cfg.d_model, dtype),
        "mlp": mlps.mlp_init(k2, cfg, dtype=dtype),
    }


def _dec_block_init(rng, cfg, dtype) -> Params:
    k1, k2, k3 = split_keys(rng, 3)
    return {
        "self_norm": layernorm_init(cfg.d_model, dtype),
        "self_attn": attn.attn_init(k1, cfg, dtype=dtype),
        "cross_norm": layernorm_init(cfg.d_model, dtype),
        "cross_attn": attn.attn_init(k2, cfg, dtype=dtype),
        "mlp_norm": layernorm_init(cfg.d_model, dtype),
        "mlp": mlps.mlp_init(k3, cfg, dtype=dtype),
    }


def encdec_init(rng, cfg) -> Params:
    dtype = as_dtype(cfg.param_dtype)
    ke, kenc, kdec, kn = split_keys(rng, 4)
    enc_keys = jnp.stack(split_keys(kenc, cfg.n_enc_layers))
    dec_keys = jnp.stack(split_keys(kdec, cfg.n_dec_layers))
    return {
        "embed": embed_init(ke, (cfg.padded_vocab, cfg.d_model), dtype),
        "enc_layers": jax.vmap(lambda k: _enc_block_init(k, cfg, dtype))(enc_keys),
        "enc_norm": layernorm_init(cfg.d_model, dtype),
        "dec_layers": jax.vmap(lambda k: _dec_block_init(k, cfg, dtype))(dec_keys),
        "dec_norm": layernorm_init(cfg.d_model, dtype),
    }


def _enc_block(cfg, p, x, positions):
    h = attn.attention_block(
        p["attn"], layernorm(p["attn_norm"], x, cfg.norm_eps), cfg, positions,
        causal=False, use_rope=False,
    )
    x = x + h
    x = x + mlps.mlp(p["mlp"], layernorm(p["mlp_norm"], x, cfg.norm_eps), cfg)
    return shard_act(x, "dp", None, None)


def _dec_block(cfg, p, x, enc_out, positions, enc_positions):
    h = attn.attention_block(
        p["self_attn"], layernorm(p["self_norm"], x, cfg.norm_eps), cfg, positions,
        causal=True, use_rope=False,
    )
    x = x + h
    h = attn.attention_block(
        p["cross_attn"], layernorm(p["cross_norm"], x, cfg.norm_eps), cfg, positions,
        causal=False, use_rope=False, kv_x=enc_out, kv_positions=enc_positions,
    )
    x = x + h
    x = x + mlps.mlp(p["mlp"], layernorm(p["mlp_norm"], x, cfg.norm_eps), cfg)
    return shard_act(x, "dp", None, None)


def encode(params: Params, frames: jax.Array, cfg) -> jax.Array:
    """frames: (B, F, d) precomputed frame embeddings (conv stub output)."""
    dt = as_dtype(cfg.dtype)
    x = frames.astype(dt) + sinusoidal_positions(frames.shape[1], cfg.d_model).astype(dt)
    b, f, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(f, dtype=jnp.int32), (b, f))

    fn = partial(_enc_block, cfg)
    if cfg.remat:
        fn = jax.checkpoint(fn)

    def step(x, lp):
        return fn(lp, x, positions), None

    x = _maybe_scan(cfg, step, x, params["enc_layers"], cfg.n_enc_layers)
    return layernorm(params["enc_norm"], x, cfg.norm_eps)


def _maybe_scan(cfg, step, x, layers, n):
    if cfg.scan_layers:
        x, _ = jax.lax.scan(lambda c, lp: step(c, shard_params(lp, cfg)), x, layers)
        return x
    for i in range(n):
        x, _ = step(x, jax.tree.map(lambda a: a[i], layers))
    return x


def decode_train(params: Params, tokens: jax.Array, enc_out: jax.Array, cfg) -> jax.Array:
    dt = as_dtype(cfg.dtype)
    b, s = tokens.shape
    x = params["embed"].astype(dt)[tokens]
    x = x + sinusoidal_positions(s, cfg.d_model).astype(dt)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    enc_positions = jnp.broadcast_to(
        jnp.arange(enc_out.shape[1], dtype=jnp.int32), (b, enc_out.shape[1])
    )

    fn = partial(_dec_block, cfg)
    if cfg.remat:
        fn = jax.checkpoint(fn)

    def step(x, lp):
        return fn(lp, x, enc_out, positions, enc_positions), None

    x = _maybe_scan(cfg, step, x, params["dec_layers"], cfg.n_dec_layers)
    x = layernorm(params["dec_norm"], x, cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(dt))  # tied head
    return shard_act(mask_vocab_pad(logits, cfg), "dp", None, "tp")


def encdec_loss(params: Params, batch: dict, cfg) -> jax.Array:
    enc_out = encode(params, batch["frontend"], cfg)
    logits = decode_train(params, batch["tokens"], enc_out, cfg)
    return softmax_xent(logits, batch["targets"]).mean()


# --- serving -----------------------------------------------------------------
def encdec_cache_specs(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    l, k, hd = cfg.n_dec_layers, cfg.n_kv_heads, cfg.head_dim
    f = cfg.frontend_len
    return {
        "self_k": jax.ShapeDtypeStruct((l, batch, max_len, k, hd), dtype),
        "self_v": jax.ShapeDtypeStruct((l, batch, max_len, k, hd), dtype),
        "cross_k": jax.ShapeDtypeStruct((l, batch, f, k, hd), dtype),
        "cross_v": jax.ShapeDtypeStruct((l, batch, f, k, hd), dtype),
    }


def encdec_init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    return jax.tree.map(
        lambda sd: jnp.zeros(sd.shape, sd.dtype),
        encdec_cache_specs(cfg, batch, max_len, dtype),
    )


def encdec_prefill(params: Params, frames: jax.Array, tokens: jax.Array, cfg, max_len: int):
    """Encode + teacher-forced decoder pass building self+cross KV caches."""
    dt = as_dtype(cfg.dtype)
    enc_out = encode(params, frames, cfg)
    b, s = tokens.shape

    # cross KV is position-independent: precompute per layer
    def cross_kv(lp):
        k = jnp.einsum("bfd,dkx->bfkx", enc_out, lp["cross_attn"]["wk"].astype(dt))
        v = jnp.einsum("bfd,dkx->bfkx", enc_out, lp["cross_attn"]["wv"].astype(dt))
        if "bk" in lp["cross_attn"]:
            k = k + lp["cross_attn"]["bk"].astype(dt)
            v = v + lp["cross_attn"]["bv"].astype(dt)
        return k, v

    x = params["embed"].astype(dt)[tokens]
    x = x + sinusoidal_positions(s, cfg.d_model).astype(dt)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    enc_positions = jnp.broadcast_to(
        jnp.arange(enc_out.shape[1], dtype=jnp.int32), (b, enc_out.shape[1])
    )

    def step(x, lp):
        xin = layernorm(lp["self_norm"], x, cfg.norm_eps)
        q, k, v = attn.qkv_proj(lp["self_attn"], xin, cfg)
        o = attn.attention_impl(cfg)(q, k, v, causal=True)
        x = x + attn.out_proj(lp["self_attn"], o, x.dtype)
        h = attn.attention_block(
            lp["cross_attn"], layernorm(lp["cross_norm"], x, cfg.norm_eps), cfg,
            positions, causal=False, use_rope=False, kv_x=enc_out,
            kv_positions=enc_positions,
        )
        x = x + h
        x = x + mlps.mlp(lp["mlp"], layernorm(lp["mlp_norm"], x, cfg.norm_eps), cfg)
        ck, cv = cross_kv(lp)
        return x, (k, v, ck, cv)

    if cfg.scan_layers:
        x, (ks, vs, cks, cvs) = jax.lax.scan(step, x, params["dec_layers"])
    else:
        acc = []
        for i in range(cfg.n_dec_layers):
            x, o = step(x, jax.tree.map(lambda a: a[i], params["dec_layers"]))
            acc.append(o)
        ks, vs, cks, cvs = (jnp.stack([a[j] for a in acc]) for j in range(4))
    x = layernorm(params["dec_norm"], x, cfg.norm_eps)
    last = mask_vocab_pad(jnp.einsum("bd,vd->bv", x[:, -1], params["embed"].astype(dt)), cfg)

    cache = encdec_init_cache(cfg, b, max_len, jnp.bfloat16 if cfg.dtype == "bfloat16" else dt)
    cache["self_k"] = jax.lax.dynamic_update_slice(
        cache["self_k"], ks.astype(cache["self_k"].dtype), (0, 0, 0, 0, 0)
    )
    cache["self_v"] = jax.lax.dynamic_update_slice(
        cache["self_v"], vs.astype(cache["self_v"].dtype), (0, 0, 0, 0, 0)
    )
    cache["cross_k"] = cks.astype(cache["cross_k"].dtype)
    cache["cross_v"] = cvs.astype(cache["cross_v"].dtype)
    return last, cache


def encdec_decode_step(params: Params, cache: dict, tokens: jax.Array, pos: jax.Array, cfg):
    dt = as_dtype(cfg.dtype)
    b = tokens.shape[0]
    x = params["embed"].astype(dt)[tokens]
    smax = cache["self_k"].shape[2]
    pe = sinusoidal_positions(smax, cfg.d_model).astype(dt)
    x = x + pe[pos]

    def step(x, inp):
        lp, sk, sv, ck, cv = inp
        xin = layernorm(lp["self_norm"], x, cfg.norm_eps)
        h, sk, sv = attn.decode_attention(
            lp["self_attn"], xin, cfg, sk, sv, pos, use_rope=False
        )
        x = x + h
        xin = layernorm(lp["cross_norm"], x, cfg.norm_eps)
        # cross attention: static KV, attend over all frontend positions
        fpos = jnp.full((b,), ck.shape[1] - 1, jnp.int32)
        h, _, _ = attn.decode_attention(
            lp["cross_attn"], xin, cfg, ck, cv, fpos, use_rope=False, update_cache=False
        )
        x = x + h
        x = x + mlps.mlp(lp["mlp"], layernorm(lp["mlp_norm"], x[:, None], cfg.norm_eps), cfg)[:, 0]
        return x, (sk, sv)

    scan_in = (params["dec_layers"], cache["self_k"], cache["self_v"],
               cache["cross_k"], cache["cross_v"])
    if cfg.scan_layers:
        x, (sks, svs) = jax.lax.scan(step, x, scan_in)
    else:
        acc = []
        for i in range(cfg.n_dec_layers):
            x, o = step(x, jax.tree.map(lambda a: a[i], scan_in))
            acc.append(o)
        sks, svs = (jnp.stack([a[j] for a in acc]) for j in range(2))
    x = layernorm(params["dec_norm"], x[:, None], cfg.norm_eps)[:, 0]
    logits = mask_vocab_pad(jnp.einsum("bd,vd->bv", x, params["embed"].astype(dt)), cfg)
    return logits, {
        "self_k": sks, "self_v": svs,
        "cross_k": cache["cross_k"], "cross_v": cache["cross_v"],
    }
