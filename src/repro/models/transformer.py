"""Unified decoder-only transformer LM: dense (gemma/qwen/minitron/yi),
MoE (olmoe/dbrx), and VLM backbone (internvl2, stub vision frontend).

Layers are stacked and scanned (compact HLO; remat at layer granularity).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard_act, shard_params

from . import attention as attn
from . import mlp as mlps
from .common import (
    Params,
    as_dtype,
    embed_init,
    rmsnorm,
    rmsnorm_init,
    softmax_xent,
    split_keys,
)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _block_init(rng, cfg, dtype) -> Params:
    k1, k2 = split_keys(rng, 2)
    p: Params = {
        "attn_norm": rmsnorm_init(cfg.d_model, dtype),
        "attn": attn.attn_init(k1, cfg, dtype=dtype),
        "mlp_norm": rmsnorm_init(cfg.d_model, dtype),
    }
    if cfg.family == "moe":
        p["moe"] = mlps.moe_init(k2, cfg, dtype=dtype)
    else:
        p["mlp"] = mlps.mlp_init(k2, cfg, dtype=dtype)
    return p


def lm_init(rng, cfg) -> Params:
    dtype = as_dtype(cfg.param_dtype)
    ke, kl, kh = split_keys(rng, 3)
    layer_keys = jnp.stack(split_keys(kl, cfg.n_layers))
    layers = jax.vmap(lambda k: _block_init(k, cfg, dtype))(layer_keys)
    p: Params = {
        "embed": embed_init(ke, (cfg.padded_vocab, cfg.d_model), dtype),
        "layers": layers,
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = embed_init(kh, (cfg.d_model, cfg.padded_vocab), dtype)
    return p


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------
def _block_apply(cfg, p: Params, x: jax.Array, positions: jax.Array):
    """Pre-norm block. x: (B,S,d). Returns (x, aux)."""
    h = attn.attention_block(
        p["attn"], rmsnorm(p["attn_norm"], x, cfg.norm_eps), cfg, positions, causal=True
    )
    x = x + h
    x = shard_act(x, "dp", "sp", None)
    aux = jnp.zeros((), jnp.float32)
    if cfg.family == "moe":
        y, aux = mlps.moe_block(p["moe"], rmsnorm(p["mlp_norm"], x, cfg.norm_eps), cfg)
    else:
        y = mlps.mlp(p["mlp"], rmsnorm(p["mlp_norm"], x, cfg.norm_eps), cfg)
    x = x + y
    x = shard_act(x, "dp", "sp", None)
    return x, aux


def _block_prefill(cfg, p: Params, x: jax.Array, positions: jax.Array):
    """Like _block_apply but also returns this layer's (k, v) for the cache."""
    xin = rmsnorm(p["attn_norm"], x, cfg.norm_eps)
    q, k, v = attn.qkv_proj(p["attn"], xin, cfg)
    from .common import apply_rope

    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    o = attn.attention_impl(cfg)(q, k, v, causal=True)
    x = x + attn.out_proj(p["attn"], o, x.dtype)
    x = shard_act(x, "dp", "sp", None)
    if cfg.family == "moe":
        y, _ = mlps.moe_block(p["moe"], rmsnorm(p["mlp_norm"], x, cfg.norm_eps), cfg)
    else:
        y = mlps.mlp(p["mlp"], rmsnorm(p["mlp_norm"], x, cfg.norm_eps), cfg)
    x = x + y
    x = shard_act(x, "dp", "sp", None)
    return x, (k, v)


def _block_decode(cfg, p: Params, x: jax.Array, ck, cv, pos):
    """Single-token decode block. x: (B,d)."""
    xin = rmsnorm(p["attn_norm"], x, cfg.norm_eps)
    h, ck, cv = attn.decode_attention(p["attn"], xin, cfg, ck, cv, pos)
    x = x + h
    xin = rmsnorm(p["mlp_norm"], x, cfg.norm_eps)
    if cfg.family == "moe":
        y, _ = mlps.moe_block(p["moe"], xin[:, None, :], cfg)
        y = y[:, 0]
    else:
        y = mlps.mlp(p["mlp"], xin, cfg)
    x = x + y
    x = shard_act(x, "dp", None)
    return x, ck, cv


# ---------------------------------------------------------------------------
# embeddings / logits
# ---------------------------------------------------------------------------
def embed_tokens(params: Params, tokens: jax.Array, cfg, frontend: Optional[jax.Array]):
    dt = as_dtype(cfg.dtype)
    x = params["embed"].astype(dt)[tokens]
    if cfg.family in ("dense", "moe"):
        pass
    if frontend is not None:  # VLM: prepend patch embeddings
        x = jnp.concatenate([frontend.astype(dt), x], axis=1)
    if cfg.name.startswith("gemma"):
        x = x * jnp.asarray(cfg.d_model**0.5, dt)
    return x


def lm_logits(params: Params, x: jax.Array, cfg) -> jax.Array:
    dt = x.dtype
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(dt))
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(dt))
    if cfg.padded_vocab != cfg.vocab_size:  # mask pad slots
        iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
        logits = jnp.where(iota < cfg.vocab_size, logits, jnp.asarray(-1e30, logits.dtype))
    if cfg.logits_parallel:
        logits = shard_act(logits, "dp", None, "tp")
    return logits


# ---------------------------------------------------------------------------
# full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------
def _remat_policy(cfg):
    if cfg.remat_policy == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return None  # recompute everything


def _scan_blocks(cfg, layers: Params, x, positions, block_fn):
    fn = partial(block_fn, cfg)
    if cfg.remat:
        fn = jax.checkpoint(fn, policy=_remat_policy(cfg))
    has_aux = cfg.family == "moe"  # dense: keep the scan carry single-tensor

    def step(carry, lp):
        x, aux = carry if has_aux else (carry, None)
        lp = shard_params(lp, cfg)  # pin sliced params (and their grads)
        x, a = fn(lp, x, positions)
        if has_aux:
            return (x, aux + jnp.sum(a)), None
        return x, None

    if cfg.scan_layers:
        if has_aux:
            (x, aux), _ = jax.lax.scan(step, (x, jnp.zeros((), jnp.float32)), layers)
        else:
            x, _ = jax.lax.scan(step, x, layers)
            aux = jnp.zeros((), jnp.float32)
    else:
        aux = jnp.zeros((), jnp.float32)
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], layers)
            x, a = fn(lp, x, positions)
            aux = aux + jnp.sum(a)
    return x, aux


def lm_forward(params: Params, tokens: jax.Array, cfg, frontend=None):
    """tokens (B,S_text) -> logits (B,S,V), aux.  S = S_text (+frontend)."""
    x = embed_tokens(params, tokens, cfg, frontend)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = shard_act(x, "dp", "sp", None)
    x, aux = _scan_blocks(cfg, params["layers"], x, positions, _block_apply)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return lm_logits(params, x, cfg), aux


def lm_loss(params: Params, batch: dict, cfg) -> jax.Array:
    frontend = batch.get("frontend")
    logits, aux = lm_forward(params, batch["tokens"], cfg, frontend)
    targets = batch["targets"]
    if frontend is not None:  # loss only over the text span
        logits = logits[:, frontend.shape[1]:]
    loss = softmax_xent(logits, targets).mean()
    if cfg.family == "moe":
        loss = loss + cfg.moe_aux_coef * aux / cfg.n_layers
    return loss


# ---------------------------------------------------------------------------
# prefill / decode
# ---------------------------------------------------------------------------
def lm_prefill(params: Params, tokens: jax.Array, cfg, max_len: int, frontend=None):
    """Full forward that also builds the KV cache.

    Returns (last_logits (B,V), cache) with cache len ``max_len`` >= S.
    """
    x = embed_tokens(params, tokens, cfg, frontend)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = shard_act(x, "dp", "sp", None)

    fn = partial(_block_prefill, cfg)

    def step(x, lp):
        x, (k, v) = fn(shard_params(lp, cfg), x, positions)
        return x, (k, v)

    if cfg.scan_layers:
        x, (ks, vs) = jax.lax.scan(step, x, params["layers"])
    else:
        ks_l, vs_l = [], []
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            x, (k, v) = step(x, lp)
            ks_l.append(k)
            vs_l.append(v)
        ks, vs = jnp.stack(ks_l), jnp.stack(vs_l)

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    last = lm_logits(params, x[:, -1:, :], cfg)[:, 0]
    cdt = jnp.bfloat16 if cfg.dtype == "bfloat16" else ks.dtype
    cache = attn.init_cache(cfg, b, max_len, cfg.n_layers, dtype=cdt)
    cache = {
        "k": jax.lax.dynamic_update_slice(cache["k"], ks.astype(cdt), (0, 0, 0, 0, 0)),
        "v": jax.lax.dynamic_update_slice(cache["v"], vs.astype(cdt), (0, 0, 0, 0, 0)),
    }
    return last, cache


def _chunk_attention(q, cache_k, cache_v, positions, cfg):
    """Chunk queries against the full KV cache with per-(lane, query) masks.

    q (B,C,H,hd); cache_k/v (B,Smax,K,hd); positions (B,C) — key index t is
    visible to query c of lane b iff t <= positions[b, c].  Pad queries
    (positions == Smax) see everything and produce garbage the caller drops.
    """
    b, c, h, hd = q.shape
    kh = cache_k.shape[2]
    g = h // kh
    qg = q.reshape(b, c, kh, g, hd).astype(jnp.float32)
    scale = hd**-0.5
    s = jnp.einsum("bckgd,btkd->bckgt", qg, cache_k.astype(jnp.float32)) * scale
    smax = cache_k.shape[1]
    mask = jnp.arange(smax)[None, None, :] <= positions[:, :, None]  # (B,C,Smax)
    s = jnp.where(mask[:, :, None, None, :], s, attn.NEG_INF)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    o = jnp.einsum("bckgt,btkd->bckgd", p, cache_v.astype(jnp.float32))
    o = o / jnp.maximum(p.sum(-1)[..., None], 1e-30)
    return o.reshape(b, c, h, hd).astype(q.dtype)


def _block_decode_chunk(cfg, p: Params, x: jax.Array, ck, cv, positions):
    """Chunked decode block: C new tokens per lane against one cache lane.

    x (B,C,d); ck/cv (B,Smax,K,hd); positions (B,C).  Writes the chunk's KV
    into the cache first (mask-select, no scatter), then attends — intra-chunk
    causality falls out of the t <= positions mask because every chunk key
    already sits in the cache at its own position.
    """
    xin = rmsnorm(p["attn_norm"], x, cfg.norm_eps)
    q, k, v = attn.qkv_proj(p["attn"], xin, cfg)
    from .common import apply_rope

    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    smax = ck.shape[1]
    onehot = positions[:, :, None] == jnp.arange(smax, dtype=jnp.int32)[None, None, :]
    write = onehot.any(axis=1)[:, :, None, None]  # (B,Smax,1,1)
    k_new = jnp.einsum("bct,bckd->btkd", onehot.astype(ck.dtype), k.astype(ck.dtype))
    v_new = jnp.einsum("bct,bckd->btkd", onehot.astype(cv.dtype), v.astype(cv.dtype))
    ck = jnp.where(write, k_new, ck)
    cv = jnp.where(write, v_new, cv)
    o = _chunk_attention(q, ck, cv, positions, cfg)
    x = x + attn.out_proj(p["attn"], o, x.dtype)
    xin = rmsnorm(p["mlp_norm"], x, cfg.norm_eps)
    if cfg.family == "moe":
        y, _ = mlps.moe_block(p["moe"], xin, cfg)
    else:
        y = mlps.mlp(p["mlp"], xin, cfg)
    x = x + y
    x = shard_act(x, "dp", None, None)
    return x, ck, cv


def lm_decode_chunk(params: Params, cache: dict, tokens: jax.Array, positions: jax.Array, cfg):
    """Chunked batched prefill step: C tokens per lane in ONE compiled call.

    tokens (B,C) int32; positions (B,C) int32 gives each token's cache index
    in its own lane (lanes advance independently).  A position equal to Smax
    is padding: nothing is written and that query's logits row is garbage the
    caller ignores.  Returns (logits (B,C,V), cache) — exact continuation of
    ``lm_decode_step`` semantics, C steps at a time.
    """
    dt = as_dtype(cfg.dtype)
    x = params["embed"].astype(dt)[tokens]
    if cfg.name.startswith("gemma"):
        x = x * jnp.asarray(cfg.d_model**0.5, dt)
    x = shard_act(x, "dp", None, None)

    def step(x, inp):
        lp, ck, cv = inp
        x, ck, cv = _block_decode_chunk(cfg, shard_params(lp, cfg), x, ck, cv, positions)
        return x, (ck, cv)

    if cfg.scan_layers:
        x, (ks, vs) = jax.lax.scan(step, x, (params["layers"], cache["k"], cache["v"]))
    else:
        ks_l, vs_l = [], []
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            x, (ck, cv) = step(x, (lp, cache["k"][i], cache["v"][i]))
            ks_l.append(ck)
            vs_l.append(cv)
        ks, vs = jnp.stack(ks_l), jnp.stack(vs_l)

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = lm_logits(params, x, cfg)
    return logits, {"k": ks, "v": vs}


# ---------------------------------------------------------------------------
# paged decode: same maths, cache indirected through a block table
# ---------------------------------------------------------------------------
def _block_decode_paged(cfg, p: Params, x: jax.Array, pk, pv, block_table, pos):
    """Single-token decode block against the paged pool.  x: (B,d);
    pk/pv (N_pages, page, K, hd); block_table (B, T)."""
    xin = rmsnorm(p["attn_norm"], x, cfg.norm_eps)
    h, pk, pv = attn.paged_decode_attention(p["attn"], xin, cfg, pk, pv, block_table, pos)
    x = x + h
    xin = rmsnorm(p["mlp_norm"], x, cfg.norm_eps)
    if cfg.family == "moe":
        y, _ = mlps.moe_block(p["moe"], xin[:, None, :], cfg)
        y = y[:, 0]
    else:
        y = mlps.mlp(p["mlp"], xin, cfg)
    x = x + y
    x = shard_act(x, "dp", None)
    return x, pk, pv


def _block_decode_chunk_paged(cfg, p: Params, x: jax.Array, pk, pv, block_table, positions):
    """Chunked decode block against the paged pool: C new tokens per lane.

    Pool-write first (through the block table), then gather the lane's pages
    back to the dense layout and run the same chunk attention as the dense
    path — intra-chunk causality falls out of the t <= positions mask exactly
    as in :func:`_block_decode_chunk`.
    """
    xin = rmsnorm(p["attn_norm"], x, cfg.norm_eps)
    q, k, v = attn.qkv_proj(p["attn"], xin, cfg)
    from .common import apply_rope

    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    pk = attn.paged_write(pk, block_table, positions, k)
    pv = attn.paged_write(pv, block_table, positions, v)
    ck = attn.gather_pages(pk, block_table)  # (B, T*page, K, hd)
    cv = attn.gather_pages(pv, block_table)
    o = _chunk_attention(q, ck, cv, positions, cfg)
    x = x + attn.out_proj(p["attn"], o, x.dtype)
    xin = rmsnorm(p["mlp_norm"], x, cfg.norm_eps)
    if cfg.family == "moe":
        y, _ = mlps.moe_block(p["moe"], xin, cfg)
    else:
        y = mlps.mlp(p["mlp"], xin, cfg)
    x = x + y
    x = shard_act(x, "dp", None, None)
    return x, pk, pv


def lm_decode_chunk_paged(params: Params, cache: dict, block_table: jax.Array,
                          tokens: jax.Array, positions: jax.Array, cfg):
    """Paged twin of :func:`lm_decode_chunk`.

    cache holds the global page pool {"k"/"v": (L, N_pages, page, K, hd)};
    ``block_table`` (B, T) int32 maps each lane's logical positions to pages
    (position t -> page ``bt[b, t // page]``, offset ``t % page``).  A
    position >= T*page is padding: nothing is written and that row's logits
    are garbage the caller ignores.  Exact vs the dense path: gathering a
    lane's pages reproduces its dense cache bit-for-bit.
    """
    dt = as_dtype(cfg.dtype)
    x = params["embed"].astype(dt)[tokens]
    if cfg.name.startswith("gemma"):
        x = x * jnp.asarray(cfg.d_model**0.5, dt)
    x = shard_act(x, "dp", None, None)

    def step(x, inp):
        lp, pk, pv = inp
        x, pk, pv = _block_decode_chunk_paged(
            cfg, shard_params(lp, cfg), x, pk, pv, block_table, positions
        )
        return x, (pk, pv)

    if cfg.scan_layers:
        x, (ks, vs) = jax.lax.scan(step, x, (params["layers"], cache["k"], cache["v"]))
    else:
        ks_l, vs_l = [], []
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            x, (pk, pv) = step(x, (lp, cache["k"][i], cache["v"][i]))
            ks_l.append(pk)
            vs_l.append(pv)
        ks, vs = jnp.stack(ks_l), jnp.stack(vs_l)

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = lm_logits(params, x, cfg)
    return logits, {"k": ks, "v": vs}


def lm_decode_step_paged(params: Params, cache: dict, block_table: jax.Array,
                         tokens: jax.Array, pos: jax.Array, cfg):
    """Paged twin of :func:`lm_decode_step`: one token per lane, KV gathered
    through the block table.  Lanes with ``pos >= T*page`` (empty slots)
    write nothing and produce garbage logits the engine ignores."""
    dt = as_dtype(cfg.dtype)
    x = params["embed"].astype(dt)[tokens]
    if cfg.name.startswith("gemma"):
        x = x * jnp.asarray(cfg.d_model**0.5, dt)
    x = shard_act(x, "dp", None)

    def step(x, inp):
        lp, pk, pv = inp
        x, pk, pv = _block_decode_paged(
            cfg, shard_params(lp, cfg), x, pk, pv, block_table, pos
        )
        return x, (pk, pv)

    if cfg.scan_layers:
        x, (ks, vs) = jax.lax.scan(step, x, (params["layers"], cache["k"], cache["v"]))
    else:
        ks_l, vs_l = [], []
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            x, (pk, pv) = step(x, (lp, cache["k"][i], cache["v"][i]))
            ks_l.append(pk)
            vs_l.append(pv)
        ks, vs = jnp.stack(ks_l), jnp.stack(vs_l)

    x = rmsnorm(params["final_norm"], x[:, None, :], cfg.norm_eps)
    logits = lm_logits(params, x, cfg)[:, 0]
    return logits, {"k": ks, "v": vs}


def lm_decode_step(params: Params, cache: dict, tokens: jax.Array, pos: jax.Array, cfg):
    """One decode step.  tokens (B,) int32, pos (B,) int32 -> (logits (B,V), cache)."""
    dt = as_dtype(cfg.dtype)
    x = params["embed"].astype(dt)[tokens]
    if cfg.name.startswith("gemma"):
        x = x * jnp.asarray(cfg.d_model**0.5, dt)
    x = shard_act(x, "dp", None)

    def step(x, inp):
        lp, ck, cv = inp
        x, ck, cv = _block_decode(cfg, shard_params(lp, cfg), x, ck, cv, pos)
        return x, (ck, cv)

    if cfg.scan_layers:
        x, (ks, vs) = jax.lax.scan(step, x, (params["layers"], cache["k"], cache["v"]))
    else:
        ks_l, vs_l = [], []
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            x, (ck, cv) = step(x, (lp, cache["k"][i], cache["v"][i]))
            ks_l.append(ck)
            vs_l.append(cv)
        ks, vs = jnp.stack(ks_l), jnp.stack(vs_l)

    x = rmsnorm(params["final_norm"], x[:, None, :], cfg.norm_eps)
    logits = lm_logits(params, x, cfg)[:, 0]
    return logits, {"k": ks, "v": vs}
