"""Model zoo for the 10 assigned architectures (pure-functional JAX)."""
from .api import build_model, ModelApi, input_specs

__all__ = ["build_model", "ModelApi", "input_specs"]
