"""Unified model API: one façade over the four model families.

``build_model(cfg)`` returns a :class:`ModelApi` — a frozen bundle of pure
functions closed over the config.  Every entry point is jit-compatible and
side-effect free; state (params, caches) flows through arguments and return
values, never through the object, which is why one ``ModelApi`` can safely
back many engines/benches at once (each jits its own closures, see
``ServeEngine._jit_scoped``).

Two KV-cache layouts coexist behind the same façade:

- **dense** — ``init_cache(batch, max_len)`` reserves one contiguous
  ``max_len`` region per lane; ``decode_step``/``decode_chunk`` index it
  directly.  Memory is ``batch * max_len`` regardless of actual lengths.
- **paged** — ``init_paged_cache(n_pages, page_size)`` builds one global
  page pool shared by all lanes; ``decode_step_paged``/``decode_chunk_paged``
  take an extra ``block_table (B, T)`` mapping each lane's logical position
  ``t`` to pool page ``bt[b, t // page]``.  Lanes may reference the same page
  (shared prefixes); the caller guarantees shared pages are never written
  (see ``repro.serve.paging``).

``input_specs(cfg, shape)`` builds ShapeDtypeStruct stand-ins for every
input of the step function a given shape cell lowers (dry-run: zero
allocation).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig

from . import encdec, mamba, transformer, xlstm
from . import attention as attn
from .common import as_dtype

Params = Any


@dataclass(frozen=True)
class ModelApi:
    """Per-family model surface.  All callables are pure and jit-safe.

    Field contracts (shapes use B=batch/lanes, C=chunk, T=table width):

    - ``init(rng) -> params``
    - ``loss_fn(params, batch) -> scalar``                      (train cells)
    - ``prefill(params, batch) -> (last_logits (B,V), cache)``  (prefill cells)
    - ``decode_step(params, cache, tokens (B,), pos (B,)) -> (logits (B,V), cache)``
    - ``init_cache(batch, max_len) / cache_specs(batch, max_len)`` — dense
      per-lane KV cache (specs: ShapeDtypeStruct stand-ins, zero allocation)
    """

    cfg: ModelConfig
    init: Callable
    loss_fn: Callable
    prefill: Callable
    decode_step: Callable
    init_cache: Callable
    cache_specs: Callable
    # Chunked batched decode/prefill: (params, cache, tokens (B,C), positions
    # (B,C)) -> (logits (B,C,V), cache).  C decode_step-equivalent steps in one
    # compiled call; positions == cache_len marks pad entries (no write, row
    # ignored).  None for families whose per-lane state cannot yet advance
    # independently inside a shared batch (recurrent ssm/hybrid caches).
    decode_chunk: Optional[Callable] = None
    # Paged-KV twins (None where unsupported).  The cache is a global page
    # pool {"k"/"v": (L, n_pages, page, K, hd)} built by
    # ``init_paged_cache(n_pages, page_size)``; decode ops take an extra
    # ``block_table (B, T)`` int32 argument ahead of tokens/positions and a
    # position >= T*page means "pad: write nothing".  Gathering a lane's
    # pages reproduces its dense cache exactly, so paged decode is
    # token-for-token equal to the dense path.
    init_paged_cache: Optional[Callable] = None
    paged_cache_specs: Optional[Callable] = None
    # (params, cache, block_table, tokens (B,), pos (B,)) -> (logits, cache)
    decode_step_paged: Optional[Callable] = None
    # (params, cache, block_table, tokens (B,C), positions (B,C)) -> (logits, cache)
    decode_chunk_paged: Optional[Callable] = None
    # Mesh placement for the caches (tensor-parallel serving).  Both take
    # (cache_or_specs, mesh) and return a matching NamedSharding tree derived
    # from the ``repro.dist.sharding`` rules: dense caches put batch on the
    # dp axes and KV heads on ``model``; paged pools shard only the KV-head
    # dim (pages are block-table-addressed and stay replicated).  Divisibility
    # guards apply — a dim that doesn't divide its mesh axis is replicated.
    cache_shardings: Optional[Callable] = None
    paged_cache_shardings: Optional[Callable] = None


def _cache_dtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else as_dtype(cfg.dtype)


def _cache_sharding_fns(cfg):
    """(dense, paged) cache-placement closures over the dist.sharding rules."""
    from repro.dist import sharding as dist_sharding

    def dense(cache, mesh):
        return dist_sharding.cache_shardings(cache, cfg, mesh)

    def paged(cache, mesh):
        return dist_sharding.paged_cache_shardings(cache, cfg, mesh)

    return dense, paged


def build_model(cfg: ModelConfig) -> ModelApi:
    fam = cfg.family
    dense_cache_shardings, pool_cache_shardings = _cache_sharding_fns(cfg)

    if fam in ("dense", "moe", "vlm"):

        def loss_fn(params, batch):
            return transformer.lm_loss(params, batch, cfg)

        def prefill(params, batch, max_len: Optional[int] = None):
            tokens = batch["tokens"]
            frontend = batch.get("frontend")
            ml = max_len if max_len is not None else tokens.shape[1] + (
                frontend.shape[1] if frontend is not None else 0
            )
            return transformer.lm_prefill(params, tokens, cfg, ml, frontend=frontend)

        def decode_step(params, cache, tokens, pos):
            return transformer.lm_decode_step(params, cache, tokens, pos, cfg)

        def decode_chunk(params, cache, tokens, positions):
            return transformer.lm_decode_chunk(params, cache, tokens, positions, cfg)

        def cache_specs(batch, max_len):
            return attn.cache_specs(cfg, batch, max_len, cfg.n_layers, _cache_dtype(cfg))

        def init_cache(batch, max_len):
            return attn.init_cache(cfg, batch, max_len, cfg.n_layers, _cache_dtype(cfg))

        def init_paged_cache(n_pages, page_size):
            return attn.init_paged_cache(
                cfg, n_pages, page_size, cfg.n_layers, _cache_dtype(cfg)
            )

        def paged_cache_specs(n_pages, page_size):
            return attn.paged_cache_specs(
                cfg, n_pages, page_size, cfg.n_layers, _cache_dtype(cfg)
            )

        def decode_step_paged(params, cache, block_table, tokens, pos):
            return transformer.lm_decode_step_paged(
                params, cache, block_table, tokens, pos, cfg
            )

        def decode_chunk_paged(params, cache, block_table, tokens, positions):
            return transformer.lm_decode_chunk_paged(
                params, cache, block_table, tokens, positions, cfg
            )

        return ModelApi(
            cfg,
            lambda rng: transformer.lm_init(rng, cfg),
            loss_fn,
            prefill,
            decode_step,
            init_cache,
            cache_specs,
            decode_chunk=decode_chunk,
            init_paged_cache=init_paged_cache,
            paged_cache_specs=paged_cache_specs,
            decode_step_paged=decode_step_paged,
            decode_chunk_paged=decode_chunk_paged,
            cache_shardings=dense_cache_shardings,
            paged_cache_shardings=pool_cache_shardings,
        )

    if fam == "ssm":  # xlstm

        def loss_fn(params, batch):
            return xlstm.xlstm_loss(params, batch, cfg)

        def prefill(params, batch, max_len: Optional[int] = None):
            # Recurrent prefill: run forward, return final-state cache.
            # (Implemented as forward + decode-state reconstruction would
            # double compute; instead states are produced by the chunked
            # scans directly.)
            return _xlstm_prefill(params, batch["tokens"], cfg)

        def decode_step(params, cache, tokens, pos):
            return xlstm.xlstm_decode_step(params, cache, tokens, pos, cfg)

        return ModelApi(
            cfg,
            lambda rng: xlstm.xlstm_init(rng, cfg),
            loss_fn,
            prefill,
            decode_step,
            lambda b, ml: xlstm.xlstm_init_cache(cfg, b, ml),
            lambda b, ml: xlstm.xlstm_cache_specs(cfg, b, ml),
            cache_shardings=dense_cache_shardings,
        )

    if fam == "hybrid":  # zamba2

        def loss_fn(params, batch):
            return mamba.zamba_loss(params, batch, cfg)

        def prefill(params, batch, max_len: Optional[int] = None):
            ml = max_len if max_len is not None else batch["tokens"].shape[1]
            return _zamba_prefill(params, batch["tokens"], cfg, ml)

        def decode_step(params, cache, tokens, pos):
            return mamba.zamba_decode_step(params, cache, tokens, pos, cfg)

        return ModelApi(
            cfg,
            lambda rng: mamba.zamba_init(rng, cfg),
            loss_fn,
            prefill,
            decode_step,
            lambda b, ml: mamba.zamba_init_cache(cfg, b, ml, _cache_dtype(cfg)),
            lambda b, ml: mamba.zamba_cache_specs(cfg, b, ml, _cache_dtype(cfg)),
            cache_shardings=dense_cache_shardings,
        )

    if fam == "encdec":  # whisper

        def loss_fn(params, batch):
            return encdec.encdec_loss(params, batch, cfg)

        def prefill(params, batch, max_len: Optional[int] = None):
            ml = max_len if max_len is not None else batch["tokens"].shape[1]
            return encdec.encdec_prefill(params, batch["frontend"], batch["tokens"], cfg, ml)

        def decode_step(params, cache, tokens, pos):
            return encdec.encdec_decode_step(params, cache, tokens, pos, cfg)

        return ModelApi(
            cfg,
            lambda rng: encdec.encdec_init(rng, cfg),
            loss_fn,
            prefill,
            decode_step,
            lambda b, ml: encdec.encdec_init_cache(cfg, b, ml, _cache_dtype(cfg)),
            lambda b, ml: encdec.encdec_cache_specs(cfg, b, ml, _cache_dtype(cfg)),
            cache_shardings=dense_cache_shardings,
        )

    raise ValueError(f"unknown family {fam!r}")


# ---------------------------------------------------------------------------
# recurrent-family prefill helpers
# ---------------------------------------------------------------------------
def _xlstm_prefill(params, tokens, cfg):
    """Full forward collecting final recurrent states as the cache."""
    dt = as_dtype(cfg.dtype)
    x = params["embed"].astype(dt)[tokens]
    b = tokens.shape[0]

    def macro_step(x, mp):
        def layer(x, lp):
            x, st = xlstm.mlstm_block(lp, x, cfg, return_state=True)
            return x, st

        if cfg.scan_layers:
            x, mstates = jax.lax.scan(layer, x, mp["mlstm"])
        else:
            acc = []
            for i in range(cfg.xlstm_mlstm_per_macro):
                x, st = layer(x, jax.tree.map(lambda a: a[i], mp["mlstm"]))
                acc.append(st)
            mstates = tuple(jnp.stack([a[j] for a in acc]) for j in range(3))
        x, sstate = xlstm.slstm_block(mp["slstm"], x, cfg, return_state=True)
        return x, (mstates, sstate)

    if cfg.scan_layers:
        x, (mstates, sstates) = jax.lax.scan(macro_step, x, params["macros"])
    else:
        acc = []
        from .xlstm import _n_macros

        for i in range(_n_macros(cfg)):
            x, st = macro_step(x, jax.tree.map(lambda a: a[i], params["macros"]))
            acc.append(st)
        mstates = tuple(jnp.stack([a[0][j] for a in acc]) for j in range(3))
        sstates = tuple(jnp.stack([a[1][j] for a in acc]) for j in range(4))
    from .common import rmsnorm

    xl = rmsnorm(params["final_norm"], x[:, -1:, :], cfg.norm_eps)[:, 0]
    logits = xl @ params["lm_head"].astype(dt)
    (mC, mn, mm) = mstates
    (sc, sn, sm, sh) = sstates
    cache = {"mC": mC, "mn": mn, "mm": mm, "sc": sc, "sn": sn, "sm": sm, "sh": sh}
    return logits, cache


def _zamba_prefill(params, tokens, cfg, max_len):
    dt = as_dtype(cfg.dtype)
    x = params["embed"].astype(dt)[tokens]
    x0 = x
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    n_super, per, tail = mamba._zamba_counts(cfg)

    def attn_prefill(x, slot_unused):
        cat = jnp.concatenate([x, x0], axis=-1)
        from .common import rmsnorm

        sp = params["shared_attn"]
        xin = rmsnorm(sp["norm"], cat, cfg.norm_eps)
        q, k, v = attn.qkv_proj(sp["attn"], xin, cfg)
        from .common import apply_rope

        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        o = attn.attention_impl(cfg)(q, k, v, causal=True)
        x = x + attn.out_proj(sp["attn"], o, x.dtype)
        from . import mlp as mlps

        x = x + mlps.mlp(sp["mlp"], rmsnorm(sp["mlp_norm"], x, cfg.norm_eps), cfg)
        return x, (k, v)

    def mamba_prefill(x, lp):
        from .common import rmsnorm

        xin = rmsnorm(lp["norm"], x, cfg.norm_eps)
        y, h = mamba.mamba_forward(lp, xin, cfg, return_state=True)
        # conv state = last (W-1) conv inputs
        d_in = cfg.ssm_expand * cfg.d_model
        xc = xin @ lp["w_in"].astype(xin.dtype)
        bc = xin @ lp["w_bc"].astype(xin.dtype)
        conv_in = jnp.concatenate([xc, bc], axis=-1)
        w = cfg.ssm_conv_width - 1
        conv_state = conv_in[:, -w:, :]
        return x + y, (h, conv_state)

    def super_step(x, sp_stack):
        x, (k, v) = attn_prefill(x, None)

        def layer(x, lp):
            return mamba_prefill(x, lp)

        if cfg.scan_layers:
            x, (hs, cs) = jax.lax.scan(layer, x, sp_stack)
        else:
            acc = []
            for i in range(per):
                x, o = layer(x, jax.tree.map(lambda a: a[i], sp_stack))
                acc.append(o)
            hs, cs = (jnp.stack([a[j] for a in acc]) for j in range(2))
        return x, ((k, v), (hs, cs))

    if cfg.scan_layers:
        x, ((ks, vs), (hss, css)) = jax.lax.scan(super_step, x, params["supers"])
    else:
        acc = []
        for i in range(n_super):
            x, o = super_step(x, jax.tree.map(lambda a: a[i], params["supers"]))
            acc.append(o)
        ks = jnp.stack([a[0][0] for a in acc])
        vs = jnp.stack([a[0][1] for a in acc])
        hss = jnp.stack([a[1][0] for a in acc])
        css = jnp.stack([a[1][1] for a in acc])
    if tail:
        x, (kt, vt) = attn_prefill(x, None)

        def layer(x, lp):
            return mamba_prefill(x, lp)

        x, (ht, ct) = jax.lax.scan(layer, x, params["tail"])

    from .common import rmsnorm

    xl = rmsnorm(params["final_norm"], x[:, -1:, :], cfg.norm_eps)[:, 0]
    logits = xl @ params["lm_head"].astype(dt)

    cdt = _cache_dtype(cfg)
    n_attn = n_super + (1 if tail else 0)
    kv_shape = (n_attn, b, max_len, cfg.n_kv_heads, cfg.head_dim)
    k_cache = jnp.zeros(kv_shape, cdt)
    v_cache = jnp.zeros(kv_shape, cdt)
    if tail:
        all_k = jnp.concatenate([ks, kt[None]], axis=0).astype(cdt)
        all_v = jnp.concatenate([vs, vt[None]], axis=0).astype(cdt)
        ssm = jnp.concatenate([hss.reshape((-1,) + hss.shape[2:]), ht], axis=0)
        conv = jnp.concatenate([css.reshape((-1,) + css.shape[2:]), ct], axis=0).astype(cdt)
    else:
        all_k, all_v = ks.astype(cdt), vs.astype(cdt)
        ssm = hss.reshape((-1,) + hss.shape[2:])
        conv = css.reshape((-1,) + css.shape[2:]).astype(cdt)
    k_cache = jax.lax.dynamic_update_slice(k_cache, all_k, (0, 0, 0, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, all_v, (0, 0, 0, 0, 0))
    cache = {"k": k_cache, "v": v_cache, "ssm": ssm, "conv": conv, "x0": x0[:, -1]}
    return logits, cache


# ---------------------------------------------------------------------------
# input specs per shape cell (ShapeDtypeStruct: zero allocation)
# ---------------------------------------------------------------------------
def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Stand-ins for every input of the step function this cell lowers."""
    i32 = jnp.int32
    b, s = shape.global_batch, shape.seq_len
    dt = as_dtype(cfg.dtype)

    if shape.kind == "train":
        s_text = s - (cfg.frontend_len if cfg.family == "vlm" else 0)
        specs = {
            "tokens": jax.ShapeDtypeStruct((b, s_text), i32),
            "targets": jax.ShapeDtypeStruct((b, s_text), i32),
        }
        if cfg.family == "vlm":
            specs["frontend"] = jax.ShapeDtypeStruct((b, cfg.frontend_len, cfg.d_model), dt)
        if cfg.family == "encdec":
            specs["frontend"] = jax.ShapeDtypeStruct((b, cfg.frontend_len, cfg.d_model), dt)
        return specs

    if shape.kind == "prefill":
        s_text = s - (cfg.frontend_len if cfg.family == "vlm" else 0)
        specs = {"tokens": jax.ShapeDtypeStruct((b, s_text), i32)}
        if cfg.family in ("vlm", "encdec"):
            specs["frontend"] = jax.ShapeDtypeStruct((b, cfg.frontend_len, cfg.d_model), dt)
        return specs

    # decode: one new token against a cache of length seq_len
    model = build_model(cfg)
    return {
        "cache": model.cache_specs(b, s),
        "tokens": jax.ShapeDtypeStruct((b,), i32),
        "pos": jax.ShapeDtypeStruct((b,), i32),
    }
