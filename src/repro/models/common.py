"""Shared layers/utilities: norms, RoPE, initializers, dtype policy."""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------
def dense_init(rng, shape, fan_in: int | None = None, dtype=jnp.float32):
    """Truncated-normal init scaled by 1/sqrt(fan_in) (llama-style)."""
    if fan_in is None:
        fan_in = shape[0]
    std = 1.0 / math.sqrt(max(fan_in, 1))
    return std * jax.random.truncated_normal(rng, -3.0, 3.0, shape, dtype)


def embed_init(rng, shape, dtype=jnp.float32):
    return 0.02 * jax.random.truncated_normal(rng, -3.0, 3.0, shape, dtype)


def split_keys(rng, n: int):
    return list(jax.random.split(rng, n))


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def rmsnorm_init(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


def layernorm_init(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mean = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mean) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# rotary position embedding
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies, shape (head_dim//2,), float32."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)  # (hd/2,)
    ang = positions.astype(jnp.float32)[..., None] * inv  # (..., seq, hd/2)
    sin = jnp.sin(ang)[..., None, :]  # (..., seq, 1, hd/2)
    cos = jnp.cos(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(length: int, d_model: int) -> jax.Array:
    """Whisper-style fixed sinusoidal embeddings (length, d_model)."""
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    half = d_model // 2
    freq = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / max(half - 1, 1))
    ang = pos * freq[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# dtype / loss utilities
# ---------------------------------------------------------------------------
def as_dtype(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32, "float16": jnp.float16}[name]


def cast_tree(tree, dtype):
    return jax.tree.map(
        lambda a: a.astype(dtype) if jnp.issubdtype(a.dtype, jnp.floating) else a, tree
    )


def softmax_xent(logits: jax.Array, targets: jax.Array) -> jax.Array:
    """Token cross-entropy, safe for vocab-sharded logits.

    No gather over the vocab axis (a take_along_axis on a sharded dim makes
    GSPMD replicate the full logits): the gold logit is extracted with an
    iota-compare mask and the LSE uses shard-local reductions + tiny
    cross-shard all-reduces.
    """
    v = logits.shape[-1]
    logits32 = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(logits32, axis=-1, keepdims=True))
    lse = jnp.log(jnp.sum(jnp.exp(logits32 - m), axis=-1)) + m[..., 0]
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    mask = vocab_iota == targets[..., None]
    gold = jnp.sum(jnp.where(mask, logits32, 0.0), axis=-1)
    return lse - gold


def mask_vocab_pad(logits: jax.Array, cfg) -> jax.Array:
    """-inf the pad region of padded-vocab logits (no-op when unpadded)."""
    if cfg.padded_vocab == cfg.vocab_size:
        return logits
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    return jnp.where(iota < cfg.vocab_size, logits, jnp.asarray(-1e30, logits.dtype))


def count_params(params) -> int:
    return sum(int(p.size) for p in jax.tree.leaves(params))
