"""Mamba2 (SSD — state-space dual) blocks + the Zamba2 hybrid LM.

Chunked SSD: within-chunk parallel (decay-masked C·B scores) + cross-chunk
state scan; exact single-step recurrence for decode.  The per-chunk core is
mirrored by the Pallas kernel in ``repro.kernels.ssm_scan``.

Zamba2 layout (see configs/zamba2_7b.py): 13 scanned super-units of
[shared-attn + 6 Mamba2 layers] + tail [shared-attn + 3 Mamba2 layers]
= 81 SSM layers, 14 shared-attention applications.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard_act, shard_params

from . import attention as attn
from . import mlp as mlps
from .common import (
    Params,
    as_dtype,
    dense_init,
    embed_init,
    rmsnorm,
    rmsnorm_init,
    softmax_xent,
    split_keys,
)


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------
def mamba_dims(cfg):
    d_in = cfg.ssm_expand * cfg.d_model
    n_heads = d_in // cfg.ssm_head_dim
    conv_dim = d_in + 2 * cfg.ssm_state
    return d_in, n_heads, conv_dim


def mamba_init(rng, cfg, dtype=jnp.float32) -> Params:
    d = cfg.d_model
    d_in, h, conv_dim = mamba_dims(cfg)
    n = cfg.ssm_state
    k1, k2, k3, k4, k5 = split_keys(rng, 5)
    return {
        "norm": rmsnorm_init(d, dtype),
        "w_in": dense_init(k1, (d, d_in), dtype=dtype),
        "w_z": dense_init(k2, (d, d_in), dtype=dtype),
        "w_bc": dense_init(k3, (d, 2 * n), dtype=dtype),
        "w_dt": dense_init(k4, (d, h), dtype=dtype),
        "dt_bias": jnp.zeros((h,), dtype),
        "A_log": jnp.zeros((h,), dtype),  # A = -exp(A_log) = -1
        "D": jnp.ones((h,), dtype),
        "conv_w": 0.1 * jax.random.normal(k5, (cfg.ssm_conv_width, conv_dim), dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "out_norm": rmsnorm_init(d_in, dtype),
        "w_out": dense_init(k5, (d_in, d), fan_in=d_in, dtype=dtype),
    }


def _causal_conv(xw: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv.  xw (B,S,C), w (W,C)."""
    width = w.shape[0]
    pad = jnp.pad(xw, ((0, 0), (width - 1, 0), (0, 0)))
    s = xw.shape[1]
    out = sum(pad[:, i : i + s, :] * w[i] for i in range(width))
    return jax.nn.silu(out + b)


def _conv_step(x_t: jax.Array, conv_state: jax.Array, w: jax.Array, b: jax.Array):
    """Single-token conv.  x_t (B,C); conv_state (B,W-1,C)."""
    window = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # (B,W,C)
    out = jnp.einsum("bwc,wc->bc", window, w)
    return jax.nn.silu(out + b), window[:, 1:, :]


def ssd_chunked(u, a_log, B_, C_, h0, chunk: int):
    """Chunked SSD scan.

    u (B,S,H,P) dt-scaled inputs; a_log (B,S,H) per-step log decay (<=0);
    B_/C_ (B,S,N); h0 (B,H,P,N).  Returns (y (B,S,H,P), h_final).
    """
    b, s, h, p = u.shape
    n = B_.shape[-1]
    nc = -(-s // chunk)
    pad = nc * chunk - s
    if pad:
        u = jnp.pad(u, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a_log = jnp.pad(a_log, ((0, 0), (0, pad), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0)))
        C_ = jnp.pad(C_, ((0, 0), (0, pad), (0, 0)))

    uc = u.reshape(b, nc, chunk, h, p).transpose(1, 0, 2, 3, 4)
    ac = a_log.reshape(b, nc, chunk, h).transpose(1, 0, 2, 3).astype(jnp.float32)
    bc = B_.reshape(b, nc, chunk, n).transpose(1, 0, 2, 3)
    cc = C_.reshape(b, nc, chunk, n).transpose(1, 0, 2, 3)

    tri = jnp.tril(jnp.ones((chunk, chunk), jnp.bool_))

    def body(hprev, inp):
        u_j, a_j, b_j, c_j = inp  # (B,L,H,P) (B,L,H) (B,L,N) (B,L,N)
        acum = jnp.cumsum(a_j, axis=1)  # (B,L,H) decay chunk-start..t
        atot = acum[:, -1:, :]  # (B,1,H)
        # intra-chunk
        cb = jnp.einsum("bln,bmn->blm", c_j.astype(jnp.float32), b_j.astype(jnp.float32))
        decay = jnp.exp(
            jnp.clip(acum[:, :, None, :] - acum[:, None, :, :], -60.0, 0.0)
        )  # (B,L,M,H): exp(A_t - A_s)
        w = cb[..., None] * decay * tri[None, :, :, None]
        y_intra = jnp.einsum("blmh,bmhp->blhp", w, u_j.astype(jnp.float32))
        # inter-chunk (state contribution)
        y_inter = jnp.einsum("bln,bhpn->blhp", c_j.astype(jnp.float32), hprev) * jnp.exp(
            acum
        ).transpose(0, 1, 2)[..., None]
        # new state
        sdecay = jnp.exp(jnp.clip(atot - acum, -60.0, 0.0))  # (B,L,H)
        h_new = hprev * jnp.exp(atot).transpose(0, 2, 1)[..., None] + jnp.einsum(
            "bln,blh,blhp->bhpn", b_j.astype(jnp.float32), sdecay, u_j.astype(jnp.float32)
        )
        return h_new, y_intra + y_inter

    h_final, yc = jax.lax.scan(body, h0.astype(jnp.float32), (uc, ac, bc, cc))
    y = yc.transpose(1, 0, 2, 3, 4).reshape(b, nc * chunk, h, p)
    return y[:, :s].astype(u.dtype), h_final


def mamba_forward(p: Params, x: jax.Array, cfg, h0=None, return_state: bool = False):
    """Full-sequence Mamba2 block (no residual).  x (B,S,d).

    ``cfg.ssm_impl == "pallas"`` routes the scan through the dispatch-API
    kernel; stateful calls (``h0`` given or ``return_state=True``) always use
    the jnp chunked scan — the kernel has no initial/final-state interface.
    """
    bsz, s, d = x.shape
    d_in, h, conv_dim = mamba_dims(cfg)
    n, pd = cfg.ssm_state, cfg.ssm_head_dim
    dt = x.dtype

    xin = x @ p["w_in"].astype(dt)
    z = x @ p["w_z"].astype(dt)
    bc = x @ p["w_bc"].astype(dt)
    dt_raw = (x @ p["w_dt"].astype(dt)).astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
    delta = jax.nn.softplus(dt_raw)  # (B,S,H)

    conv_in = jnp.concatenate([xin, bc], axis=-1)
    conv_out = _causal_conv(conv_in, p["conv_w"].astype(dt), p["conv_b"].astype(dt))
    xin, b_, c_ = jnp.split(conv_out, [d_in, d_in + n], axis=-1)

    xh = xin.reshape(bsz, s, h, pd)
    a_log = -jnp.exp(p["A_log"].astype(jnp.float32))[None, None] * delta  # (B,S,H)
    u = xh * delta.astype(dt)[..., None]

    if cfg.ssm_impl == "pallas" and h0 is None and not return_state:
        # dispatch-API kernel path: head-shared B/C layout matches directly;
        # the kernel owns chunking/padding and starts from a zero state
        from repro.kernels import api

        y = api.ssm_scan(u, a_log, b_, c_, chunk=cfg.ssm_chunk)
        h_final = None
    else:
        if h0 is None:
            h0 = jnp.zeros((bsz, h, pd, n), jnp.float32)
        y, h_final = ssd_chunked(u, a_log, b_, c_, h0, cfg.ssm_chunk)
    y = y + xh * p["D"].astype(dt)[None, None, :, None]
    y = y.reshape(bsz, s, d_in)
    y = rmsnorm(p["out_norm"], y, cfg.norm_eps) * jax.nn.silu(z)
    out = y @ p["w_out"].astype(dt)
    if return_state:
        return out, h_final
    return out


def mamba_decode(p: Params, x: jax.Array, cfg, ssm_state, conv_state):
    """Single-token Mamba2 step.  x (B,d); returns (y, ssm_state, conv_state)."""
    bsz, d = x.shape
    d_in, h, conv_dim = mamba_dims(cfg)
    n, pd = cfg.ssm_state, cfg.ssm_head_dim
    dt = x.dtype

    xin = x @ p["w_in"].astype(dt)
    z = x @ p["w_z"].astype(dt)
    bc = x @ p["w_bc"].astype(dt)
    delta = jax.nn.softplus(
        (x @ p["w_dt"].astype(dt)).astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
    )  # (B,H)

    conv_in = jnp.concatenate([xin, bc], axis=-1)
    conv_out, conv_state = _conv_step(conv_in, conv_state, p["conv_w"].astype(dt), p["conv_b"].astype(dt))
    xin, b_, c_ = jnp.split(conv_out, [d_in, d_in + n], axis=-1)

    xh = xin.reshape(bsz, h, pd).astype(jnp.float32)
    decay = jnp.exp(-jnp.exp(p["A_log"].astype(jnp.float32))[None] * delta)  # (B,H)
    u = xh * delta[..., None]
    ssm_state = ssm_state * decay[..., None, None] + jnp.einsum(
        "bn,bhp->bhpn", b_.astype(jnp.float32), u
    )
    y = jnp.einsum("bn,bhpn->bhp", c_.astype(jnp.float32), ssm_state)
    y = y + xh * p["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(bsz, d_in).astype(dt)
    y = rmsnorm(p["out_norm"], y, cfg.norm_eps) * jax.nn.silu(z)
    return y @ p["w_out"].astype(dt), ssm_state, conv_state


# ---------------------------------------------------------------------------
# Zamba2 hybrid LM
# ---------------------------------------------------------------------------
def _zamba_counts(cfg):
    """(n_super, mamba_per_super, tail_layers)."""
    per = cfg.macro_size * cfg.attn_every_k_macro  # 6
    n_super = cfg.n_layers // per  # 13
    tail = cfg.n_layers - n_super * per  # 3
    return n_super, per, tail


def _shared_attn_init(rng, cfg, dtype) -> Params:
    """Shared transformer block taking concat(x, x0) = 2d input."""
    k1, k2 = split_keys(rng, 2)
    return {
        "norm": rmsnorm_init(2 * cfg.d_model, dtype),
        "attn": attn.attn_init(k1, cfg, d_in=2 * cfg.d_model, dtype=dtype),
        "mlp_norm": rmsnorm_init(cfg.d_model, dtype),
        "mlp": mlps.mlp_init(k2, cfg, dtype=dtype),
    }


def zamba_init(rng, cfg) -> Params:
    dtype = as_dtype(cfg.param_dtype)
    n_super, per, tail = _zamba_counts(cfg)
    ke, ks, kt, ka, kh = split_keys(rng, 5)

    def stack_init(k, n):
        keys = jnp.stack(split_keys(k, n))
        return jax.vmap(lambda kk: mamba_init(kk, cfg, dtype))(keys)

    super_keys = jnp.stack(split_keys(ks, n_super))
    supers = jax.vmap(lambda kk: stack_init(kk, per))(super_keys)  # (n_super, per, ...)
    p = {
        "embed": embed_init(ke, (cfg.padded_vocab, cfg.d_model), dtype),
        "supers": supers,
        "shared_attn": _shared_attn_init(ka, cfg, dtype),
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
        "lm_head": embed_init(kh, (cfg.d_model, cfg.padded_vocab), dtype),
    }
    if tail:
        p["tail"] = stack_init(kt, tail)
    return p


def _shared_attn_apply(cfg, p: Params, x, x0, positions):
    cat = jnp.concatenate([x, x0], axis=-1)
    h = attn.attention_block(
        p["attn"], rmsnorm(p["norm"], cat, cfg.norm_eps), cfg, positions, causal=True
    )
    x = x + h
    x = x + mlps.mlp(p["mlp"], rmsnorm(p["mlp_norm"], x, cfg.norm_eps), cfg)
    return shard_act(x, "dp", None, None)


def _shared_attn_decode(cfg, p: Params, x, x0, ck, cv, pos):
    cat = jnp.concatenate([x, x0], axis=-1)
    h, ck, cv = attn.decode_attention(
        p["attn"], rmsnorm(p["norm"], cat, cfg.norm_eps), cfg, ck, cv, pos
    )
    x = x + h
    x = x + mlps.mlp(p["mlp"], rmsnorm(p["mlp_norm"], x, cfg.norm_eps), cfg)
    return x, ck, cv


def _mamba_residual(cfg, p, x, h0=None, return_state=False):
    xin = rmsnorm(p["norm"], x, cfg.norm_eps)
    if return_state:
        y, h = mamba_forward(p, xin, cfg, h0=h0, return_state=True)
        return x + y, h
    return x + mamba_forward(p, xin, cfg)


def zamba_forward(params: Params, tokens: jax.Array, cfg):
    """tokens (B,S) -> logits (B,S,V)."""
    dt = as_dtype(cfg.dtype)
    x = params["embed"].astype(dt)[tokens]
    x = shard_act(x, "dp", None, None)
    x0 = x
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    n_super, per, tail = _zamba_counts(cfg)

    mamba_res = partial(_mamba_residual, cfg)
    if cfg.remat:
        mamba_res = jax.checkpoint(mamba_res, static_argnums=())

    def super_step(x, sp):
        sp = shard_params(sp, cfg)
        x = _shared_attn_apply(cfg, params["shared_attn"], x, x0, positions)

        def layer_step(x, lp):
            return mamba_res(lp, x), None

        if cfg.scan_layers:
            x, _ = jax.lax.scan(layer_step, x, sp)
        else:
            for i in range(per):
                x, _ = layer_step(x, jax.tree.map(lambda a: a[i], sp))
        return x, None

    if cfg.scan_layers:
        x, _ = jax.lax.scan(super_step, x, params["supers"])
    else:
        for i in range(n_super):
            x, _ = super_step(x, jax.tree.map(lambda a: a[i], params["supers"]))
    if tail:  # one more shared-attn + remaining mamba layers
        x = _shared_attn_apply(cfg, params["shared_attn"], x, x0, positions)

        def layer_step(x, lp):
            return mamba_res(lp, x), None

        if cfg.scan_layers:
            x, _ = jax.lax.scan(layer_step, x, params["tail"])
        else:
            for i in range(tail):
                x, _ = layer_step(x, jax.tree.map(lambda a: a[i], params["tail"]))
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(dt))
    return shard_act(logits, "dp", None, "tp")


def zamba_loss(params: Params, batch: dict, cfg) -> jax.Array:
    logits = zamba_forward(params, batch["tokens"], cfg)
    return softmax_xent(logits, batch["targets"]).mean()


# --- serving -----------------------------------------------------------------
def zamba_cache_specs(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    n_super, per, tail = _zamba_counts(cfg)
    d_in, h, conv_dim = mamba_dims(cfg)
    n_attn = n_super + (1 if tail else 0)
    n_ssm = cfg.n_layers
    kv = (n_attn, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return {
        "k": jax.ShapeDtypeStruct(kv, dtype),
        "v": jax.ShapeDtypeStruct(kv, dtype),
        "ssm": jax.ShapeDtypeStruct(
            (n_ssm, batch, h, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32
        ),
        "conv": jax.ShapeDtypeStruct(
            (n_ssm, batch, cfg.ssm_conv_width - 1, conv_dim), dtype
        ),
        "x0": jax.ShapeDtypeStruct((batch, cfg.d_model), dtype),
    }


def zamba_init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    return jax.tree.map(
        lambda sd: jnp.zeros(sd.shape, sd.dtype), zamba_cache_specs(cfg, batch, max_len, dtype)
    )


def zamba_decode_step(params: Params, cache: dict, tokens: jax.Array, pos: jax.Array, cfg):
    """One decode step.  Scanned over super-units (attn + `per` mamba layers)
    for compact HLO; the tail unit (attn + remaining layers) is explicit.
    x0 (residual embedding stream) is the current token's embedding."""
    dt = as_dtype(cfg.dtype)
    x = params["embed"].astype(dt)[tokens]
    x0 = x  # zamba concatenates the original embedding stream
    n_super, per, tail = _zamba_counts(cfg)

    n_main = n_super * per
    ssm_main = cache["ssm"][:n_main].reshape((n_super, per) + cache["ssm"].shape[1:])
    conv_main = cache["conv"][:n_main].reshape((n_super, per) + cache["conv"].shape[1:])
    ks, vs = cache["k"], cache["v"]

    def mamba_step(x, lin):
        lp, s_st, c_st = lin
        xin = rmsnorm(lp["norm"], x, cfg.norm_eps)
        y, s_new, c_new = mamba_decode(lp, xin, cfg, s_st, c_st)
        return x + y, (s_new, c_new)

    def super_step(x, inp):
        sp, ck, cv, s_st, c_st = inp
        x, ck, cv = _shared_attn_decode(cfg, params["shared_attn"], x, x0, ck, cv, pos)
        if cfg.scan_layers:
            x, (s_new, c_new) = jax.lax.scan(mamba_step, x, (sp, s_st, c_st))
        else:
            acc = []
            for i in range(per):
                x, o = mamba_step(x, jax.tree.map(lambda a: a[i], (sp, s_st, c_st)))
                acc.append(o)
            s_new = jnp.stack([a[0] for a in acc])
            c_new = jnp.stack([a[1] for a in acc])
        return x, (ck, cv, s_new, c_new)

    scan_in = (params["supers"], ks[:n_super], vs[:n_super], ssm_main, conv_main)
    if cfg.scan_layers:
        x, (nk, nv, nssm, nconv) = jax.lax.scan(super_step, x, scan_in)
    else:
        acc = []
        for i in range(n_super):
            x, o = super_step(x, jax.tree.map(lambda a: a[i], scan_in))
            acc.append(o)
        nk, nv, nssm, nconv = (jnp.stack([a[j] for a in acc]) for j in range(4))

    nssm = nssm.reshape((n_main,) + nssm.shape[2:])
    nconv = nconv.reshape((n_main,) + nconv.shape[2:])

    if tail:
        x, ckt, cvt = _shared_attn_decode(
            cfg, params["shared_attn"], x, x0, ks[n_super], vs[n_super], pos
        )
        t_ssm, t_conv = [], []
        for lj in range(tail):
            lp = jax.tree.map(lambda a: a[lj], params["tail"])
            x, (s_new, c_new) = mamba_step(x, (lp, cache["ssm"][n_main + lj],
                                               cache["conv"][n_main + lj]))
            t_ssm.append(s_new)
            t_conv.append(c_new)
        nk = jnp.concatenate([nk, ckt[None]], axis=0)
        nv = jnp.concatenate([nv, cvt[None]], axis=0)
        nssm = jnp.concatenate([nssm, jnp.stack(t_ssm)], axis=0)
        nconv = jnp.concatenate([nconv, jnp.stack(t_conv)], axis=0)

    x = rmsnorm(params["final_norm"], x[:, None], cfg.norm_eps)[:, 0]
    logits = x @ params["lm_head"].astype(dt)
    cache = {"k": nk, "v": nv, "ssm": nssm, "conv": nconv, "x0": x0}
    return logits, cache
