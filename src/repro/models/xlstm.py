"""xLSTM LM: mLSTM (matrix-memory, chunkwise-parallel) + sLSTM (scalar-memory,
sequential) blocks, arranged as scanned macro-blocks of (7 mLSTM + 1 sLSTM).

mLSTM uses the exponentially-gated linear-attention form with running-max
stabilization, computed chunkwise (the same HBM->VMEM tiling pattern the
paper's Ch.3 motivates); decode is the exact single-step recurrence.
Simplification vs. the paper: both block types use a shared gated-FFN
sub-layer instead of the paper's asymmetric pre/post up-projections.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard_act, shard_params

from .common import (
    Params,
    as_dtype,
    dense_init,
    embed_init,
    rmsnorm,
    rmsnorm_init,
    softmax_xent,
    split_keys,
)

MCLIP = 60.0


# ---------------------------------------------------------------------------
# mLSTM cell
# ---------------------------------------------------------------------------
def mlstm_init(rng, cfg, dtype=jnp.float32) -> Params:
    d, h = cfg.d_model, cfg.n_heads
    k1, k2, k3, k4 = split_keys(rng, 4)
    return {
        "norm": rmsnorm_init(d, dtype),
        "w_qkv": dense_init(k1, (d, 3 * d), dtype=dtype),
        "w_gates": dense_init(k2, (d, 2 * h), dtype=dtype),
        "gate_bias": jnp.concatenate([jnp.zeros((h,), dtype), 3.0 * jnp.ones((h,), dtype)]),
        "ffn_norm": rmsnorm_init(d, dtype),
        "w_up": dense_init(k3, (d, 4 * d), dtype=dtype),
        "w_down": dense_init(k4, (2 * d, d), fan_in=2 * d, dtype=dtype),
    }


def _mlstm_qkvif(p, x, cfg):
    dt = x.dtype
    b, s, d = x.shape
    h = cfg.n_heads
    hd = d // h
    qkv = x @ p["w_qkv"].astype(dt)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(b, s, h, hd) * (hd**-0.5)
    k = k.reshape(b, s, h, hd)
    v = v.reshape(b, s, h, hd)
    gates = (x @ p["w_gates"].astype(dt)).astype(jnp.float32) + p["gate_bias"].astype(
        jnp.float32
    )
    i_raw, f_raw = jnp.split(gates, 2, axis=-1)  # (B,S,H)
    log_f = jax.nn.log_sigmoid(f_raw)
    return q, k, v, i_raw, log_f


def mlstm_chunked(q, k, v, i_raw, log_f, state, chunk: int):
    """Chunkwise stabilized mLSTM.

    q,k,v (B,S,H,hd); i_raw/log_f (B,S,H); state = (C (B,H,hd,hd), n (B,H,hd),
    m (B,H)) fp32.  Returns (y (B,S,H,hd), state).
    """
    b, s, h, hd = q.shape
    nc = -(-s // chunk)
    pad = nc * chunk - s
    if pad:
        zf = ((0, 0), (0, pad), (0, 0), (0, 0))
        q, k, v = (jnp.pad(a, zf) for a in (q, k, v))
        i_raw = jnp.pad(i_raw, ((0, 0), (0, pad), (0, 0)), constant_values=-1e30)
        log_f = jnp.pad(log_f, ((0, 0), (0, pad), (0, 0)))

    def chunked(a):  # (B, S', ...) -> (N, B, L, ...)
        return a.reshape((b, nc, chunk) + a.shape[2:]).swapaxes(0, 1)

    qc, kc, vc, ic, fc = (chunked(a) for a in (q, k, v, i_raw, log_f))
    tri = jnp.tril(jnp.ones((chunk, chunk), jnp.bool_))

    def body(carry, inp):
        C, n, m = carry
        q_j, k_j, v_j, i_j, f_j = inp
        q32, k32, v32 = (a.astype(jnp.float32) for a in (q_j, k_j, v_j))
        F = jnp.cumsum(f_j, axis=1)  # (B,L,H)
        F_tot = F[:, -1]  # (B,H)
        b_t = F + m[:, None]  # inter log-scale
        # intra log weights D_ts = F_t - F_s + i_s
        D = F[:, :, None, :] - F[:, None, :, :] + i_j[:, None, :, :]  # (B,L,M,H)
        D = jnp.where(tri[None, :, :, None], D, -jnp.inf)
        m_intra = D.max(axis=2)  # (B,L,H)
        m_t = jnp.maximum(b_t, m_intra)
        m_t = jnp.maximum(m_t, -MCLIP)  # keep denominators sane
        Dw = jnp.exp(jnp.clip(D - m_t[:, :, None, :], -MCLIP, 0.0))
        Dw = jnp.where(tri[None, :, :, None], Dw, 0.0)
        qk = jnp.einsum("blhx,bmhx->blmh", q32, k32)
        Sw = qk * Dw  # (B,L,M,H)
        y_intra = jnp.einsum("blmh,bmhx->blhx", Sw, v32)
        inter_scale = jnp.exp(jnp.clip(b_t - m_t, -MCLIP, 0.0))  # (B,L,H)
        y_inter = jnp.einsum("blhx,bhxy->blhy", q32, C) * inter_scale[..., None]
        norm = Sw.sum(axis=2) + jnp.einsum("blhx,bhx->blh", q32, n) * inter_scale
        denom = jnp.maximum(jnp.abs(norm), jnp.exp(-m_t))
        y = (y_intra + y_inter) / denom[..., None]
        # state update
        s_log = F_tot[:, None, :] - F + i_j  # (B,L,H): decay from s to chunk end
        m_new = jnp.maximum(F_tot + m, s_log.max(axis=1))
        m_new = jnp.maximum(m_new, -MCLIP)
        state_scale = jnp.exp(jnp.clip(F_tot + m - m_new, -MCLIP, 0.0))
        in_w = jnp.exp(jnp.clip(s_log - m_new[:, None, :], -MCLIP, 0.0))
        C_new = C * state_scale[:, :, None, None] + jnp.einsum(
            "blhx,blhy,blh->bhxy", k32, v32, in_w
        )
        n_new = n * state_scale[..., None] + jnp.einsum("blhx,blh->bhx", k32, in_w)
        return (C_new, n_new, m_new), y

    (C, n, m), yc = jax.lax.scan(body, state, (qc, kc, vc, ic, fc))
    y = yc.swapaxes(0, 1).reshape(b, nc * chunk, h, hd)[:, :s]
    return y.astype(q.dtype), (C, n, m)


def mlstm_step(q, k, v, i_raw, log_f, state):
    """Exact single-token mLSTM recurrence.  q,k,v (B,H,hd); gates (B,H)."""
    C, n, m = state
    q32, k32, v32 = (a.astype(jnp.float32) for a in (q, k, v))
    m_new = jnp.maximum(log_f + m, i_raw)
    m_new = jnp.maximum(m_new, -MCLIP)
    f_w = jnp.exp(jnp.clip(log_f + m - m_new, -MCLIP, 0.0))
    i_w = jnp.exp(jnp.clip(i_raw - m_new, -MCLIP, 0.0))
    C = C * f_w[..., None, None] + i_w[..., None, None] * jnp.einsum(
        "bhx,bhy->bhxy", k32, v32
    )
    n = n * f_w[..., None] + i_w[..., None] * k32
    y = jnp.einsum("bhx,bhxy->bhy", q32, C)
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhx,bhx->bh", q32, n)), jnp.exp(-m_new))
    y = y / denom[..., None]
    return y.astype(q.dtype), (C, n, m_new)


def _ffn(p, x, cfg):
    dt = x.dtype
    up = x @ p["w_up"].astype(dt)
    g, u = jnp.split(up, 2, axis=-1)
    return (jax.nn.silu(g) * u) @ p["w_down"].astype(dt)


def mlstm_block(p: Params, x: jax.Array, cfg, state=None, return_state: bool = False):
    b, s, d = x.shape
    h, hd = cfg.n_heads, d // cfg.n_heads
    if state is None:
        state = (
            jnp.zeros((b, h, hd, hd), jnp.float32),
            jnp.zeros((b, h, hd), jnp.float32),
            jnp.zeros((b, h), jnp.float32),
        )
    xin = rmsnorm(p["norm"], x, cfg.norm_eps)
    q, k, v, i_raw, log_f = _mlstm_qkvif(p, xin, cfg)
    y, state = mlstm_chunked(q, k, v, i_raw, log_f, state, cfg.ssm_chunk)
    x = x + y.reshape(b, s, d)
    x = x + _ffn(p, rmsnorm(p["ffn_norm"], x, cfg.norm_eps), cfg)
    x = shard_act(x, "dp", None, None)
    if return_state:
        return x, state
    return x


def mlstm_block_decode(p: Params, x: jax.Array, cfg, state):
    """x (B,d)."""
    b, d = x.shape
    h, hd = cfg.n_heads, d // cfg.n_heads
    xin = rmsnorm(p["norm"], x[:, None], cfg.norm_eps)
    q, k, v, i_raw, log_f = _mlstm_qkvif(p, xin, cfg)
    y, state = mlstm_step(q[:, 0], k[:, 0], v[:, 0], i_raw[:, 0], log_f[:, 0], state)
    x = x + y.reshape(b, d)
    x = x + _ffn(p, rmsnorm(p["ffn_norm"], x[:, None], cfg.norm_eps), cfg)[:, 0]
    return x, state


# ---------------------------------------------------------------------------
# sLSTM cell (sequential scan; few layers)
# ---------------------------------------------------------------------------
def slstm_init(rng, cfg, dtype=jnp.float32) -> Params:
    d, h = cfg.d_model, cfg.n_heads
    hd = d // h
    k1, k2, k3, k4 = split_keys(rng, 4)
    return {
        "norm": rmsnorm_init(d, dtype),
        "w_x": dense_init(k1, (d, 4 * d), dtype=dtype),
        "bias": jnp.concatenate(
            [jnp.zeros((2 * d,), dtype), 3.0 * jnp.ones((d,), dtype), jnp.zeros((d,), dtype)]
        ),
        "r": 0.1 * jax.random.normal(k2, (4, h, hd, hd), dtype),
        "ffn_norm": rmsnorm_init(d, dtype),
        "w_up": dense_init(k3, (d, 4 * d), dtype=dtype),
        "w_down": dense_init(k4, (2 * d, d), fan_in=2 * d, dtype=dtype),
    }


def _slstm_scan(p, xg, cfg, state):
    """xg: (B,S,4d) precomputed input projections (+bias).  Sequential scan."""
    b, s, _ = xg.shape
    d = cfg.d_model
    h, hd = cfg.n_heads, d // cfg.n_heads
    r = p["r"].astype(jnp.float32)

    def step(carry, x_t):
        c, n, m, hprev = carry  # (B,H,hd) x3, m (B,H,hd)... m per unit
        rec = jnp.einsum("bhx,ghxy->gbhy", hprev, r)  # (4,B,H,hd)
        zt, it, ft, ot = (
            x_t.reshape(b, 4, h, hd).swapaxes(0, 1).astype(jnp.float32) + rec
        )
        z = jnp.tanh(zt)
        o = jax.nn.sigmoid(ot)
        log_f = jax.nn.log_sigmoid(ft)
        m_new = jnp.maximum(log_f + m, it)
        m_new = jnp.maximum(m_new, -MCLIP)
        f_w = jnp.exp(jnp.clip(log_f + m - m_new, -MCLIP, 0.0))
        i_w = jnp.exp(jnp.clip(it - m_new, -MCLIP, 0.0))
        c = f_w * c + i_w * z
        n = f_w * n + i_w
        h_new = o * c / jnp.maximum(n, 1.0)
        return (c, n, m_new, h_new), h_new

    (c, n, m, hlast), ys = jax.lax.scan(step, state, xg.swapaxes(0, 1))
    return ys.swapaxes(0, 1).reshape(b, s, d), (c, n, m, hlast)


def slstm_zero_state(cfg, batch):
    h, hd = cfg.n_heads, cfg.d_model // cfg.n_heads
    z = jnp.zeros((batch, h, hd), jnp.float32)
    return (z, z, z, z)


def slstm_block(p: Params, x: jax.Array, cfg, state=None, return_state: bool = False):
    b, s, d = x.shape
    if state is None:
        state = slstm_zero_state(cfg, b)
    xin = rmsnorm(p["norm"], x, cfg.norm_eps)
    xg = xin @ p["w_x"].astype(x.dtype) + p["bias"].astype(x.dtype)
    y, state = _slstm_scan(p, xg, cfg, state)
    x = x + y.astype(x.dtype)
    x = x + _ffn(p, rmsnorm(p["ffn_norm"], x, cfg.norm_eps), cfg)
    x = shard_act(x, "dp", None, None)
    if return_state:
        return x, state
    return x


def slstm_block_decode(p: Params, x: jax.Array, cfg, state):
    b, d = x.shape
    xin = rmsnorm(p["norm"], x[:, None], cfg.norm_eps)
    xg = xin @ p["w_x"].astype(x.dtype) + p["bias"].astype(x.dtype)
    y, state = _slstm_scan(p, xg, cfg, state)
    x = x + y[:, 0].astype(x.dtype)
    x = x + _ffn(p, rmsnorm(p["ffn_norm"], x[:, None], cfg.norm_eps), cfg)[:, 0]
    return x, state


# ---------------------------------------------------------------------------
# full LM
# ---------------------------------------------------------------------------
def _n_macros(cfg):
    per = cfg.xlstm_mlstm_per_macro + cfg.xlstm_slstm_per_macro
    assert cfg.n_layers % per == 0, "n_layers must divide into macro blocks"
    return cfg.n_layers // per


def xlstm_init(rng, cfg) -> Params:
    dtype = as_dtype(cfg.param_dtype)
    nm = _n_macros(cfg)
    ke, km, kh = split_keys(rng, 3)

    def macro_init(k):
        k1, k2 = split_keys(k, 2)
        mkeys = jnp.stack(split_keys(k1, cfg.xlstm_mlstm_per_macro))
        return {
            "mlstm": jax.vmap(lambda kk: mlstm_init(kk, cfg, dtype))(mkeys),
            "slstm": slstm_init(k2, cfg, dtype),
        }

    mkeys = jnp.stack(split_keys(km, nm))
    return {
        "embed": embed_init(ke, (cfg.padded_vocab, cfg.d_model), dtype),
        "macros": jax.vmap(macro_init)(mkeys),
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
        "lm_head": embed_init(kh, (cfg.d_model, cfg.padded_vocab), dtype),
    }


def xlstm_forward(params: Params, tokens: jax.Array, cfg):
    dt = as_dtype(cfg.dtype)
    x = params["embed"].astype(dt)[tokens]
    x = shard_act(x, "dp", None, None)

    mblock = partial(mlstm_block, cfg=cfg)
    sblock = partial(slstm_block, cfg=cfg)
    if cfg.remat:
        mblock = jax.checkpoint(mblock)
        sblock = jax.checkpoint(sblock)

    def macro_step(x, mp):
        mp = shard_params(mp, cfg)

        def layer(x, lp):
            return mblock(lp, x), None

        if cfg.scan_layers:
            x, _ = jax.lax.scan(layer, x, mp["mlstm"])
        else:
            for i in range(cfg.xlstm_mlstm_per_macro):
                x, _ = layer(x, jax.tree.map(lambda a: a[i], mp["mlstm"]))
        x = sblock(mp["slstm"], x)
        return x, None

    if cfg.scan_layers:
        x, _ = jax.lax.scan(macro_step, x, params["macros"])
    else:
        for i in range(_n_macros(cfg)):
            x, _ = macro_step(x, jax.tree.map(lambda a: a[i], params["macros"]))
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(dt))
    return shard_act(logits, "dp", None, "tp")


def xlstm_loss(params: Params, batch: dict, cfg) -> jax.Array:
    logits = xlstm_forward(params, batch["tokens"], cfg)
    return softmax_xent(logits, batch["targets"]).mean()


# --- serving -----------------------------------------------------------------
def xlstm_cache_specs(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    nm = _n_macros(cfg)
    h, hd = cfg.n_heads, cfg.d_model // cfg.n_heads
    nmm = cfg.xlstm_mlstm_per_macro
    f32 = jnp.float32
    return {
        "mC": jax.ShapeDtypeStruct((nm, nmm, batch, h, hd, hd), f32),
        "mn": jax.ShapeDtypeStruct((nm, nmm, batch, h, hd), f32),
        "mm": jax.ShapeDtypeStruct((nm, nmm, batch, h), f32),
        "sc": jax.ShapeDtypeStruct((nm, batch, h, hd), f32),
        "sn": jax.ShapeDtypeStruct((nm, batch, h, hd), f32),
        "sm": jax.ShapeDtypeStruct((nm, batch, h, hd), f32),
        "sh": jax.ShapeDtypeStruct((nm, batch, h, hd), f32),
    }


def xlstm_init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    return jax.tree.map(
        lambda sd: jnp.zeros(sd.shape, sd.dtype), xlstm_cache_specs(cfg, batch, max_len)
    )


def xlstm_decode_step(params: Params, cache: dict, tokens: jax.Array, pos: jax.Array, cfg):
    dt = as_dtype(cfg.dtype)
    x = params["embed"].astype(dt)[tokens]

    def macro_step(x, inp):
        mp, mC, mn, mm, sc, sn, sm, sh = inp

        def layer(carry, lin):
            x = carry
            lp, C, n, m = lin
            x, (C, n, m) = mlstm_block_decode(lp, x, cfg, (C, n, m))
            return x, (C, n, m)

        if cfg.scan_layers:
            x, (mC, mn, mm) = jax.lax.scan(layer, x, (mp["mlstm"], mC, mn, mm))
        else:
            acc = []
            for i in range(cfg.xlstm_mlstm_per_macro):
                x, st = layer(x, jax.tree.map(lambda a: a[i], (mp["mlstm"], mC, mn, mm)))
                acc.append(st)
            mC, mn, mm = (jnp.stack([a[j] for a in acc]) for j in range(3))
        x, (sc, sn, sm, sh) = slstm_block_decode(mp["slstm"], x, cfg, (sc, sn, sm, sh))
        return x, (mC, mn, mm, sc, sn, sm, sh)

    scan_in = (
        params["macros"],
        cache["mC"],
        cache["mn"],
        cache["mm"],
        cache["sc"],
        cache["sn"],
        cache["sm"],
        cache["sh"],
    )
    if cfg.scan_layers:
        x, (mC, mn, mm, sc, sn, sm, sh) = jax.lax.scan(macro_step, x, scan_in)
    else:
        outs = []
        for i in range(_n_macros(cfg)):
            x, o = macro_step(x, jax.tree.map(lambda a: a[i], scan_in))
            outs.append(o)
        mC, mn, mm, sc, sn, sm, sh = (jnp.stack([o[j] for o in outs]) for j in range(7))
    x = rmsnorm(params["final_norm"], x[:, None], cfg.norm_eps)[:, 0]
    logits = x @ params["lm_head"].astype(dt)
    cache = {"mC": mC, "mn": mn, "mm": mm, "sc": sc, "sn": sn, "sm": sm, "sh": sh}
    return logits, cache
