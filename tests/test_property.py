"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.pchase import detect_plateaus, single_cycle_permutation
from repro.core.throttle import T4_THROTTLE, simulate, steady_state_clock
from repro.kernels import api, ref

FAST = settings(max_examples=20, deadline=None)


# ---------------------------------------------------------------------------
@given(n=st.integers(4, 512), seed=st.integers(0, 1000))
@FAST
def test_single_cycle_permutation_is_one_cycle(n, seed):
    perm = single_cycle_permutation(n, seed)
    assert sorted(perm) == list(range(n))  # a permutation
    idx, seen = 0, set()
    for _ in range(n):
        assert idx not in seen
        seen.add(idx)
        idx = int(perm[idx])
    assert idx == 0 and len(seen) == n  # one full cycle


@given(
    caps=st.lists(st.integers(12, 24), min_size=1, max_size=3, unique=True),
    lat0=st.floats(1.0, 10.0),
    growth=st.floats(2.0, 6.0),
)
@FAST
def test_plateau_detection_recovers_planted_hierarchy(caps, lat0, growth):
    """Planted cache hierarchy -> detected capacities match exactly."""
    caps = sorted(1 << c for c in caps)
    sizes = np.array([1 << p for p in range(10, 27)])
    lat = np.full(len(sizes), lat0)
    for c in caps:
        lat = np.where(sizes > c, lat * growth, lat)
    plats = detect_plateaus(sizes, lat, rel_jump=0.3)
    detected = [p.end_size for p in plats[:-1]]
    expected = [c for c in caps if c < sizes[-1]]
    assert detected == expected


# ---------------------------------------------------------------------------
@given(u=st.floats(0.3, 1.0))
@FAST
def test_throttle_invariants(u):
    """Clock within [f_min, f_max]; sustained power never exceeds the limit
    by more than the governor's one-step overshoot; temp bounded."""
    out = simulate(T4_THROTTLE, utilization=u, duration_s=240, dt=0.5)
    assert out["clock_hz"].max() <= T4_THROTTLE.f_max_hz + 1e-3
    assert out["clock_hz"].min() >= 0.1 * T4_THROTTLE.f_max_hz - 1e-3
    # steady state respects the power cap
    assert out["power_w"][-20:].mean() <= T4_THROTTLE.power_limit_w * 1.05
    assert out["temp_c"].max() <= T4_THROTTLE.max_temp_c + 8.0


@given(u1=st.floats(0.4, 0.7), u2=st.floats(0.75, 1.0))
@FAST
def test_throttle_monotone_in_utilization(u1, u2):
    """More utilization -> no higher steady-state clock."""
    assert steady_state_clock(T4_THROTTLE, u2) <= steady_state_clock(T4_THROTTLE, u1) + 1e3


# ---------------------------------------------------------------------------
@given(
    s=st.integers(8, 96),
    hd=st.sampled_from([16, 32]),
    causal=st.booleans(),
    seed=st.integers(0, 99),
)
@settings(max_examples=15, deadline=None)
def test_flash_attention_matches_oracle_property(s, hd, causal, seed):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(1, s, 1, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, s, 1, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, s, 1, hd)).astype(np.float32))
    got = api.flash_attention(q, k, v, causal=causal, bq=32, bk=32)
    want = ref.flash_attention_ref(
        q[:, :, 0], k[:, :, 0], v[:, :, 0], causal=causal
    )[:, :, None]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@given(
    s=st.integers(4, 64),
    chunk=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 99),
)
@settings(max_examples=15, deadline=None)
def test_ssm_scan_matches_sequential_property(s, chunk, seed):
    rng = np.random.default_rng(seed)
    u = jnp.asarray(rng.normal(size=(1, s, 1, 8)).astype(np.float32))
    a = -jnp.abs(jnp.asarray(rng.normal(size=(1, s, 1)).astype(np.float32))) * 0.3
    B_ = jnp.asarray(rng.normal(size=(1, s, 4)).astype(np.float32))
    C_ = jnp.asarray(rng.normal(size=(1, s, 4)).astype(np.float32))
    got = api.ssm_scan(u, a, B_, C_, chunk=chunk)[:, :, 0]
    want = ref.ssm_scan_ref(u[:, :, 0], a[:, :, 0], B_, C_)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
@given(
    b=st.integers(1, 3),
    s=st.integers(2, 16),
    seed=st.integers(0, 50),
)
@settings(max_examples=10, deadline=None)
def test_moe_conservation_property(b, s, seed):
    """With ample capacity, MoE output == dense-dispatch oracle, and router
    weights per token sum to 1 (conservation)."""
    from repro.configs import get_config
    from repro.models.mlp import moe_block, moe_block_dense, moe_init

    cfg = get_config("olmoe-1b-7b").reduced().replace(capacity_factor=16.0)
    p = moe_init(jax.random.key(seed), cfg)
    x = jax.random.normal(jax.random.key(seed + 1), (b, s, cfg.d_model))
    y1, a1 = moe_block(p, x, cfg)
    y2, a2 = moe_block_dense(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(float(a1), float(a2), rtol=1e-5)


@given(seed=st.integers(0, 100), steps=st.integers(1, 500))
@FAST
def test_pchase_kernel_walk_property(seed, steps):
    from repro.core.pchase import single_cycle_permutation

    perm = single_cycle_permutation(128, seed)
    got = int(api.pchase(jnp.asarray(perm), steps)[0, 0])
    assert got == ref.pchase_ref(perm, steps)
