"""Numerics guard: shadow-oracle verification, sentinels, per-op degradation.

The acceptance contract from docs/robustness.md#numerics-guard drives these
tests: clean kernels never trip the guard, injected drift always does, the
int8 saturation sentinel fires on genuinely saturating inputs, a tripped op
quarantines to the oracle and revives through the breaker's half-open probe,
and a guarded serving engine survives op-targeted chaos with token-exact
output and zero whole-engine degradations.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # the fuzzing variants need hypothesis; everything else runs without
    from hypothesis import given, settings, strategies as st
    FAST = settings(max_examples=10, deadline=None)
except ImportError:
    given = None

from repro.configs import get_config
from repro.kernels import api, guard
from repro.kernels.api import kernel_policy
from repro.models import build_model
from repro.serve import EngineConfig, Fault, FaultInjector, FaultPlan, ServeEngine


@pytest.fixture(autouse=True)
def _fresh_guard():
    """Every test runs on a fresh, isolated guard state (injections and
    breaker trips cannot leak across tests or into the process global)."""
    with guard.isolated():
        yield


@pytest.fixture(scope="module")
def gemma():
    cfg = get_config("gemma-2b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _pair(m, k, n, seed=0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    return (jnp.asarray(rng.standard_normal((m, k)), dtype),
            jnp.asarray(rng.standard_normal((k, n)), dtype))


# ---------------------------------------------------------------------------
# tolerance ladder
# ---------------------------------------------------------------------------
def test_tolerance_resolves_through_hw_ladder():
    # T4 publishes fp16 but not bf16: bf16 results are judged at fp16 ulps
    t = guard.tolerance(jnp.bfloat16)
    assert t.resolved == "float16" and not t.exact
    assert t.rtol == pytest.approx(32 * 2.0**-10)
    t32 = guard.tolerance(np.float32)
    assert t32.resolved == "float32"
    assert t32.rtol == pytest.approx(256 * 2.0**-23)
    # tighter precisions get tighter budgets, monotonically
    assert t32.rtol < t.rtol


def test_tolerance_integer_dtypes_are_exact():
    for dt in (np.int8, np.int32, np.uint8, np.bool_):
        t = guard.tolerance(dt)
        assert t.exact and t.rtol == 0.0 and t.atol == 0.0


def test_compare_exact_and_tolerant_paths():
    t = guard.tolerance(np.int32)
    a = np.arange(6, dtype=np.int32)
    assert guard.compare(a, a.copy(), t).ok
    b = a.copy()
    b[3] += 1
    rep = guard.compare(b, a, t)
    assert not rep.ok and rep.max_abs == 1.0
    tf = guard.tolerance(np.float32)
    x = np.linspace(-2, 2, 64, dtype=np.float32)
    assert guard.compare(x, x + 1e-7, tf).ok
    assert not guard.compare(x, x + 1.0, tf).ok


def test_compare_finiteness_mismatch_is_drift():
    tf = guard.tolerance(np.float32)
    x = np.ones(8, np.float32)
    y = x.copy()
    y[0] = np.nan
    rep = guard.compare(y, x, tf)
    assert not rep.ok and rep.max_ulp == float("inf")


def test_trees_match_reports_worst_leaf():
    ok, detail = guard.trees_match({"a": jnp.ones(4)}, {"a": jnp.ones(4)})
    assert ok and detail == ""
    ok, detail = guard.trees_match(
        {"a": jnp.ones(4), "b": jnp.zeros(3)},
        {"a": jnp.ones(4), "b": jnp.full(3, 9.0)},
    )
    assert not ok and "leaf[1]" in detail
    ok, detail = guard.trees_match((jnp.ones(2),), (jnp.ones(2), jnp.ones(2)))
    assert not ok and "structure" in detail


def test_guard_config_validation():
    with pytest.raises(ValueError, match="sample_stride"):
        guard.GuardConfig(sample_stride=0)
    with pytest.raises(ValueError, match="on_drift"):
        guard.GuardConfig(on_drift="explode")
    with pytest.raises(ValueError, match="cooldown"):
        guard.GuardConfig(cooldown=0)
    with pytest.raises(ValueError, match="saturation_threshold"):
        guard.GuardConfig(saturation_threshold=1.5)


# ---------------------------------------------------------------------------
# property: clean runs never trip, injected drift always trips
# ---------------------------------------------------------------------------
def _check_clean_matmul(m, k, n, seed):
    with guard.isolated():
        a, b = _pair(m, k, n, seed)
        with kernel_policy(guard="shadow"):
            out = api.matmul(a, b)
        assert out.shape == (m, n)
        gm = guard.metrics()
        assert gm.checks >= 1 and gm.drift_events == 0
        assert not guard.quarantined_ops()


def _check_injected_drift_trips(scale, seed):
    with guard.isolated():
        guard.inject_drift("matmul", scale=scale, seed=seed)
        a, b = _pair(16, 32, 16, seed)
        with kernel_policy(guard="shadow"):
            with pytest.raises(guard.KernelDriftError) as ei:
                api.matmul(a, b)
        assert ei.value.op == "matmul"
        assert guard.is_quarantined("matmul")
        assert guard.metrics().drift_events == 1


@pytest.mark.parametrize("m,k,n,seed", [
    (16, 16, 16, 0), (16, 64, 32, 1), (32, 32, 16, 2), (32, 16, 32, 3),
])
def test_clean_matmul_never_trips_shadow_guard(m, k, n, seed):
    _check_clean_matmul(m, k, n, seed)


@pytest.mark.parametrize("scale,seed", [
    (0.01, 0), (0.1, 1), (0.5, 2), (1.0, 3),
])
def test_injected_drift_always_trips_shadow_guard(scale, seed):
    _check_injected_drift_trips(scale, seed)


if given is not None:  # hypothesis fuzzing over the same invariants

    @given(m=st.sampled_from((16, 32)), k=st.sampled_from((16, 32, 64)),
           n=st.sampled_from((16, 32)), seed=st.integers(0, 1000))
    @FAST
    def test_clean_matmul_never_trips_shadow_guard_fuzz(m, k, n, seed):
        _check_clean_matmul(m, k, n, seed)

    @given(scale=st.floats(0.01, 1.0), seed=st.integers(0, 1000))
    @FAST
    def test_injected_drift_always_trips_shadow_guard_fuzz(scale, seed):
        _check_injected_drift_trips(scale, seed)


def test_drift_error_carries_report():
    guard.inject_drift("matmul", scale=0.5)
    a, b = _pair(16, 16, 16)
    with kernel_policy(guard="shadow"):
        with pytest.raises(guard.KernelDriftError) as ei:
            api.matmul(a, b)
    rep = ei.value.report
    assert rep.shapes == ((16, 16),) and rep.dtype == "float32"
    assert rep.max_ulp > rep.tol.ulps


def test_sample_mode_checks_on_a_deterministic_stride():
    guard.configure(sample_stride=4, seed=0)
    a, b = _pair(16, 16, 16)
    with kernel_policy(guard="sample"):
        for _ in range(8):
            api.matmul(a, b)
    # calls 0 and 4 of the op are the checked ones: (n + seed) % stride == 0
    assert guard.metrics().checks == 2


def test_sample_mode_misses_drift_between_strides_then_catches_it():
    guard.configure(sample_stride=4, seed=0, on_drift="oracle")
    a, b = _pair(16, 16, 16)
    with kernel_policy(guard="sample"):
        api.matmul(a, b)  # call 0: checked, clean
        guard.inject_drift("matmul", scale=0.5)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            for _ in range(4):  # calls 1-3 unchecked; call 4 catches it
                api.matmul(a, b)
    assert guard.metrics().drift_events == 1
    assert guard.is_quarantined("matmul")


# ---------------------------------------------------------------------------
# saturation sentinels
# ---------------------------------------------------------------------------
def test_int8_saturation_sentinel_fires():
    a = jnp.full((16, 16), 64, jnp.int8)
    with kernel_policy(guard="shadow"):
        with pytest.raises(guard.SaturationError) as ei:
            api.matmul(a, a, out_dtype=jnp.int8)
    assert ei.value.op == "matmul" and ei.value.fraction == 1.0
    # saturation is a property of the inputs, not the backend: the oracle
    # would saturate identically, so the breaker must NOT trip
    assert not guard.is_quarantined("matmul")
    assert guard.metrics().saturation_events == 1


def test_small_int8_matmul_passes_sentinel_and_oracle():
    a = jnp.ones((16, 16), jnp.int8)
    with kernel_policy(guard="shadow"):
        out = api.matmul(a, a, out_dtype=jnp.int32)
    np.testing.assert_array_equal(np.asarray(out), np.full((16, 16), 16))
    gm = guard.metrics()
    assert gm.sentinel_checks >= 1 and gm.saturation_events == 0


def test_sentinels_can_be_disabled():
    guard.configure(sentinels=False)
    a = jnp.full((16, 16), 64, jnp.int8)
    with kernel_policy(guard="shadow"):
        api.matmul(a, a, out_dtype=jnp.int8)  # would raise with sentinels on
    assert guard.metrics().saturation_events == 0


# ---------------------------------------------------------------------------
# breaker: quarantine, cooldown, half-open revival
# ---------------------------------------------------------------------------
def test_breaker_quarantines_then_revives_through_half_open():
    guard.configure(cooldown=3, probe_checks=2, on_drift="oracle")
    a, b = _pair(16, 16, 16)
    with kernel_policy(guard="shadow"):
        guard.inject_drift("matmul", scale=0.5)
        with pytest.warns(RuntimeWarning, match="drift"):
            api.matmul(a, b)  # trip
        assert guard.is_quarantined("matmul")
        guard.clear_drift("matmul")
        ref = np.asarray(api.matmul(a, b))  # served by the oracle while open
        assert guard.metrics().degraded_calls >= 1
        for _ in range(8):  # cooldown elapses -> half-open -> 2 clean probes
            out = api.matmul(a, b)
        assert not guard.is_quarantined("matmul")
        np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-5)
    gm = guard.metrics()
    assert gm.quarantines == 1 and gm.half_opens >= 1 and gm.revivals == 1


def test_reopened_breaker_doubles_its_cooldown():
    guard.configure(cooldown=4, max_cooldown_doublings=4)
    s = guard.state()
    assert s._cooldown_ticks(guard.OpBreaker(fail_count=1)) == 4
    assert s._cooldown_ticks(guard.OpBreaker(fail_count=3)) == 16
    assert s._cooldown_ticks(guard.OpBreaker(fail_count=99)) == 64  # capped


def test_probe_and_attribution_target_the_faulty_op_only():
    assert guard.probe("matmul") and guard.probe("axpy")
    guard.inject_fault("axpy")
    assert not guard.probe("axpy")
    bad = guard.attribute()
    assert bad == ["axpy"]
    assert guard.is_quarantined("axpy") and not guard.is_quarantined("matmul")
    # already-quarantined ops are skipped: attribution converges
    assert guard.attribute() == []
    guard.clear_fault("axpy")
    assert guard.probe("axpy")
    guard.revive("axpy")
    assert not guard.is_quarantined("axpy")


def test_verify_ops_sweep_is_clean_without_injections():
    reports = guard.verify_ops()
    assert reports and all(r.ok for r in reports.values())


# ---------------------------------------------------------------------------
# policy scoping
# ---------------------------------------------------------------------------
def test_policy_guard_nests_inherits_and_restores():
    from repro.kernels.api import current_policy

    assert current_policy().guard is None
    with kernel_policy(guard="shadow"):
        assert current_policy().guard == "shadow"
        with kernel_policy(autotune="heuristic"):  # inherits the guard
            assert current_policy().guard == "shadow"
        with kernel_policy(guard="off"):  # explicit override
            assert current_policy().guard == "off"
        assert current_policy().guard == "shadow"
        with pytest.raises(RuntimeError, match="boom"):
            with kernel_policy(guard="off"):
                raise RuntimeError("boom")
        assert current_policy().guard == "shadow"  # restored past the raise
    assert current_policy().guard is None
    with pytest.raises(ValueError, match="guard"):
        with kernel_policy(guard="paranoid"):
            pass


def test_guard_off_mode_skips_all_machinery():
    a, b = _pair(16, 16, 16)
    guard.inject_drift("matmul", scale=0.5)
    with kernel_policy(guard="off"):
        api.matmul(a, b)  # drift not even injected: bound() path
    assert guard.metrics().checks == 0 and guard.metrics().drift_events == 0


# ---------------------------------------------------------------------------
# fault-plan surface
# ---------------------------------------------------------------------------
def test_kernel_drift_fault_validation_and_defaults():
    f = Fault(tick=0, kind="kernel_drift")
    assert f.op == "matmul" and f.drift_scale > 0
    with pytest.raises(ValueError, match="drift_scale"):
        Fault(tick=0, kind="kernel_drift", drift_scale=0.0)
    # random plans must never draw undetectable drift (guard-off engines
    # would silently corrupt tokens): kernel_drift is opt-in only
    plan = FaultPlan.random(3, n_ticks=32, n_faults=12)
    assert all(f.kind != "kernel_drift" for f in plan.faults)


# ---------------------------------------------------------------------------
# the acceptance criterion: guarded engine under op-targeted chaos
# ---------------------------------------------------------------------------
def _guard_plan():
    return FaultPlan(seed=42, faults=(
        Fault(tick=2, kind="kernel_drift", replica=0, duration=2,
              op="matmul", drift_scale=0.25),
        Fault(tick=6, kind="kernel_fault", replica=0, op="flash_attention"),
    ))


def _prompts(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    return [[int(t) for t in rng.integers(1, cfg.vocab_size, 5)]
            for _ in range(n)]


def _run_engine(model, params, prompts, **cfg_kw):
    engine = ServeEngine(model, params, EngineConfig(
        n_slots=2, max_len=32, prefill_chunk=4, **cfg_kw))
    sessions = [engine.submit(p, 8) for p in prompts]
    return engine, sessions


def test_guarded_engine_clean_run_is_exact_with_zero_drift(gemma):
    cfg, model, params = gemma
    prompts = _prompts(cfg, 2, seed=5)
    ref_engine, ref = _run_engine(model, params, prompts)
    ref_engine.run()
    engine, sessions = _run_engine(model, params, prompts, guard="shadow")
    engine.run()
    assert [s.out for s in sessions] == [s.out for s in ref]
    summ = engine.summary()
    assert summ["guard_checks"] > 0
    assert summ["drift_events"] == 0 and summ["op_degradations"] == 0


def test_guarded_engine_detects_quarantines_heals_token_exact(gemma):
    cfg, model, params = gemma
    prompts = _prompts(cfg, 2, seed=5)
    ref_engine, ref = _run_engine(model, params, prompts)
    ref_engine.run()

    engine, sessions = _run_engine(
        model, params, prompts, guard="shadow", guard_cooldown=2)
    injector = FaultInjector(_guard_plan(), engine)
    with pytest.warns(RuntimeWarning, match="quarantined kernel op"):
        injector.run()

    # token-exact: every drifted/faulted step was repaired from the shadow
    assert all(s.done for s in sessions)
    assert [s.out for s in sessions] == [s.out for s in ref]
    summ = engine.summary()
    # 100% detection: every perturbed step raised a drift event
    assert engine._injected_drift_calls >= 1
    assert summ["drift_events"] == engine._injected_drift_calls
    # exactly the targeted ops were quarantined — and never the whole engine
    assert guard.metrics().quarantined_ops == {"matmul", "flash_attention"}
    assert summ["op_degradations"] == 2 and summ["degradations"] == 0
    assert not engine._degraded
    # both ops heal once their faults expire — the drift-era quarantine
    # already revived mid-run; drive a few more ticks for the late one
    heal = engine.submit(prompts[0], 4)
    engine.run()
    assert heal.done
    summ = engine.summary()
    assert summ["op_revivals"] == 2 and not engine._op_quarantine
    assert not guard.quarantined_ops()


def test_guarded_engine_runs_are_deterministic(gemma):
    cfg, model, params = gemma
    prompts = _prompts(cfg, 2, seed=5)

    def one_run():
        with guard.isolated():
            engine, sessions = _run_engine(
                model, params, prompts, guard="shadow", guard_cooldown=2)
            with warnings.catch_warnings():
                warnings.simplefilter("ignore", RuntimeWarning)
                FaultInjector(_guard_plan(), engine).run()
            summ = engine.summary()
            keys = ("drift_events", "op_degradations", "op_revivals",
                    "degradations")
            return [s.out for s in sessions], {k: summ[k] for k in keys}

    outs1, summ1 = one_run()
    outs2, summ2 = one_run()
    assert outs1 == outs2 and summ1 == summ2
