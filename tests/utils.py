"""Test helpers: subprocess runner for multi-device (fake-device) tests."""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap
from pathlib import Path

SRC = str(Path(__file__).resolve().parent.parent / "src")


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 600) -> str:
    """Run ``code`` in a subprocess with n fake CPU devices; returns stdout.

    Raises on non-zero exit (stderr included in the message).
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True,
        text=True,
        env=env,
        timeout=timeout,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={proc.returncode})\nstdout:\n{proc.stdout}\n"
            f"stderr:\n{proc.stderr[-4000:]}"
        )
    return proc.stdout
