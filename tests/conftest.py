# NOTE: deliberately NO unconditional XLA_FLAGS here — smoke tests and
# benches must see the real single CPU device.  Multi-device tests either
# spawn subprocesses that set --xla_force_host_platform_device_count
# themselves (tests/utils.run_with_devices), or are marked `multidevice`
# and run in-process only when REPRO_FORCE_DEVICES is exported (the CI
# multidevice job runs `REPRO_FORCE_DEVICES=8 pytest -m multidevice`).
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Env-guarded fake-device mode: conftest imports run before any test module
# imports jax, so this is early enough for the flag to take effect.
_force = os.environ.get("REPRO_FORCE_DEVICES")
if _force:
    _flag = f"--xla_force_host_platform_device_count={int(_force)}"
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") + " " + _flag).strip()


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "multidevice(n): needs >= n jax devices in-process; skipped when "
        "fewer are visible (export REPRO_FORCE_DEVICES=8 to run)",
    )


def pytest_runtest_setup(item):
    marker = item.get_closest_marker("multidevice")
    if marker is None:
        return
    need = int(marker.args[0]) if marker.args else int(marker.kwargs.get("n", 2))
    import jax
    import pytest

    have = jax.device_count()
    if have < need:
        pytest.skip(
            f"needs {need} devices, have {have} "
            f"(export REPRO_FORCE_DEVICES={need} to force fake host devices)"
        )
