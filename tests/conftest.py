# NOTE: deliberately NO XLA_FLAGS here — smoke tests and benches must see the
# real single CPU device.  Multi-device tests spawn subprocesses that set
# --xla_force_host_platform_device_count themselves (see tests/utils.py).
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
