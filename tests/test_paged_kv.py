"""Paged KV cache: allocator invariants, model-level paged/dense decode
parity, engine-level token parity across page sizes, shared-prefix
copy-on-write forks, and pool exhaustion -> clean recompute preemption."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serve import (
    EngineConfig,
    PageAllocator,
    PagePoolExhausted,
    ServeEngine,
)


@pytest.fixture(scope="module")
def gemma():
    cfg = get_config("gemma-2b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _prompts(cfg, n, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [
        [int(t) for t in rng.integers(1, cfg.vocab_size, lens[i % len(lens)])]
        for i in range(n)
    ]


# ---------------------------------------------------------------------------
# host-side allocator
# ---------------------------------------------------------------------------
def test_allocator_alloc_free_roundtrip():
    a = PageAllocator(n_pages=6, page_size=4)
    p = a.alloc(4)
    assert len(p) == len(set(p)) == 4 and a.used == 4 and a.free_pages == 2
    a.free(p[:2])
    assert a.used == 2
    q = a.alloc(3)
    assert set(q).isdisjoint(p[2:])  # live pages are never re-issued
    a.free(p[2:] + q)
    assert a.used == 0 and a.free_pages == 6


def test_allocator_exhaustion_is_all_or_nothing():
    a = PageAllocator(n_pages=3, page_size=2)
    a.alloc(2)
    with pytest.raises(PagePoolExhausted):
        a.alloc(2)
    assert a.free_pages == 1  # the failed alloc claimed nothing


def test_allocator_refcount_share_free():
    a = PageAllocator(n_pages=4, page_size=2)
    p = a.alloc(2)
    a.share(p)
    assert a.is_shared(p[0]) and a.refcount(p[1]) == 2
    a.free(p)  # first owner out: pages still held
    assert a.used == 2 and not a.is_shared(p[0])
    a.free(p)  # second owner out: pages return
    assert a.used == 0
    with pytest.raises(ValueError):
        a.free(p)  # double free


def test_allocator_share_unallocated_rejected():
    a = PageAllocator(n_pages=2, page_size=2)
    with pytest.raises(ValueError):
        a.share([0])


def test_allocator_pages_for():
    a = PageAllocator(n_pages=8, page_size=4)
    assert [a.pages_for(n) for n in (1, 4, 5, 8, 9)] == [1, 1, 2, 2, 3]


# ---------------------------------------------------------------------------
# model level: gathered pages reproduce the dense cache exactly
# ---------------------------------------------------------------------------
def test_paged_decode_matches_dense_permuted_pages(gemma):
    """decode_step/chunk through an arbitrarily permuted page table produce
    the same logits as the contiguous dense cache for the same tokens."""
    cfg, model, params = gemma
    ps, T = 4, 3  # page_size 4, 3 pages per lane -> logical max 12
    max_len = T * ps
    bt = np.array([[5, 2, 7], [1, 6, 3]], np.int32)  # 8-page pool, permuted
    lens = [7, 5]
    toks = _prompts(cfg, 2, lens, seed=1)

    dense = model.init_cache(2, max_len)
    paged = model.init_paged_cache(8, ps)
    for t in range(max(lens)):
        tk = np.array(
            [p[t] if t < len(p) else 0 for p in toks], np.int32
        )
        pos_d = np.array(
            [t if t < len(p) else max_len for p in toks], np.int32
        )
        pos_p = np.array(
            [t if t < len(p) else T * ps for p in toks], np.int32
        )
        want, dense = model.decode_step(
            params, dense, jnp.asarray(tk), jnp.asarray(pos_d)
        )
        got, paged = model.decode_step_paged(
            params, paged, jnp.asarray(bt), jnp.asarray(tk), jnp.asarray(pos_p)
        )
        for b in range(2):
            if t < lens[b]:
                np.testing.assert_allclose(
                    np.asarray(got[b]), np.asarray(want[b]), rtol=2e-4, atol=2e-4
                )


def test_paged_chunk_matches_dense(gemma):
    cfg, model, params = gemma
    ps, T = 4, 2
    bt = np.array([[3, 0], [2, 1]], np.int32)
    lens = [6, 4]
    toks = _prompts(cfg, 2, lens, seed=2)
    C = max(lens)
    tk = np.zeros((2, C), np.int32)
    pos_d = np.full((2, C), T * ps, np.int32)
    pos_p = np.full((2, C), T * ps, np.int32)
    for b, p in enumerate(toks):
        tk[b, : len(p)] = p
        pos_d[b, : len(p)] = np.arange(len(p))
        pos_p[b, : len(p)] = np.arange(len(p))
    dense = model.init_cache(2, T * ps)
    paged = model.init_paged_cache(4, ps)
    want, _ = model.decode_chunk(
        params, dense, jnp.asarray(tk), jnp.asarray(pos_d)
    )
    got, _ = model.decode_chunk_paged(
        params, paged, jnp.asarray(bt), jnp.asarray(tk), jnp.asarray(pos_p)
    )
    for b, n in enumerate(lens):
        np.testing.assert_allclose(
            np.asarray(got[b, :n]), np.asarray(want[b, :n]), rtol=2e-4, atol=2e-4
        )


def test_pad_sentinel_writes_nothing(gemma):
    """A lane at the pad position must not touch the pool — otherwise an
    idle lane would scribble over pages another lane owns via block-table
    row zeros."""
    cfg, model, params = gemma
    ps = 4
    bt = np.zeros((2, 2), np.int32)  # both rows point at page 0
    pos = np.array([0, 2 * ps], np.int32)  # lane 1 is pad

    def pool_after(lane1_token):
        tk = np.array([7, lane1_token], np.int32)
        _, pool = model.decode_step_paged(
            params, model.init_paged_cache(2, ps), jnp.asarray(bt),
            jnp.asarray(tk), jnp.asarray(pos),
        )
        return pool

    # pad lane contributes nothing: pool identical whatever token it carries
    for leaf_a, leaf_b in zip(
        jax.tree.leaves(pool_after(9)), jax.tree.leaves(pool_after(123))
    ):
        np.testing.assert_array_equal(np.asarray(leaf_a), np.asarray(leaf_b))


# ---------------------------------------------------------------------------
# engine level: paged == dense, token for token
# ---------------------------------------------------------------------------
def _run_engine(model, params, prompts, *, page_size=None, n_pages=None,
                n_slots=3, max_len=24, max_new=8):
    eng = ServeEngine(
        model, params,
        EngineConfig(n_slots=n_slots, max_len=max_len, prefill_chunk=4,
                     page_size=page_size, n_pages=n_pages),
    )
    sessions = [eng.submit(p, max_new_tokens=max_new) for p in prompts]
    eng.run()
    assert all(s.done for s in sessions)
    return eng, [s.out for s in sessions]


@pytest.mark.parametrize("page_size", [4, 8])
def test_engine_paged_matches_dense(gemma, page_size):
    """Same requests through a dense and a paged engine produce identical
    token streams (greedy decode is deterministic)."""
    cfg, model, params = gemma
    prompts = _prompts(cfg, 6, [5, 9, 3, 7, 11, 4], seed=0)
    _, dense = _run_engine(model, params, prompts)
    eng, paged = _run_engine(model, params, prompts, page_size=page_size)
    assert paged == dense
    assert eng.allocator.used == 0  # all pages returned at drain
    assert eng.summary()["pages_peak"] > 0


def test_engine_page_exhaustion_preempts_cleanly(gemma):
    """A pool too small for all lanes forces preemption; evicted sessions
    resume exactly (same tokens as an unconstrained run) and every page is
    freed at drain — exhaustion degrades throughput, never correctness."""
    cfg, model, params = gemma
    prompts = _prompts(cfg, 6, [5, 9, 3, 7, 11, 4], seed=0)
    _, dense = _run_engine(model, params, prompts)
    eng, tight = _run_engine(model, params, prompts, page_size=4, n_pages=8)
    assert tight == dense
    assert eng.summary()["preemptions"] > 0
    assert eng.allocator.used == 0
    assert any(s.stats.preemptions > 0 for s in eng.finished)


def test_engine_shared_prefix_fork_identical(gemma):
    """Forked continuations are bit-identical to full-prefill runs, prefill
    work drops by the reused tokens, and prefix pages stay resident (the
    registry's reference) while per-session pages are freed."""
    cfg, model, params = gemma
    rng = np.random.default_rng(7)
    pfx = [int(t) for t in rng.integers(1, cfg.vocab_size, 6)]
    tails = _prompts(cfg, 4, [4, 2, 5, 3], seed=8)
    prompts = [pfx + t for t in tails]

    _, plain = _run_engine(model, params, prompts, page_size=4)

    eng = ServeEngine(
        model, params,
        EngineConfig(n_slots=3, max_len=24, prefill_chunk=4, page_size=4),
    )
    prefix = eng.register_prefix(pfx)
    sessions = [eng.submit(p, max_new_tokens=8) for p in prompts]
    eng.run()
    assert [s.out for s in sessions] == plain
    s = eng.summary()
    assert s["prefix_hits"] == len(prompts)
    assert s["prefix_tokens_reused"] > 0
    assert prefix.hits == len(prompts)
    # only the registry's prefix pages remain resident after drain
    assert eng.allocator.used == len(prefix.pages)
    eng.unregister_prefix(pfx)
    assert eng.allocator.used == 0


def test_engine_shared_prefix_saves_prefill(gemma):
    cfg, model, params = gemma
    rng = np.random.default_rng(9)
    pfx = [int(t) for t in rng.integers(1, cfg.vocab_size, 8)]
    prompts = [pfx + t for t in _prompts(cfg, 4, [3, 4], seed=10)]

    def drive(register):
        eng = ServeEngine(
            model, params,
            EngineConfig(n_slots=2, max_len=32, prefill_chunk=4, page_size=4),
        )
        if register:
            eng.register_prefix(pfx)
        for p in prompts:
            eng.submit(p, max_new_tokens=6)
        eng.run()
        return eng.summary()

    base, forked = drive(False), drive(True)
    assert forked["prefill_tokens"] < base["prefill_tokens"]


def test_engine_prompt_longer_than_prefix_page_boundary(gemma):
    """CoW boundary case: reuse not page-aligned — the fork copies the
    boundary page and continues inside it without corrupting the registered
    prefix for later forks."""
    cfg, model, params = gemma
    rng = np.random.default_rng(11)
    pfx = [int(t) for t in rng.integers(1, cfg.vocab_size, 6)]  # 1.5 pages @ 4
    prompts = [pfx + t for t in _prompts(cfg, 3, [3, 5, 2], seed=12)]
    _, plain = _run_engine(model, params, prompts, page_size=4, n_slots=2)
    eng = ServeEngine(
        model, params,
        EngineConfig(n_slots=2, max_len=24, prefill_chunk=4, page_size=4),
    )
    eng.register_prefix(pfx)
    sessions = [eng.submit(p, max_new_tokens=8) for p in prompts]
    eng.run()
    assert [s.out for s in sessions] == plain


def test_engine_config_validation():
    with pytest.raises(ValueError, match="page_size"):
        EngineConfig(n_slots=2, max_len=16, page_size=0)
    with pytest.raises(ValueError, match="requires page_size"):
        EngineConfig(n_slots=2, max_len=16, n_pages=8)
    with pytest.raises(ValueError, match="worst-case lane"):
        EngineConfig(n_slots=2, max_len=16, page_size=4, n_pages=3)
    assert EngineConfig(n_slots=2, max_len=16, page_size=4).table_width == 4


def test_register_prefix_requires_paged(gemma):
    cfg, model, params = gemma
    eng = ServeEngine(model, params, EngineConfig(n_slots=2, max_len=16))
    with pytest.raises(ValueError, match="paged"):
        eng.register_prefix([1, 2, 3])


def test_register_prefix_keeps_lane_headroom(gemma):
    """A prefix that would starve the pool (no room left for one worst-case
    lane) is rejected up front rather than deadlocking admission."""
    cfg, model, params = gemma
    eng = ServeEngine(
        model, params,
        EngineConfig(n_slots=2, max_len=16, page_size=4, n_pages=4),
    )
    with pytest.raises(PagePoolExhausted):
        eng.register_prefix(list(range(1, 9)))  # 2 pages, leaves 2 < 4 headroom
    assert eng.allocator.used == 0
