"""Core microbenchmark engine: hwmodel, dissect, autotune, throttle-vs-paper."""
import json

import pytest

from repro.core import TPU_V5E, T4_PAPER, HardwareModel
from repro.core.autotune import choose_attention_chunk, choose_matmul_tiles
from repro.core.dissect import dissect_model
from repro.core.throttle import T4_THROTTLE, simulate


def test_hwmodel_json_roundtrip():
    s = TPU_V5E.to_json()
    back = HardwareModel.from_json(s)
    assert back.peak("bfloat16") == TPU_V5E.peak("bfloat16")
    assert back.levels[1].name == "vmem"


def test_t4_preset_matches_paper_table():
    """The T4 preset encodes the paper's published Table 3.1/4.3 numbers."""
    assert T4_PAPER.power_limit_w == 70.0
    assert T4_PAPER.max_temp_c == 85.0
    assert T4_PAPER.num_cores == 40
    assert abs(T4_PAPER.peak("float16") - 41.616e12) < 1e9
    l1, l2, glob = T4_PAPER.levels
    # 32-cycle L1 / 188-cycle L2 / 616-cycle global at 1.59 GHz (Fig 3.5)
    assert abs(l1.latency_ns - 32 / 1.59) < 0.1
    assert abs(l2.latency_ns - 188 / 1.59) < 0.5
    assert l2.size_bytes == 4096 * 1024
    assert abs(T4_PAPER.main_memory_Bps - 220e9) < 1e9  # 68.8% of 320 GB/s


def test_throttle_reproduces_paper_fig43_44():
    """Validation vs the paper's claims: T4 holds max clock only briefly,
    power-throttles to a plateau, then thermal-throttles harder at 85C."""
    out = simulate(T4_THROTTLE, utilization=1.0, duration_s=300, dt=0.5)
    clock, temp = out["clock_hz"], out["temp_c"]
    assert clock[0] == pytest.approx(1.59e9, rel=0.01)
    # clock decays within the first ~10s (power limit, Fig 4.3)
    assert clock[20] < 1.45e9
    # temperature reaches the 85C operating limit (Fig 4.4)
    assert temp.max() >= 84.0
    # thermal step-down: final clock below the pure-power-limited level
    f_power = (70.0 - 20.0) / T4_THROTTLE.watts_per_hz
    assert clock[-1] < f_power
    # steady-state power respects the 70W envelope
    assert out["power_w"][-40:].mean() <= 71.0


def test_dissect_model_mode_writes_report(tmp_path):
    p = tmp_path / "report.json"
    rep = dissect_model(out_path=str(p))
    data = json.loads(p.read_text())
    assert data["mode"] == "model"
    assert data["hardware"]["name"] == "tpu-v5e"
    pc = data["probes"]["pointer_chase"]
    # latency must be monotone nondecreasing with footprint in the model
    assert list(pc["y"]) == sorted(pc["y"])
    mm = data["probes"]["matmul_throughput"]
    assert max(mm["y"]) <= TPU_V5E.peak("bfloat16") / 1e9 * 1.001


def test_autotune_matmul_respects_vmem_and_alignment():
    c = choose_matmul_tiles(4096, 4096, 4096, "bfloat16")
    assert c.vmem_bytes <= TPU_V5E.staging_bytes * 0.8
    for b in (c.bm, c.bk, c.bn):
        assert b % 128 == 0
    # bigger tiles should be preferred over minimum (traffic model)
    assert max(c.bm, c.bn) > 128


def test_autotune_prefers_wide_over_misaligned():
    from repro.core.autotune import matmul_time_model

    t_aligned, _ = matmul_time_model(4096, 4096, 4096, 256, 256, 256, "bfloat16", TPU_V5E)
    t_misaligned, _ = matmul_time_model(4096, 4096, 4096, 96, 96, 96, "bfloat16", TPU_V5E)
    assert t_aligned < t_misaligned


def test_autotune_attention_chunk_scales_with_vmem():
    small = choose_attention_chunk(32768, 128, n_heads_local=64)
    big = choose_attention_chunk(32768, 128, n_heads_local=1)
    assert big >= small
