"""Config registry: all 10 assigned archs present with the assigned geometry."""
import pytest

from repro.configs import CONFIGS, SHAPES, get_config, runnable_cells

ASSIGNED = {
    "olmoe-1b-7b": dict(n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
                        d_ff=1024, vocab_size=50304, n_experts=64, experts_per_token=8),
    "dbrx-132b": dict(n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8,
                      d_ff=10752, vocab_size=100352, n_experts=16, experts_per_token=4),
    "xlstm-1.3b": dict(n_layers=48, d_model=2048, n_heads=4, d_ff=0, vocab_size=50304),
    "whisper-base": dict(d_model=512, n_heads=8, d_ff=2048, vocab_size=51865,
                         n_enc_layers=6, n_dec_layers=6),
    "internvl2-76b": dict(n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
                          d_ff=28672, vocab_size=128256),
    "gemma-2b": dict(n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1,
                     d_ff=16384, vocab_size=256000, head_dim=256),
    "qwen2.5-14b": dict(n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
                        d_ff=13824, vocab_size=152064, qkv_bias=True),
    "minitron-8b": dict(n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
                        d_ff=16384, vocab_size=256000),
    "yi-34b": dict(n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
                   d_ff=20480, vocab_size=64000),
    "zamba2-7b": dict(n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
                      d_ff=14336, vocab_size=32000, ssm_state=64),
}


def test_all_archs_registered():
    assert set(CONFIGS) == set(ASSIGNED)


@pytest.mark.parametrize("name", sorted(ASSIGNED))
def test_assigned_geometry(name):
    cfg = get_config(name)
    for field, want in ASSIGNED[name].items():
        assert getattr(cfg, field) == want, (name, field, getattr(cfg, field), want)


def test_shapes_assigned():
    assert SHAPES["train_4k"].seq_len == 4096 and SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].seq_len == 32768 and SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].seq_len == 32768 and SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288 and SHAPES["long_500k"].global_batch == 1


def test_long_context_gating():
    # long_500k runs ONLY for sub-quadratic archs
    runnable = {(c.name, s.name) for c, s in runnable_cells()}
    assert ("xlstm-1.3b", "long_500k") in runnable
    assert ("zamba2-7b", "long_500k") in runnable
    for dense in ("yi-34b", "gemma-2b", "dbrx-132b", "whisper-base", "internvl2-76b"):
        assert (dense, "long_500k") not in runnable
    # 10 archs x 3 universal shapes + 2 long cells = 32 runnable cells
    assert len(runnable) == 32


@pytest.mark.parametrize("name", sorted(ASSIGNED))
def test_param_count_analytic_sane(name):
    """Analytic count within 25% of the advertised size class."""
    sizes = {
        "olmoe-1b-7b": 6.9e9, "dbrx-132b": 132e9,
        # xlstm block here is a structural superset (uniform gated FFN in both
        # block types; see DESIGN.md) -> ~2.0B for the 48L/2048d geometry
        "xlstm-1.3b": 2.0e9,
        "whisper-base": 72e6, "internvl2-76b": 76e9, "gemma-2b": 2.5e9,
        "qwen2.5-14b": 14.7e9, "minitron-8b": 8.3e9, "yi-34b": 34e9,
        "zamba2-7b": 7.3e9,
    }
    n = get_config(name).param_count()
    assert 0.6 * sizes[name] <= n <= 1.5 * sizes[name], (name, n / 1e9)
