"""Per-architecture smoke tests (REQUIRED by the assignment): a REDUCED
same-family config runs one forward/train step on CPU; output shapes and
finiteness asserted.  Full configs are exercised only via the dry-run."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import CONFIGS, get_config
from repro.models import build_model

B, S = 2, 64


def _batch(cfg):
    batch = {
        "tokens": jnp.ones((B, S), jnp.int32) * 3,
        "targets": jnp.ones((B, S), jnp.int32),
    }
    if cfg.family in ("vlm", "encdec"):
        batch["frontend"] = 0.1 * jnp.ones((B, cfg.frontend_len, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("name", sorted(CONFIGS))
def test_forward_and_train_step(name):
    cfg = get_config(name).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg)

    loss, grads = jax.jit(jax.value_and_grad(model.loss_fn))(params, batch)
    assert loss.shape == ()
    assert jnp.isfinite(loss), (name, float(loss))
    gnorm2 = sum(
        jnp.sum(jnp.square(g))
        for g in jax.tree.leaves(grads)
        if jnp.issubdtype(g.dtype, jnp.floating)
    )
    assert jnp.isfinite(gnorm2), name
    assert float(gnorm2) > 0, f"{name}: zero gradient"


@pytest.mark.parametrize("name", sorted(CONFIGS))
def test_prefill_decode_shapes(name):
    cfg = get_config(name).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = {k: v for k, v in _batch(cfg).items() if k != "targets"}

    logits, cache = jax.jit(lambda p, b: model.prefill(p, b, S + 16))(params, batch)
    assert logits.shape == (B, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits).any()), name

    tok = jnp.zeros((B,), jnp.int32)
    pos = jnp.full((B,), S, jnp.int32)
    logits2, cache2 = jax.jit(model.decode_step)(params, cache, tok, pos)
    assert logits2.shape == (B, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits2).any()), name
    # cache tree structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("name", sorted(CONFIGS))
def test_loss_decreases_two_steps(name):
    """A small SGD step on the same batch must reduce loss (learnability)."""
    cfg = get_config(name).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg)
    loss0, grads = jax.jit(jax.value_and_grad(model.loss_fn))(params, batch)
    loss_fn = jax.jit(model.loss_fn)
    for lr in (0.5, 0.1, 0.02):
        params2 = jax.tree.map(lambda p, g: p - lr * g, params, grads)
        loss1 = float(loss_fn(params2, batch))
        if loss1 < float(loss0):
            return
    raise AssertionError((name, float(loss0), loss1))
