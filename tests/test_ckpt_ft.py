"""Checkpointing, fault tolerance, straggler detection, end-to-end resume."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import CheckpointManager
from repro.ft import HeartbeatMonitor, StragglerDetector
from repro.core.throttle import V5E_THROTTLE, slowdown_factor


def _tree():
    return {
        "layer": {"w": jnp.arange(12.0).reshape(3, 4), "b": jnp.ones((4,))},
        "step": jnp.asarray(5, jnp.int32),
    }


def test_ckpt_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    tree = _tree()
    mgr.save(10, tree)
    step, restored = mgr.restore(jax.eval_shape(lambda: tree))
    assert step == 10
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_ckpt_keeps_latest_and_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree())
    assert mgr.steps() == [3, 4]


def test_ckpt_corruption_fallback(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=5)
    mgr.save(1, _tree())
    mgr.save(2, _tree())
    # corrupt the newest checkpoint's arrays
    (tmp_path / "step_000000002" / "arrays.npz").write_bytes(b"garbage")
    step, restored = mgr.restore(jax.eval_shape(_tree))
    assert step == 1 and restored is not None


def test_ckpt_atomicity_tmp_never_visible(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(7, _tree())
    assert not any(p.name.startswith(".tmp") for p in tmp_path.iterdir())


# ---------------------------------------------------------------------------
def test_heartbeat_detects_dead_worker():
    t = [0.0]
    mon = HeartbeatMonitor(timeout_s=10.0, clock=lambda: t[0])
    mon.beat("w0", 1)
    mon.beat("w1", 1)
    t[0] = 5.0
    mon.beat("w0", 2)
    t[0] = 12.0
    assert mon.dead_workers() == ["w1"]
    assert mon.alive_workers() == ["w0"]
    assert mon.min_step() == 1


def test_straggler_detector_flags_throttled_worker():
    det = StragglerDetector(utilization=0.9, min_samples=3)
    sig = det.signature()
    assert sig > 1.05  # the throttle model predicts real inflation
    for _ in range(6):
        for w in ("w0", "w1", "w2", "w3"):
            det.observe(w, 1.0)
        det.observe("slow", sig)  # fully-throttled signature
    flagged = dict(det.stragglers())
    assert "slow" in flagged
    assert det.likely_thermal("slow")
    assert "w0" not in flagged


def test_slowdown_factor_reasonable():
    f = slowdown_factor(V5E_THROTTLE, 0.9)
    assert 1.0 < f < 3.0


# ---------------------------------------------------------------------------
def test_train_resume_exact(tmp_path):
    """Kill training at step 6, resume, verify identical final state vs an
    uninterrupted run (exact fault-tolerant resume)."""
    from repro.configs import get_config
    from repro.data import DataPipeline, SyntheticLM
    from repro.models import build_model
    from repro.optim import AdamW
    from repro.optim.schedule import constant
    from repro.train.loop import FailureInjector, LoopConfig, train_loop
    from repro.train.step import TrainState, make_train_step

    cfg = get_config("qwen2.5-14b").reduced().replace(n_layers=1, d_model=32, d_ff=64,
                                                      n_heads=2, n_kv_heads=2,
                                                      head_dim=16, vocab_size=64)
    model = build_model(cfg)
    opt = AdamW()
    step_fn = jax.jit(make_train_step(model.loss_fn, opt, constant(1e-3)))
    src = SyntheticLM(cfg.vocab_size, 16, 4, seed=0)

    def fresh_state():
        params = model.init(jax.random.key(0))
        return TrainState(params=params, opt=opt.init(params))

    loop_cfg = LoopConfig(total_steps=10, ckpt_every=3)

    # uninterrupted reference
    pipe = DataPipeline(lambda s: src.batch_at(s), prefetch=0)
    ref_state, ref_hist = train_loop(step_fn, fresh_state(), pipe, ckpt=None, cfg=loop_cfg)

    # interrupted run with checkpointing
    ckpt = CheckpointManager(tmp_path / "ft")
    pipe2 = DataPipeline(lambda s: src.batch_at(s), prefetch=0)
    with pytest.raises(RuntimeError, match="injected failure"):
        train_loop(step_fn, fresh_state(), pipe2, ckpt=ckpt, cfg=loop_cfg,
                   injector=FailureInjector(fail_at_step=6))
    # resume (train_loop restores from the latest checkpoint automatically)
    pipe3 = DataPipeline(lambda s: src.batch_at(s), prefetch=0)
    res_state, res_hist = train_loop(step_fn, fresh_state(), pipe3, ckpt=ckpt, cfg=loop_cfg)

    for a, b in zip(jax.tree.leaves(ref_state.params), jax.tree.leaves(res_state.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-6)
    # the resumed run replayed exactly the post-checkpoint steps
    assert res_hist[0]["step"] == 6
    for r_ref, r_res in zip(ref_hist[6:], res_hist):
        assert abs(r_ref["loss"] - r_res["loss"]) < 1e-5
