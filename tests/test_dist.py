"""Distribution layer tests (multi fake devices via subprocess — conftest
deliberately leaves the main pytest process at 1 device)."""
from utils import run_with_devices


def test_sharding_rules_resolve():
    out = run_with_devices(
        """
        import jax
        from repro.configs import get_config
        from repro.launch.mesh import make_test_mesh
        from repro.dist.sharding import param_specs
        from repro.models import build_model

        cfg = get_config("qwen2.5-14b")
        mesh = make_test_mesh()  # (data=2, model=4)
        model = build_model(cfg)
        shapes = jax.eval_shape(lambda: model.init(jax.random.key(0)))
        specs = param_specs(shapes, cfg, mesh)
        import jax.tree_util as jtu
        flat = jtu.tree_flatten_with_path(specs)[0]
        shard_count = 0
        for path, s in flat:
            p = "/".join(str(getattr(q, "key", q)) for q in path)
            if "wq" in p or "wi_gate" in p or "embed" in p:
                assert "model" in str(s.spec), (p, s.spec)
            if "model" in str(s.spec):
                shard_count += 1
        assert shard_count >= 6, shard_count
        print("OK", shard_count)
        """
    )
    assert "OK" in out


def test_zero1_adds_data_axis():
    out = run_with_devices(
        """
        import jax
        from repro.configs import get_config
        from repro.launch.mesh import make_test_mesh
        from repro.dist.sharding import param_specs
        from repro.dist.zero import zero1_state_specs
        from repro.models import build_model

        cfg = get_config("qwen2.5-14b")
        mesh = make_test_mesh()
        model = build_model(cfg)
        shapes = jax.eval_shape(lambda: model.init(jax.random.key(0)))
        pspecs = param_specs(shapes, cfg, mesh)
        zspecs = zero1_state_specs(shapes, pspecs, mesh)
        import jax.tree_util as jtu
        n_data = sum(1 for s in jtu.tree_leaves(zspecs) if "data" in str(s.spec))
        assert n_data > 0, "ZeRO-1 added no data-axis shards"
        print("OK", n_data)
        """
    )
    assert "OK" in out


def test_compressed_psum_close_to_exact():
    out = run_with_devices(
        """
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.launch.mesh import make_test_mesh
        from repro.dist.compress import psum_compressed, quantize_int8, dequantize_int8

        x = jnp.asarray(np.random.default_rng(0).normal(size=(64,)).astype(np.float32))
        q, s = quantize_int8(x)
        rt = dequantize_int8(q, s)
        assert float(jnp.abs(rt - x).max()) <= float(s) * 0.51 + 1e-6

        mesh = make_test_mesh(multi_pod=True)  # (pod=2, data=2, model=2)
        def body(v):
            return psum_compressed(v, "pod", mode="int8")
        f = jax.shard_map(body, mesh=mesh, in_specs=P("pod"), out_specs=P("pod"))
        v = jnp.stack([x, 2 * x])  # pod-sharded rows
        out = f(v)
        expect = 3 * x  # sum across pods
        err = float(jnp.abs(out[0] - expect).max()) / float(jnp.abs(expect).max())
        assert err < 0.02, err
        print("OK", err)
        """
    )
    assert "OK" in out


def test_error_feedback_reduces_bias():
    out = run_with_devices(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.dist.compress import quantize_int8, dequantize_int8

        rng = np.random.default_rng(1)
        g = jnp.asarray(rng.normal(size=(256,)).astype(np.float32)) * 1e-3
        # repeated compression WITHOUT EF accumulates bias; WITH EF it corrects
        err = jnp.zeros_like(g)
        acc_ef, acc_raw = jnp.zeros_like(g), jnp.zeros_like(g)
        for _ in range(50):
            q, s = quantize_int8(g + err)
            rt = dequantize_int8(q, s)
            err = (g + err) - rt
            acc_ef = acc_ef + rt
            q2, s2 = quantize_int8(g)
            acc_raw = acc_raw + dequantize_int8(q2, s2)
        truth = 50 * g
        e_ef = float(jnp.abs(acc_ef - truth).mean())
        e_raw = float(jnp.abs(acc_raw - truth).mean())
        assert e_ef <= e_raw + 1e-9, (e_ef, e_raw)
        print("OK", e_ef, e_raw)
        """
    )
    assert "OK" in out


def test_gpipe_matches_sequential():
    out = run_with_devices(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_test_mesh
        from repro.dist.pipeline import gpipe_apply

        mesh = make_test_mesh(multi_pod=True)  # pod axis = 2 stages
        n_stages, n_micro, mb, d = 2, 4, 3, 8
        rng = np.random.default_rng(0)
        w = jnp.asarray(rng.normal(size=(n_stages, d, d)).astype(np.float32)) * 0.3
        x = jnp.asarray(rng.normal(size=(n_micro, mb, d)).astype(np.float32))

        def stage(params, h):
            return jnp.tanh(h @ params)

        got = gpipe_apply(lambda p, h: stage(p["w"], h), {"w": w}, x, mesh, axis="pod")
        # sequential reference
        want = x
        for s in range(n_stages):
            want = jnp.tanh(want @ w[s])
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)

        # differentiability (GPipe training)
        def loss(w_):
            y = gpipe_apply(lambda p, h: stage(p["w"], h), {"w": w_}, x, mesh, axis="pod")
            return jnp.sum(y ** 2)
        g = jax.grad(loss)(w)
        assert bool(jnp.isfinite(g).all()) and float(jnp.abs(g).max()) > 0
        print("OK")
        """
    )
    assert "OK" in out


def test_small_mesh_dryrun_cell_compiles():
    """Integration: a reduced train cell lowers + compiles on a (2,4) mesh
    with memory/cost/collective extraction — the dry-run path end-to-end."""
    out = run_with_devices(
        """
        import dataclasses, jax
        from repro.configs import get_config, get_shape
        from repro.launch.cell import build_cell, cost_reference
        from repro.launch.mesh import make_test_mesh
        from repro.perfmodel.costs import extract_costs
        from repro.perfmodel.hlo import collective_bytes

        cfg = get_config("olmoe-1b-7b").reduced().replace(vocab_size=512)
        shape = dataclasses.replace(get_shape("train_4k"), seq_len=128, global_batch=8)
        mesh = make_test_mesh()
        cell = build_cell(cfg, shape, mesh)
        compiled = cell.lower().compile()
        costs = extract_costs(compiled)
        coll = collective_bytes(compiled.as_text())
        ref = cost_reference(cfg, shape)
        assert costs.peak_hbm_bytes > 0
        assert coll.per_device_bytes > 0
        assert ref["global_flops"] > costs.flops_per_device  # loop undercount is real
        print("OK", int(coll.per_device_bytes))
        """
    )
    assert "OK" in out


def test_elastic_reshard_across_meshes():
    out = run_with_devices(
        """
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.ckpt import CheckpointManager, load_resharded
        from repro.launch.mesh import make_mesh_for

        tree = {"w": jnp.arange(64.0).reshape(8, 8)}
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d)
            mgr.save(3, tree)
            # resume on a DIFFERENT mesh factorization (elastic shrink 8 -> 4)
            mesh_b = make_mesh_for(4, model_parallel=2)
            sh = {"w": NamedSharding(mesh_b, P("data", "model"))}
            step, restored = load_resharded(mgr, jax.eval_shape(lambda: tree), sh)
            assert step == 3
            np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))
            assert restored["w"].sharding.mesh.shape["model"] == 2
        print("OK")
        """
    )
    assert "OK" in out
