"""Optimizer, schedule, clipping, data pipeline unit tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data import DataPipeline, SyntheticLM
from repro.optim import AdamW, clip_by_global_norm, cosine_with_warmup


def test_adamw_matches_reference_step():
    opt = AdamW(b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0)
    params = {"w": jnp.array([1.0, -2.0, 3.0])}
    grads = {"w": jnp.array([0.1, -0.2, 0.3])}
    state = opt.init(params)
    updates, state = opt.update(grads, state, params, lr=0.01)
    # step 1: mhat = g, vhat = g^2 -> update = -lr * g/(|g|+eps) = -lr*sign(g)
    np.testing.assert_allclose(
        np.asarray(updates["w"]), -0.01 * np.sign([0.1, -0.2, 0.3]), rtol=1e-4
    )
    new = opt.apply(params, updates)
    np.testing.assert_allclose(np.asarray(new["w"]), [0.99, -1.99, 2.99], rtol=1e-5)


def test_adamw_weight_decay_direction():
    opt = AdamW(weight_decay=0.1)
    params = {"w": jnp.array([10.0])}
    grads = {"w": jnp.array([0.0])}
    state = opt.init(params)
    updates, _ = opt.update(grads, state, params, lr=0.1)
    assert float(updates["w"][0]) < 0  # decays toward zero


def test_clip_by_global_norm():
    grads = {"a": jnp.array([3.0]), "b": jnp.array([4.0])}
    clipped, norm = clip_by_global_norm(grads, 1.0)
    np.testing.assert_allclose(float(norm), 5.0, rtol=1e-6)
    total = jnp.sqrt(sum(jnp.sum(g**2) for g in jax.tree.leaves(clipped)))
    np.testing.assert_allclose(float(total), 1.0, rtol=1e-5)
    # under the limit: unchanged
    clipped2, _ = clip_by_global_norm(grads, 100.0)
    np.testing.assert_allclose(np.asarray(clipped2["a"]), [3.0])


def test_schedule_warmup_then_decay():
    lr = cosine_with_warmup(1.0, warmup=10, total=100)
    vals = [float(lr(s)) for s in range(100)]
    assert vals[0] < vals[5] < vals[9]  # warming up
    assert abs(vals[10] - 1.0) < 0.02  # peak
    assert vals[50] < vals[10] and vals[99] < vals[50]  # decaying
    assert vals[99] >= 0.1 - 1e-6  # min_frac floor


# ---------------------------------------------------------------------------
def test_synthetic_data_deterministic():
    src = SyntheticLM(vocab_size=1000, seq_len=16, global_batch=4, seed=3)
    b1, b2 = src.batch_at(7), src.batch_at(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = src.batch_at(8)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    assert b1["tokens"].min() >= 0 and b1["tokens"].max() < 1000


def test_pipeline_order_and_seek():
    src = SyntheticLM(vocab_size=100, seq_len=8, global_batch=2, seed=0)
    pipe = DataPipeline(lambda s: src.batch_at(s), prefetch=2)
    s0, b0 = next(pipe)
    s1, b1 = next(pipe)
    assert (s0, s1) == (0, 1)
    pipe.seek(10)
    s10, b10 = next(pipe)
    assert s10 == 10
    np.testing.assert_array_equal(b10["tokens"], src.batch_at(10)["tokens"])
    pipe.close()


def test_pipeline_no_prefetch_mode():
    src = SyntheticLM(vocab_size=100, seq_len=8, global_batch=2, seed=0)
    pipe = DataPipeline(lambda s: src.batch_at(s), prefetch=0)
    assert next(pipe)[0] == 0 and next(pipe)[0] == 1
