"""Every registered benchmark runs in quick mode through the registry path.

One shared runner invocation (sweeps trimmed via the spec override hook so
the tier-1 suite stays fast); per-benchmark assertions validate that each
suite produced schema-valid, finite records under its own name.
"""
import math

import pytest

from repro.bench import runner, validate_result
from repro.core import registry

# trimmed sweep overrides for the heavy host-measured suites; semantics and
# code paths are identical to the full quick grids
_SMOKE_OVERRIDES = {
    "axpy": {"sizes": (1 << 16,), "widths": (128, 256)},
    "memhier": {"min_pow": 12, "max_pow": 17, "steps": 1 << 12},
    "bandwidth": {"min_pow": 18, "max_pow": 20, "block_footprint": 1 << 20},
    "instr": {"chain": 256},
    "atomics": {"n_updates": 1 << 12, "collisions": (1, 4)},
    "gemm": {"sizes": (128,)},
    "scheduler": {"rows_per_program": 16, "programs": (1, 2)},
    # backend-parameterized variants (keyed by full variant name)
    **{f"bandwidth[{b}]": {"min_pow": 18, "max_pow": 20} for b in ("pallas", "xla")},
    **{f"memhier[{b}]": {"min_pow": 12, "max_pow": 14, "steps": 1 << 10}
       for b in ("pallas", "xla")},
    **{f"scheduler[{b}]": {"rows_per_program": 16, "programs": (1, 2)}
       for b in ("pallas", "xla")},
    **{f"gemm_lp[{b}]": {"sizes": (64,), "dtypes": ("float32", "int8")}
       for b in ("pallas", "xla")},
    **{f"serving[{b}]": {"requests": 2, "prompt_lens": (4,), "out_lens": (3,)}
       for b in ("pallas", "xla")},
    **{f"serving_scaled[{b}]": {"tps": (1,), "replicas": (1, 2), "requests": 2,
                                "prompt_len": 4, "out_len": 3, "page_sizes": (4,)}
       for b in ("pallas", "xla")},
    **{f"serving_chaos[{b}]": {"requests": 2, "prompt_len": 4, "out_len": 3}
       for b in ("pallas", "xla")},
}


@pytest.fixture(scope="module")
def quick_records():
    runner.load_suites()
    out = {}
    for name in registry.names():
        if name == "dissect":
            continue  # dissect re-runs the probe suites; covered in test_core_engine
        out[name] = registry.get(name).run("quick", overrides=_SMOKE_OVERRIDES.get(name))
    return out


def test_all_paper_benchmarks_registered():
    runner.load_suites()
    assert set(registry.names()) >= {
        "axpy", "scheduler", "memhier", "bandwidth", "instr",
        "atomics", "gemm", "throttle", "dissect",
    }
    for spec in registry.specs():
        assert spec.paper_ref, f"{spec.name} missing paper_ref"
        assert spec.params("quick") is not None


def test_runner_select_filters_by_prefix():
    # a bare prefix sweeps up the backend-parameterized variants too —
    # `run gemm` is the paper-style side-by-side comparison
    assert runner.select(["gem"]) == [
        "gemm", "gemm[pallas]", "gemm[xla]", "gemm_lp[pallas]", "gemm_lp[xla]",
    ]
    assert runner.select(["gemm[xla]"]) == ["gemm[xla]"]
    assert runner.select() == registry.names()


@pytest.mark.parametrize(
    "name",
    ["atomics", "axpy", "bandwidth", "gemm", "instr", "memhier", "scheduler", "throttle",
     "bandwidth[pallas]", "bandwidth[xla]", "memhier[pallas]", "memhier[xla]",
     "scheduler[pallas]", "scheduler[xla]", "gemm_lp[pallas]", "gemm_lp[xla]",
     "serving[pallas]", "serving[xla]",
     "serving_scaled[pallas]", "serving_scaled[xla]",
     "serving_chaos[pallas]", "serving_chaos[xla]"],
)
def test_quick_mode_produces_valid_records(quick_records, name):
    recs = quick_records[name]
    assert recs, f"{name}: no records"
    for r in recs:
        assert r.benchmark == name
        assert math.isfinite(r.value), f"{r.name}: non-finite value"
        for k, v in r.metrics.items():
            assert isinstance(v, (int, float)), f"{r.name}.metrics[{k}]"
    assert len({r.name for r in recs}) == len(recs), f"{name}: duplicate record names"


def test_combined_result_is_schema_valid(quick_records):
    from repro.bench import BenchResult, EnvFingerprint

    records = [r for recs in quick_records.values() for r in recs]
    res = BenchResult(mode="quick", env=EnvFingerprint.capture(), records=records)
    validate_result(res.to_dict())
    back = BenchResult.from_json(res.to_json())
    assert back.records == records


def test_checked_in_baselines_load_and_cover_suites():
    from pathlib import Path

    from repro.bench import load_baselines

    d = Path(__file__).resolve().parent.parent / "benchmarks" / "baselines"
    table = load_baselines(d)
    assert table, "no baselines checked in"
    covered = {bench for bench, _ in table.values()}
    assert covered >= {"axpy", "bandwidth", "gemm", "instr", "memhier", "throttle"}


def test_legacy_csv_shim_roundtrip():
    from benchmarks import bench_throttle

    rows = bench_throttle.run(quick=True)
    assert rows and set(rows[0]) == {"name", "us_per_call", "derived"}
    assert any("MHz" in r["derived"] for r in rows)
