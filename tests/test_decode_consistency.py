"""Decode-path correctness: prefill + decode_step must continue the full
forward pass exactly (the KV-cache/recurrent-state bookkeeping oracle)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.models import transformer as tr
from repro.models import xlstm as xl
from repro.models import mamba as mb

B, S = 2, 32


def _logits_at_last(cfg, model, params, toks):
    """Reference: full forward logits at every position."""
    if cfg.family in ("dense", "moe", "vlm"):
        logits, _ = tr.lm_forward(params, toks, cfg)
        return logits
    if cfg.family == "ssm":
        return xl.xlstm_forward(params, toks, cfg)
    if cfg.family == "hybrid":
        return mb.zamba_forward(params, toks, cfg)
    raise ValueError(cfg.family)


@pytest.mark.parametrize(
    "name", ["qwen2.5-14b", "gemma-2b", "olmoe-1b-7b", "xlstm-1.3b", "zamba2-7b"]
)
def test_prefill_then_decode_matches_forward(name):
    cfg = get_config(name).reduced()
    if cfg.family == "moe":
        cfg = cfg.replace(capacity_factor=8.0)  # no token drops -> exactness
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(7), (B, S), 0, cfg.vocab_size)

    full = _logits_at_last(cfg, model, params, toks)
    last, cache = model.prefill(params, {"tokens": toks[:, :-1]}, S + 8)
    np.testing.assert_allclose(
        np.asarray(last), np.asarray(full[:, -2]), rtol=5e-3, atol=5e-3
    )
    lg, _ = model.decode_step(params, cache, toks[:, -1], jnp.full((B,), S - 1, jnp.int32))
    np.testing.assert_allclose(
        np.asarray(lg), np.asarray(full[:, -1]), rtol=5e-3, atol=5e-3
    )


def test_whisper_prefill_decode_consistency():
    cfg = get_config("whisper-base").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    frames = 0.1 * jnp.ones((B, cfg.frontend_len, cfg.d_model), jnp.float32)
    toks = jax.random.randint(jax.random.key(3), (B, S), 0, cfg.vocab_size)

    from repro.models import encdec

    enc_out = encdec.encode(params, frames, cfg)
    full = encdec.decode_train(params, toks, enc_out, cfg)
    last, cache = model.prefill(params, {"frontend": frames, "tokens": toks[:, :-1]}, S + 8)
    np.testing.assert_allclose(
        np.asarray(last), np.asarray(full[:, -2]), rtol=5e-3, atol=5e-3
    )
    lg, _ = model.decode_step(params, cache, toks[:, -1], jnp.full((B,), S - 1, jnp.int32))
    np.testing.assert_allclose(
        np.asarray(lg), np.asarray(full[:, -1]), rtol=5e-3, atol=5e-3
    )


def test_mlstm_chunked_equals_stepwise():
    """Chunked-parallel mLSTM == exact sequential recurrence."""
    cfg = get_config("xlstm-1.3b").reduced()
    h, hd = cfg.n_heads, cfg.d_model // cfg.n_heads
    rng = np.random.default_rng(0)
    s = 24
    q = jnp.asarray(rng.normal(size=(B, s, h, hd)).astype(np.float32)) * hd**-0.5
    k = jnp.asarray(rng.normal(size=(B, s, h, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, s, h, hd)).astype(np.float32))
    i_raw = jnp.asarray(rng.normal(size=(B, s, h)).astype(np.float32))
    log_f = jax.nn.log_sigmoid(jnp.asarray(rng.normal(size=(B, s, h)).astype(np.float32)) + 2.0)

    state = (
        jnp.zeros((B, h, hd, hd), jnp.float32),
        jnp.zeros((B, h, hd), jnp.float32),
        jnp.zeros((B, h), jnp.float32),
    )
    y_chunk, st_chunk = xl.mlstm_chunked(q, k, v, i_raw, log_f, state, chunk=8)

    st = state
    ys = []
    for t in range(s):
        y_t, st = xl.mlstm_step(q[:, t], k[:, t], v[:, t], i_raw[:, t], log_f[:, t], st)
        ys.append(y_t)
    y_seq = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_seq), rtol=2e-4, atol=2e-4)
    for a, b2 in zip(st_chunk[:2], st[:2]):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b2), rtol=2e-4, atol=2e-4)


def test_ssd_chunked_equals_stepwise():
    """Chunked SSD == sequential recurrence (model-level path)."""
    rng = np.random.default_rng(1)
    bsz, s, h, p, n = 2, 32, 3, 8, 4
    u = jnp.asarray(rng.normal(size=(bsz, s, h, p)).astype(np.float32))
    a_log = -jnp.abs(jnp.asarray(rng.normal(size=(bsz, s, h)).astype(np.float32))) * 0.2
    B_ = jnp.asarray(rng.normal(size=(bsz, s, n)).astype(np.float32))
    C_ = jnp.asarray(rng.normal(size=(bsz, s, n)).astype(np.float32))
    h0 = jnp.zeros((bsz, h, p, n), jnp.float32)

    y_chunk, hf = mb.ssd_chunked(u, a_log, B_, C_, h0, chunk=8)

    # sequential reference
    hs = np.zeros((bsz, h, p, n), np.float32)
    ys = np.zeros((bsz, s, h, p), np.float32)
    for t in range(s):
        decay = np.exp(np.asarray(a_log[:, t]))  # (B,H)
        hs = hs * decay[..., None, None] + np.einsum(
            "bhp,bn->bhpn", np.asarray(u[:, t]), np.asarray(B_[:, t])
        )
        ys[:, t] = np.einsum("bhpn,bn->bhp", hs, np.asarray(C_[:, t]))
    np.testing.assert_allclose(np.asarray(y_chunk), ys, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(hf), hs, rtol=2e-4, atol=2e-4)
