"""repro.bench subsystem: registry, schema round-trip, baseline gating."""
import json

import pytest

from repro.bench import (
    BenchRecord,
    BenchResult,
    EnvFingerprint,
    SchemaError,
    compare,
    load_baselines,
    validate_result,
    write_baselines,
)
from repro.bench.schema import SCHEMA_VERSION, better_for_unit, finite
from repro.core import registry


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
def test_register_lookup_and_grids():
    @registry.register(
        "_tmp_bench",
        paper_ref="Tab 9.9",
        quick={"n": 2},
        full={"n": 16},
        tags=("test",),
    )
    def _bench(n=2):
        """one-line description."""
        return [
            BenchRecord(name=f"tmp_{i}", benchmark="_tmp_bench", x=i, value=1.0, unit="us")
            for i in range(n)
        ]

    try:
        spec = registry.get("_tmp_bench")
        assert spec.paper_ref == "Tab 9.9"
        assert spec.description == "one-line description."
        assert spec.params("quick") == {"n": 2} and spec.params("full") == {"n": 16}
        assert "_tmp_bench" in registry.names()
        assert len(spec.run("quick")) == 2
        assert len(spec.run("full")) == 16
        assert len(spec.run("quick", overrides={"n": 3})) == 3
        with pytest.raises(ValueError):
            registry.register("_tmp_bench")(lambda: [])
        with pytest.raises(ValueError):
            spec.params("smoke")
    finally:
        registry.unregister("_tmp_bench")
    with pytest.raises(KeyError):
        registry.get("_tmp_bench")


# ---------------------------------------------------------------------------
# schema
# ---------------------------------------------------------------------------
def _mk_result(records) -> BenchResult:
    return BenchResult(mode="quick", env=EnvFingerprint.capture(), records=records)


def test_better_inference_and_finite():
    assert better_for_unit("ns/op") == "lower"
    assert better_for_unit("GB/s") == "higher"
    assert better_for_unit("levels") == "info"
    assert BenchRecord(name="a", benchmark="b", x=0, value=1.0, unit="us").better == "lower"
    assert finite(float("inf"), 7.0) == 7.0
    assert finite(float("nan")) == 0.0
    assert finite(3.5) == 3.5


def test_schema_roundtrip(tmp_path):
    recs = [
        BenchRecord(
            name="r1", benchmark="b", x=128, value=2.5, unit="GB/s",
            metrics={"us_per_call": 4.0}, info="hello",
        ),
        BenchRecord(
            name="r2", benchmark="b", x="f32", value=9.0, unit="ns/op", measured=False,
        ),
    ]
    res = _mk_result(recs)
    p = tmp_path / "r.json"
    res.save(p)
    back = BenchResult.load(p)
    assert back.schema_version == SCHEMA_VERSION
    assert back.records == recs
    assert back.env == res.env
    assert back.benchmarks() == ["b"]


def test_schema_validation_rejects_bad_docs():
    good = _mk_result(
        [BenchRecord(name="r1", benchmark="b", x=0, value=1.0, unit="us")]
    ).to_dict()
    validate_result(good)

    d = dict(good)
    d.pop("env")
    with pytest.raises(SchemaError, match="missing result keys"):
        validate_result(d)

    d = json.loads(json.dumps(good))
    d["schema_version"] = 999
    with pytest.raises(SchemaError, match="schema_version"):
        validate_result(d)

    d = json.loads(json.dumps(good))
    d["records"].append(dict(d["records"][0]))
    with pytest.raises(SchemaError, match="duplicate record name"):
        validate_result(d)

    d = json.loads(json.dumps(good))
    d["records"][0]["value"] = "fast"
    with pytest.raises(SchemaError, match="numeric"):
        validate_result(d)


# ---------------------------------------------------------------------------
# baseline gating
# ---------------------------------------------------------------------------
def _gate_fixture(tmp_path, lat=100.0, bw=50.0):
    base = _mk_result(
        [
            BenchRecord(name="lat", benchmark="bb", x=0, value=lat, unit="ns/op"),
            BenchRecord(name="bw", benchmark="bb", x=0, value=bw, unit="GB/s"),
            BenchRecord(
                name="model", benchmark="bb", x=0, value=10.0, unit="MHz", measured=False
            ),
            BenchRecord(
                name="note", benchmark="bb", x=0, value=1.0, unit="levels"
            ),  # info: never gated
        ]
    )
    write_baselines(base, tmp_path)
    return load_baselines(tmp_path)


def test_baseline_gate_trips_on_2x_slowdown(tmp_path):
    table = _gate_fixture(tmp_path)
    slow = _mk_result(
        [
            BenchRecord(name="lat", benchmark="bb", x=0, value=200.0, unit="ns/op"),
            BenchRecord(name="bw", benchmark="bb", x=0, value=25.0, unit="GB/s"),
            BenchRecord(
                name="model", benchmark="bb", x=0, value=10.0, unit="MHz", measured=False
            ),
        ]
    )
    report = compare(slow, table)
    assert not report.passed
    assert sorted(d.name for d in report.regressions) == ["bw", "lat"]
    # a 2x slowdown reads as +100% in BOTH unit directions
    assert all(abs(d.regression - 1.0) < 1e-9 for d in report.regressions)


def test_baseline_gate_passes_within_noise(tmp_path):
    table = _gate_fixture(tmp_path)
    noisy = _mk_result(
        [
            BenchRecord(name="lat", benchmark="bb", x=0, value=130.0, unit="ns/op"),
            BenchRecord(name="bw", benchmark="bb", x=0, value=40.0, unit="GB/s"),
            BenchRecord(
                name="model", benchmark="bb", x=0, value=10.1, unit="MHz", measured=False
            ),
            BenchRecord(name="note", benchmark="bb", x=0, value=5.0, unit="levels"),
        ]
    )
    report = compare(noisy, table)
    assert report.passed, report.format()
    assert report.within == 3  # info row not gated


def test_modeled_records_get_tight_threshold(tmp_path):
    table = _gate_fixture(tmp_path)
    drifted = _mk_result(
        [
            BenchRecord(
                name="model", benchmark="bb", x=0, value=9.5, unit="MHz", measured=False
            )
        ]
    )
    report = compare(drifted, table)
    assert [d.name for d in report.regressions] == ["model"]  # ~5% > 2% tight gate
    assert report.missing_records == ["bw", "lat"]


def test_new_records_and_run_errors_reported(tmp_path):
    table = _gate_fixture(tmp_path)
    res = _mk_result(
        [BenchRecord(name="brand_new", benchmark="bb", x=0, value=1.0, unit="us")]
    )
    res.errors["bb"] = "RuntimeError: boom"
    report = compare(res, table)
    assert report.new_records == ["brand_new"]
    assert not report.passed  # run errors fail the gate
    assert "bb" in report.errors


def test_threshold_scale_loosens_gate(tmp_path):
    table = _gate_fixture(tmp_path)
    slow = _mk_result(
        [BenchRecord(name="lat", benchmark="bb", x=0, value=200.0, unit="ns/op")]
    )
    assert not compare(slow, table).passed
    assert compare(slow, table, threshold_scale=2.0).passed
