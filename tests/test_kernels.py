"""Per-kernel shape/dtype sweeps vs. the pure-jnp oracles (interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.pchase import single_cycle_permutation
from repro.kernels import api, ref

RNG = np.random.default_rng(42)


def _arr(shape, dtype=np.float32, scale=1.0):
    return jnp.asarray((RNG.normal(size=shape) * scale).astype(dtype))


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("shape", [(8, 128), (64, 512), (32, 1024), (128, 256)])
@pytest.mark.parametrize("dtype", [np.float32, np.dtype("bfloat16")])
def test_axpy_sweep(shape, dtype):
    x, y = _arr(shape, dtype), _arr(shape, dtype)
    cols = min(shape[1], 512)
    got = api.axpy(x, y, 2.5, block_rows=8, block_cols=cols)
    want = ref.axpy_ref(x, y, 2.5)
    tol = 1e-5 if dtype == np.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


@pytest.mark.parametrize("shape", [(8, 512), (64, 512), (256, 1024)])
def test_stream_copy_reduce(shape):
    x = _arr(shape)
    np.testing.assert_array_equal(np.asarray(api.stream_copy(x)), np.asarray(x))
    np.testing.assert_allclose(
        float(api.stream_reduce(x)[0, 0]), float(ref.reduce_ref(x)[0, 0]), rtol=1e-4
    )


@pytest.mark.parametrize("stride", [1, 2, 4, 8])
def test_strided_reduce(stride):
    x = _arr((256, 128))
    got = float(api.strided_reduce(x, stride=stride)[0, 0])
    want = float(ref.strided_reduce_ref(x, stride)[0, 0])
    np.testing.assert_allclose(got, want, rtol=1e-4)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n", [64, 256, 1024])
@pytest.mark.parametrize("steps", [10, 777])
def test_pchase_sweep(n, steps):
    perm = single_cycle_permutation(n, seed=n)
    got = int(api.pchase(jnp.asarray(perm), steps)[0, 0])
    assert got == ref.pchase_ref(perm, steps)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "mkn", [(128, 128, 128), (256, 384, 128), (300, 200, 100), (512, 256, 512)]
)
@pytest.mark.parametrize("dtype", [np.float32, np.dtype("bfloat16")])
def test_matmul_sweep(mkn, dtype):
    m, k, n = mkn
    a, b = _arr((m, k), dtype, 0.3), _arr((k, n), dtype, 0.3)
    got = api.matmul(a, b)
    want = ref.matmul_ref(a, b)
    tol = 1e-4 if dtype == np.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


# ---------------------------------------------------------------------------
def _flat(x):
    b, s, h, hd = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b * h, s, hd)


@pytest.mark.parametrize("seq", [64, 100, 256])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [np.float32, np.dtype("bfloat16")])
def test_flash_attention_sweep(seq, causal, dtype):
    b, h, hd = 2, 3, 64
    q, k, v = (_arr((b, seq, h, hd), dtype, 0.5) for _ in range(3))
    got = api.flash_attention(q, k, v, causal=causal, bq=64, bk=64)
    want = ref.flash_attention_ref(_flat(q), _flat(k), _flat(v), causal=causal)
    want = want.reshape(b, h, seq, hd).transpose(0, 2, 1, 3)
    tol = 5e-5 if dtype == np.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


def test_flash_attention_cross_lengths():
    q = _arr((1, 48, 2, 32))
    k = _arr((1, 160, 2, 32))
    v = _arr((1, 160, 2, 32))
    got = api.flash_attention(q, k, v, causal=False, bq=16, bk=64)
    want = ref.flash_attention_ref(_flat(q), _flat(k), _flat(v), causal=False)
    want = want.reshape(1, 2, 48, 32).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=5e-5, atol=5e-5)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seq,chunk", [(64, 16), (128, 32), (100, 32)])
def test_ssm_scan_sweep(seq, chunk):
    bsz, h, p, n = 2, 3, 16, 8
    u = _arr((bsz, seq, h, p))
    a = -jnp.abs(_arr((bsz, seq, h))) * 0.2
    B_ = _arr((bsz, seq, n))
    C_ = _arr((bsz, seq, n))
    got = api.ssm_scan(u, a, B_, C_, chunk=chunk)

    def flat(x):
        if x.ndim == 4:
            return x.transpose(0, 2, 1, 3).reshape(bsz * h, seq, -1)
        return x.transpose(0, 2, 1).reshape(bsz * h, seq)

    want = ref.ssm_scan_ref(
        flat(u), flat(a),
        jnp.repeat(B_[:, None], h, 1).reshape(bsz * h, seq, n),
        jnp.repeat(C_[:, None], h, 1).reshape(bsz * h, seq, n),
    ).reshape(bsz, h, seq, p).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)
