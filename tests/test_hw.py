"""repro.hw spec database: registry semantics, per-dtype peak lookups,
JSON round-trips, the paper's Table-4.3 dtype ladder, and the hw=-by-name
contract of every consumer (roofline / dissect / autotune / gemm_lp)."""
import json
import math

import pytest

import repro.hw as hw
from repro.hw import HardwareModel, MemoryLevel, UnknownDtypeError


# ---------------------------------------------------------------------------
# registry: get / aliases / resolve / register
# ---------------------------------------------------------------------------
def test_presets_registered():
    assert set(hw.names()) >= {
        "tpu-v5e", "nvidia-t4-paper", "nvidia-p4", "nvidia-v100",
        "nvidia-a100-sxm", "nvidia-h100-sxm", "nvidia-b200",
    }


def test_get_normalizes_and_aliases():
    t4 = hw.get("nvidia-t4-paper")
    assert hw.get("T4") is t4
    assert hw.get("t4") is t4
    assert hw.get("Tesla T4") is t4  # space -> dash, case-folded
    assert hw.get("tpu_v5e").name == "tpu-v5e"  # underscore -> dash


def test_get_unknown_lists_registered():
    with pytest.raises(KeyError, match="nvidia-t4-paper"):
        hw.get("gtx-9000")


def test_resolve_name_model_and_type_error():
    t4 = hw.get("T4")
    assert hw.resolve("T4") is t4
    assert hw.resolve(t4) is t4
    with pytest.raises(TypeError):
        hw.resolve(42)


def test_register_conflicts_and_unregister():
    part = HardwareModel(
        name="test-part", peak_flops={"float32": 1e12}, clock_hz=1e9,
        num_cores=1, levels=(), main_memory_Bps=1e11, main_memory_bytes=1,
        staging_bytes=1, staging_Bps=1e11,
    )
    hw.register(part, aliases=("tp0",))
    try:
        with pytest.raises(ValueError, match="already registered"):
            hw.register(part)
        with pytest.raises(ValueError, match="already taken"):
            hw.register(
                HardwareModel(
                    name="test-part-2", peak_flops={}, clock_hz=0, num_cores=0,
                    levels=(), main_memory_Bps=0, main_memory_bytes=0,
                    staging_bytes=0, staging_Bps=0,
                ),
                aliases=("tp0",),
            )
        # overwrite=True is the fit_from_probes re-run path
        hw.register(part, overwrite=True)
        assert hw.get("tp0") is part
    finally:
        hw.unregister("test-part")
        hw.unregister("test-part-2")
    with pytest.raises(KeyError):
        hw.get("test-part")
    with pytest.raises(KeyError):
        hw.get("tp0")  # aliases die with the registration


# ---------------------------------------------------------------------------
# per-dtype peaks: lookup, helpful error, fallback chain
# ---------------------------------------------------------------------------
def test_peak_lookup_and_dtypes_order():
    t4 = hw.get("T4")
    assert t4.peak("int8") == pytest.approx(74.934e12)
    assert t4.supports("float16") and not t4.supports("bfloat16")
    ds = t4.dtypes()
    assert ds[0] == "int1"  # fastest first
    assert list(ds) == sorted(ds, key=t4.peak_flops.get, reverse=True)


def test_unknown_dtype_error_lists_available():
    t4 = hw.get("T4")
    with pytest.raises(UnknownDtypeError) as ei:
        t4.peak("bfloat16")
    msg = str(ei.value)
    assert "bfloat16" in msg and "float16" in msg and "fallback" in msg
    # back-compat: callers that caught the old bare KeyError still work
    with pytest.raises(KeyError):
        t4.peak("bfloat16")


def test_peak_fallback_single_and_chain():
    t4 = hw.get("T4")
    assert t4.peak("bfloat16", fallback="float16") == t4.peak("float16")
    # chain: first supported entry wins
    assert t4.peak("tf32", fallback=("bfloat16", "float32")) == t4.peak("float32")
    with pytest.raises(UnknownDtypeError):
        t4.peak("bfloat16", fallback="fp6")  # fallback itself unsupported


def test_level_lookup():
    t4 = hw.get("T4")
    assert t4.level("L2").shared
    with pytest.raises(KeyError, match="L1, L2, global"):
        t4.level("L9")


# ---------------------------------------------------------------------------
# serialization: every registered part round-trips
# ---------------------------------------------------------------------------
def test_every_registered_model_roundtrips_json():
    for part in hw.models():
        back = HardwareModel.from_json(part.to_json())
        assert back == part, part.name
        assert isinstance(back.levels, tuple)
        assert all(isinstance(l, MemoryLevel) for l in back.levels)
        json.loads(part.to_json())  # stays plain JSON


# ---------------------------------------------------------------------------
# query / compare
# ---------------------------------------------------------------------------
def test_query_by_dtype_min_peak_sorted():
    fast = hw.query(dtype="int8", min_peak=500e12)
    names = [p.name for p in fast]
    assert names == ["nvidia-b200", "nvidia-h100-sxm", "nvidia-a100-sxm"]
    # every hit really clears the gate
    assert all(p.peak("int8") >= 500e12 for p in fast)


def test_query_vendor_arch_power_predicate():
    assert [p.name for p in hw.query(arch="turing")] == ["nvidia-t4-paper"]
    nv = hw.query(vendor="NVIDIA")
    assert len(nv) >= 6 and all(p.vendor == "nvidia" for p in nv)
    low_power = hw.query(vendor="nvidia", max_power_w=80.0)
    assert {p.name for p in low_power} == {"nvidia-t4-paper", "nvidia-p4"}
    pre_volta = hw.query(predicate=lambda p: 0 < p.year < 2017)
    assert [p.name for p in pre_volta] == ["nvidia-p4"]


def test_query_min_peak_requires_dtype():
    with pytest.raises(ValueError, match="requires dtype"):
        hw.query(min_peak=1e12)


def test_compare_t4_vs_p4_matches_paper_story():
    c = hw.compare("T4", "P4")
    assert c["a"] == "nvidia-t4-paper" and c["b"] == "nvidia-p4"
    # shared dtypes only, unless pinned
    assert "int4" not in c["peak_ratio"]
    # Turing TensorCore fp16 vs Pascal's crippled fp16: the ~467x headline
    assert c["peak_ratio"]["float16"] == pytest.approx(41.616 / 0.089, rel=1e-3)
    assert c["peak_ratio"]["int8"] > 1.0
    assert c["main_memory_Bps_ratio"] == pytest.approx(220 / 192, rel=1e-3)
    pinned = hw.compare("T4", "P4", dtypes=["float32"])
    assert list(pinned["peak_ratio"]) == ["float32"]


# ---------------------------------------------------------------------------
# paper validation: the T4 Table-4.3 dtype ladder
# ---------------------------------------------------------------------------
def test_t4_ladder_matches_paper_table_4_3():
    t4 = hw.get("T4")
    assert t4.peak("float16") / t4.peak("float32") == pytest.approx(5.80, abs=0.02)
    assert t4.peak("int8") / t4.peak("float32") == pytest.approx(10.45, abs=0.02)
    assert t4.peak("int8") / t4.peak("float16") == pytest.approx(1.80, abs=0.01)
    # sub-byte modes keep climbing (int4 > int8, int1 > int4)
    assert t4.peak("int1") > t4.peak("int4") > t4.peak("int8")


def test_fit_from_probes_registers_queryable_part():
    fitted = hw.fit_from_probes(
        "fit-test-host",
        plateau_levels=[(1.0, 32 * 1024), (10.0, None)],
        stream_Bps=50e9,
        matmul_flops={"float32": 2e12},
    )
    try:
        assert hw.get("fit-test-host") is fitted
        assert fitted.source == "fit_from_probes"
        # re-running a fit must not raise (overwrite semantics)
        hw.fit_from_probes(
            "fit-test-host", plateau_levels=[(1.5, None)], stream_Bps=60e9,
            matmul_flops={"float32": 2.5e12},
        )
        c = hw.compare("fit-test-host", "T4")
        assert c["peak_ratio"]["float32"] == pytest.approx(2.5e12 / 7.174e12)
    finally:
        hw.unregister("fit-test-host")


# ---------------------------------------------------------------------------
# consumers take hw= by DB name
# ---------------------------------------------------------------------------
def test_roofline_accepts_db_names():
    from repro.perfmodel.costs import CompiledCosts
    from repro.perfmodel.hlo import CollectiveStats
    from repro.perfmodel.roofline import roofline, roofline_across

    costs = CompiledCosts(
        flops_per_device=1e12, bytes_per_device=1e9, transcendentals=0,
        arg_bytes=0, out_bytes=0, temp_bytes=0, alias_bytes=0, code_bytes=0,
    )
    coll = CollectiveStats(per_device_bytes=1e9)
    terms = {}
    for name in ("tpu-v5e", "T4", "A100", "H100"):
        rt = roofline(costs, coll, chips=1, kind="train",
                      n_params_active=1e8, tokens=1e3, hw=name, dtype="bfloat16")
        terms[name] = rt
        assert rt.hw == hw.get(name).name
        assert math.isfinite(rt.compute_s) and rt.compute_s > 0
    # T4 has no interconnect: collective term must be zero, not a crash
    assert terms["T4"].collective_s == 0.0
    assert terms["tpu-v5e"].collective_s > 0.0
    # faster part, less compute time
    assert terms["H100"].compute_s < terms["T4"].compute_s
    across = roofline_across(costs, coll, chips=1, kind="train",
                             n_params_active=1e8, tokens=1e3,
                             hws=("T4", "P4"))
    assert set(across) == {"nvidia-t4-paper", "nvidia-p4"}


def test_dissect_model_and_compare_accept_names():
    from repro.core.dissect import dissect_compare, dissect_model

    rep = dissect_model("T4", dtype="float16")
    assert rep.hardware.name == "nvidia-t4-paper"
    assert max(rep.probe_results["matmul_throughput"]["y"]) <= 41.616e3  # GFLOP/s
    cmp_ = dissect_compare(hws=("P4", "T4"), baseline="T4")
    assert cmp_["baseline"] == "nvidia-t4-paper"
    assert set(cmp_["comparisons"]) == {"nvidia-p4"}
    assert "nvidia-t4-paper" in cmp_["reports"]


def test_autotune_reads_per_dtype_peaks_from_db():
    from repro.core.autotune import choose_matmul_tiles, matmul_time_model, peak_for

    # by name, with fallback: T4 publishes no bf16 -> costed at its fp16 rate
    assert peak_for("T4", "bfloat16") == hw.get("T4").peak("float16")
    t_int8, _ = matmul_time_model(512, 512, 512, 128, 128, 128, "int8", "T4")
    t_fp32, _ = matmul_time_model(512, 512, 512, 128, 128, 128, "float32", "T4")
    assert t_int8 < t_fp32  # cheaper bytes AND higher peak
    choice = choose_matmul_tiles(512, 512, 512, dtype="int8", hw="T4")
    assert choice.predicted_s > 0 and choice.vmem_bytes > 0


def test_gemm_lp_emits_records_for_three_dtypes():
    from repro.bench.suites.gemm_lp import bench_gemm_lp

    recs = bench_gemm_lp(sizes=(64,), dtypes=("float32", "bfloat16", "int8"),
                         hw="T4", backend="xla")
    by_name = {r.name: r for r in recs}
    measured_dts = {r.x.split(":")[0] for r in recs
                    if r.measured and r.name.startswith("gemm_lp_") and ":" in str(r.x)}
    assert {"float32", "bfloat16", "int8"} <= measured_dts
    # modeled ladder rides along, tagged unmeasured, with the paper ratios
    ratio = by_name["gemm_lp_model_nvidia-t4-paper_ratio_int8_over_float16"]
    assert not ratio.measured and ratio.better == "info"
    assert ratio.value == pytest.approx(1.80, abs=0.01)
    assert by_name["gemm_lp_model_nvidia-t4-paper_ratio_float16_over_float32"].value \
        == pytest.approx(5.80, abs=0.02)
