"""Sharded serving cluster: router policies, replica parity, failover, and
tensor-parallel token identity.

Single-device tests cover the data-parallel layer (policy routing, cluster
== single-engine token streams, metric aggregation, replica-failure
drain/requeue, the typed family refusal at ``submit()``).  Tensor-parallel
identity runs in a subprocess with forced fake host devices
(``tests/utils.run_with_devices``); the ``multidevice``-marked tests
additionally exercise replicas × tp in-process when ``REPRO_FORCE_DEVICES``
grants enough devices (the CI multidevice job).
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serve import (
    ClusterConfig,
    ClusterRouter,
    EngineConfig,
    LeastLoadedPolicy,
    PrefixAffinityPolicy,
    RoundRobinPolicy,
    ServeEngine,
    UnsupportedFamilyError,
    make_router,
    replica_meshes,
)
from tests.utils import run_with_devices


@pytest.fixture(scope="module")
def gemma():
    cfg = get_config("gemma-2b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _prompts(cfg, n, lens=(3, 5, 4), seed=0):
    rng = np.random.default_rng(seed)
    return [
        [int(t) for t in rng.integers(1, cfg.vocab_size, lens[i % len(lens)])]
        for i in range(n)
    ]


def _reference_outputs(model, params, prompts, max_new=8, **cfg_kw):
    engine = ServeEngine(
        model, params, EngineConfig(n_slots=2, max_len=32, prefill_chunk=4, **cfg_kw)
    )
    sessions = [engine.submit(p, max_new) for p in prompts]
    engine.run()
    return {tuple(p): s.out for p, s in zip(prompts, sessions)}


# ---------------------------------------------------------------------------
# routing policies (unit, no engines)
# ---------------------------------------------------------------------------
class _StubReplica:
    def __init__(self, index, load, alive=True):
        self.index, self._load, self.alive = index, load, alive

    def load(self):
        return self._load


def test_round_robin_cycles_and_skips_dead():
    policy = RoundRobinPolicy()
    replicas = [_StubReplica(0, 0), _StubReplica(1, 0, alive=False), _StubReplica(2, 0)]
    picks = [policy.place([1], 0, replicas) for _ in range(4)]
    assert picks == [0, 2, 0, 2]


def test_least_loaded_picks_min_load_lowest_index():
    policy = LeastLoadedPolicy()
    replicas = [_StubReplica(0, 5), _StubReplica(1, 2), _StubReplica(2, 2)]
    assert policy.place([1], 0, replicas) == 1
    replicas[1].alive = False
    assert policy.place([1], 0, replicas) == 2


def test_prefix_affinity_longest_match_and_fallback():
    policy = PrefixAffinityPolicy()
    replicas = [_StubReplica(0, 9), _StubReplica(1, 0), _StubReplica(2, 3)]
    policy.note_prefix([1, 2], 0)
    policy.note_prefix([1, 2, 3], 2)
    assert policy.place([1, 2, 3, 4], 0, replicas) == 2  # longest prefix wins
    assert policy.place([1, 2, 9], 0, replicas) == 0  # shorter match
    assert policy.place([7, 8, 9], 0, replicas) == 1  # no match: least-loaded
    policy.forget_replica(2)
    assert policy.place([1, 2, 3, 4], 0, replicas) == 0  # survivor's prefix


def test_make_router_unknown_name():
    with pytest.raises(ValueError, match="unknown router"):
        make_router("nope")


def test_replica_meshes_single_device():
    meshes = replica_meshes(3, tp=1, devices=jax.devices()[:1])
    assert meshes == [None, None, None]
    with pytest.raises(ValueError, match="needs 2 devices"):
        replica_meshes(1, tp=2, devices=jax.devices()[:1])


def test_max_useful_tp(gemma):
    cfg, _, _ = gemma  # reduced gemma: n_heads=4, n_kv_heads=1
    assert cfg.max_useful_tp() == 1
    assert cfg.replace(n_kv_heads=2).max_useful_tp() == 2
    assert cfg.replace(n_kv_heads=4).max_useful_tp() == 4
    assert cfg.replace(n_kv_heads=4).max_useful_tp(limit=2) == 2


# ---------------------------------------------------------------------------
# typed family refusal
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def xlstm():
    cfg = get_config("xlstm-1.3b").reduced()
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.key(0))


def test_engine_raises_typed_family_error(xlstm):
    cfg, model, params = xlstm
    with pytest.raises(UnsupportedFamilyError) as exc:
        ServeEngine(model, params, EngineConfig(n_slots=2, max_len=16))
    assert exc.value.family == cfg.family
    assert exc.value.missing == "decode_chunk"
    assert "dense" in str(exc.value)  # names the fallback families
    assert isinstance(exc.value, NotImplementedError)  # old catch sites hold


def test_cluster_surfaces_family_error_at_submit(xlstm):
    _, model, params = xlstm
    cluster = ClusterRouter(model, params, ClusterConfig(
        engine=EngineConfig(n_slots=2, max_len=16), n_replicas=2))
    # construction is lazy: no error until the first submit
    with pytest.raises(UnsupportedFamilyError, match="decode_chunk"):
        cluster.submit([1, 2, 3], 4)


# ---------------------------------------------------------------------------
# cluster == single engine (token streams), 1 device
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("router", ["round_robin", "least_loaded"])
def test_cluster_matches_single_engine(gemma, router):
    cfg, model, params = gemma
    prompts = _prompts(cfg, 6)
    ref = _reference_outputs(model, params, prompts)
    cluster = ClusterRouter(model, params, ClusterConfig(
        engine=EngineConfig(n_slots=2, max_len=32, prefill_chunk=4),
        n_replicas=2, router=router))
    sessions = [cluster.submit(p, 8) for p in prompts]
    cluster.run()
    for p, s in zip(prompts, sessions):
        assert s.out == ref[tuple(p)], (router, p)
    # rids are cluster-unique (per-replica stride)
    rids = [s.rid for s in sessions]
    assert len(set(rids)) == len(rids)


def test_cluster_metrics_aggregate(gemma):
    cfg, model, params = gemma
    prompts = _prompts(cfg, 6, seed=1)
    cluster = ClusterRouter(model, params, ClusterConfig(
        engine=EngineConfig(n_slots=2, max_len=32, prefill_chunk=4),
        n_replicas=2))
    for p in prompts:
        cluster.submit(p, 6)
    cluster.run()
    summ = cluster.summary()
    per = summ["per_replica"]
    assert summ["replicas"] == 2 and len(per) == 2
    assert summ["requests"] == 6 == sum(r["requests"] for r in per)
    assert summ["generated_tokens"] == sum(r["generated_tokens"] for r in per)
    assert summ["routed"] == 6 and summ["failures"] == 0
    assert 0 < summ["occupancy"] <= 1
    assert summ["throughput_tok_s"] > 0
    recs = cluster.to_records("serving_scaled", "cluster", x=2)
    assert {r.name for r in recs} == {
        "cluster_ttft", "cluster_tok_latency_p95",
        "cluster_throughput", "cluster_occupancy",
        "cluster_goodput", "cluster_availability", "cluster_faults",
    }
    for r in recs:
        assert r.metrics["replicas"] == 2


def test_prefix_affinity_routes_to_prefix_owner(gemma):
    cfg, model, params = gemma
    cluster = ClusterRouter(model, params, ClusterConfig(
        engine=EngineConfig(n_slots=2, max_len=32, prefill_chunk=4, page_size=4),
        n_replicas=2, router="prefix_affinity"))
    prefix = [1, 2, 3, 4]
    cluster.register_prefix(prefix, replica=1)
    s = cluster.submit(prefix + [5, 6], 4)
    assert cluster._placement[s.rid] == 1
    cluster.run()
    assert s.done
    # the fork actually reused shared pages on the owning replica
    assert cluster.replicas[1].engine.metrics.prefix_hits == 1


# ---------------------------------------------------------------------------
# failure drain / requeue
# ---------------------------------------------------------------------------
def test_failover_resumes_token_exact(gemma):
    cfg, model, params = gemma
    prompts = _prompts(cfg, 6, seed=2)
    ref = _reference_outputs(model, params, prompts)
    cluster = ClusterRouter(model, params, ClusterConfig(
        engine=EngineConfig(n_slots=2, max_len=32, prefill_chunk=4, page_size=4),
        n_replicas=2, router="round_robin"))
    sessions = [cluster.submit(p, 8) for p in prompts]
    for _ in range(3):  # some sessions mid-decode, some still queued
        cluster.step()
    drained = cluster.fail_replica(0)
    assert drained and any(s.out for s in drained)  # in-flight output kept
    cluster.run()
    for p, s in zip(prompts, sessions):
        assert s.done
        assert s.out == ref[tuple(p)], ("failover", p)
    summ = cluster.summary()
    assert summ["failures"] == 1
    assert summ["requeued_sessions"] == len(drained)
    assert not cluster.replicas[0].alive
    with pytest.raises(ValueError, match="already failed"):
        cluster.fail_replica(0)


def test_failover_last_replica_raises(gemma):
    cfg, model, params = gemma
    cluster = ClusterRouter(model, params, ClusterConfig(
        engine=EngineConfig(n_slots=2, max_len=32, prefill_chunk=4),
        n_replicas=1))
    cluster.submit(_prompts(cfg, 1)[0], 4)
    with pytest.raises(RuntimeError, match="no live replicas"):
        cluster.fail_replica(0)


def test_engine_drain_returns_running_and_queued(gemma):
    cfg, model, params = gemma
    engine = ServeEngine(model, params,
                         EngineConfig(n_slots=2, max_len=32, prefill_chunk=4))
    sessions = [engine.submit(p, 8) for p in _prompts(cfg, 5, seed=3)]
    engine.step()  # two running, three queued
    drained = engine.drain()
    assert len(drained) == 5
    assert all(s.status == "queued" for s in drained)
    assert not engine.has_work()
    assert {s.rid for s in drained} == {s.rid for s in sessions}


class _NoDrainFCFS:
    """Scheduler without the optional drain() — exercises the select-loop
    fallback in ServeEngine.drain."""

    def __init__(self):
        self._q = []

    def submit(self, s):
        self._q.append(s)

    def select(self, n_free, n_slots):
        out, self._q = self._q[:n_free], self._q[n_free:]
        return [s for s in out if not s.done]

    def pending(self):
        return sum(1 for s in self._q if not s.done)


def test_engine_drain_without_scheduler_drain(gemma):
    cfg, model, params = gemma
    engine = ServeEngine(
        model, params, EngineConfig(n_slots=2, max_len=32, prefill_chunk=4),
        scheduler=_NoDrainFCFS())
    for p in _prompts(cfg, 4, seed=4):
        engine.submit(p, 4)
    drained = engine.drain()
    assert len(drained) == 4 and engine.scheduler.pending() == 0


def test_drain_counts_preemptions_for_slot_drained_only(gemma):
    cfg, model, params = gemma
    engine = ServeEngine(model, params,
                         EngineConfig(n_slots=2, max_len=32, prefill_chunk=4))
    sessions = [engine.submit(p, 8) for p in _prompts(cfg, 5, seed=5)]
    engine.step()  # two in lanes, three still queued
    running = [s for s in sessions if s.status != "queued"]
    assert len(running) == 2
    drained = engine.drain()
    assert len(drained) == 5
    # only the lane-holders replay through prefill; queue-drained sessions
    # re-enter exactly as they were
    for s in drained:
        assert s.stats.preemptions == (1 if s in running else 0)


class _WithholdingScheduler(_NoDrainFCFS):
    """Claims pending work but never releases it — drain() must terminate
    (and strand the queue) instead of spinning on select()."""

    def select(self, n_free, n_slots):
        return []


def test_drain_terminates_against_withholding_scheduler(gemma):
    cfg, model, params = gemma
    engine = ServeEngine(
        model, params, EngineConfig(n_slots=2, max_len=32, prefill_chunk=4),
        scheduler=_WithholdingScheduler())
    for p in _prompts(cfg, 3, seed=6):
        engine.submit(p, 4)
    drained = engine.drain()  # would loop forever without the empty-batch stop
    assert drained == []
    assert engine.scheduler.pending() == 3  # stranded, but drain() returned


def test_failover_reroutes_registered_prefix_sessions(gemma):
    cfg, model, params = gemma
    cluster = ClusterRouter(model, params, ClusterConfig(
        engine=EngineConfig(n_slots=2, max_len=32, prefill_chunk=4,
                            page_size=4),
        n_replicas=2, router="prefix_affinity"))
    prefix = [1, 2, 3, 4]
    cluster.register_prefix(prefix, replica=0)
    sessions = [cluster.submit(prefix + [t], 6) for t in (5, 6, 7)]
    ref = _reference_outputs(model, params,
                             [s.prompt for s in sessions], max_new=6,
                             page_size=4)
    cluster.step()
    drained = cluster.fail_replica(0)  # the prefix owner goes down
    assert drained
    # affinity forgot replica 0: the drained sessions land on the survivor
    # (which has no shared pages for the prefix) and still finish token-exact
    assert all(cluster._placement[s.rid] == 1 for s in drained)
    cluster.run()
    for s in sessions:
        assert s.done and s.out == ref[tuple(s.prompt)]
    assert cluster.replicas[1].engine.metrics.prefix_hits == 0


def test_register_router_custom_policy(gemma):
    from repro.serve import ROUTERS, RouterPolicy, register_router

    class _PinToLast(RouterPolicy):
        def place(self, prompt, priority, replicas):
            return max(r.index for r in replicas if r.alive)

    try:
        register_router("pin_to_last", _PinToLast)
        assert ROUTERS["pin_to_last"] is _PinToLast
        assert isinstance(make_router("pin_to_last"), _PinToLast)
        with pytest.raises(ValueError, match="already registered"):
            register_router("pin_to_last", _PinToLast)
        # registered names pass ClusterConfig validation and route for real
        cfg, model, params = gemma
        cluster = ClusterRouter(model, params, ClusterConfig(
            engine=EngineConfig(n_slots=2, max_len=32, prefill_chunk=4),
            n_replicas=2, router="pin_to_last"))
        s = cluster.submit(_prompts(cfg, 1)[0], 4)
        assert cluster._placement[s.rid] == 1
        cluster.run()
        assert s.done
    finally:
        ROUTERS.pop("pin_to_last", None)


def test_register_router_as_decorator():
    from repro.serve import ROUTERS, RouterPolicy, register_router

    try:
        @register_router("decorated")
        class _Decorated(RouterPolicy):
            def place(self, prompt, priority, replicas):
                return 0

        assert ROUTERS["decorated"] is _Decorated
    finally:
        ROUTERS.pop("decorated", None)


def test_cluster_config_rejects_engine_mesh(gemma):
    with pytest.raises(ValueError, match="owns device placement"):
        ClusterConfig(
            engine=EngineConfig(
                n_slots=2, max_len=16,
                mesh=jax.sharding.Mesh(np.array(jax.devices()[:1]), ("model",)),
            ),
            n_replicas=2,
        )


# ---------------------------------------------------------------------------
# tensor parallel: token identity under forced fake devices (subprocess)
# ---------------------------------------------------------------------------
def test_tp_decode_token_identity_subprocess():
    """Sharded decode (tp in {1,2,4}, dense + paged) produces the same token
    streams as the no-mesh engine, verified under 8 fake CPU devices."""
    out = run_with_devices(
        """
        import jax, numpy as np
        from jax.sharding import Mesh
        from repro.configs import get_config
        from repro.models import build_model
        from repro.serve import EngineConfig, ServeEngine

        cfg = get_config("gemma-2b").reduced()
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        prompts = [[1 + (i % 5), 2, 3 + i % 7] for i in range(4)]

        def drive(mesh, page_size):
            engine = ServeEngine(model, params, EngineConfig(
                n_slots=2, max_len=32, prefill_chunk=4,
                page_size=page_size, mesh=mesh))
            sessions = [engine.submit(p, 6) for p in prompts]
            engine.run()
            return [s.out for s in sessions]

        ref = drive(None, None)
        assert drive(None, 4) == ref  # paged == dense, unsharded
        for tp in (1, 2, 4):
            mesh = Mesh(np.array(jax.devices()[:tp]), ("model",))
            for ps in (None, 4):
                got = drive(mesh, ps)
                assert got == ref, (tp, ps, got, ref)
                print(f"tp={tp} ps={ps} OK")
        print("TP_IDENTITY_OK")
        """,
        n_devices=8,
    )
    assert "TP_IDENTITY_OK" in out


# ---------------------------------------------------------------------------
# in-process multi-device (CI multidevice job: REPRO_FORCE_DEVICES=8)
# ---------------------------------------------------------------------------
@pytest.mark.multidevice(4)
def test_cluster_tp_replicas_in_process(gemma):
    """2 replicas x tp=2 on disjoint device pairs: same tokens as the
    single-device single-engine reference."""
    cfg, model, params = gemma
    prompts = _prompts(cfg, 6, seed=5)
    ref = _reference_outputs(model, params, prompts)
    cluster = ClusterRouter(model, params, ClusterConfig(
        engine=EngineConfig(n_slots=2, max_len=32, prefill_chunk=4),
        n_replicas=2, tp=2))
    sessions = [cluster.submit(p, 8) for p in prompts]
    cluster.run()
    for p, s in zip(prompts, sessions):
        assert s.out == ref[tuple(p)]
    meshes = [r.mesh for r in cluster.replicas]
    assert all(m is not None and m.shape["model"] == 2 for m in meshes)
    # disjoint device pairs when the pool is large enough
    d0 = {d.id for d in meshes[0].devices.flat}
    d1 = {d.id for d in meshes[1].devices.flat}
    assert d0.isdisjoint(d1)


@pytest.mark.multidevice(4)
def test_cluster_failover_sharded_in_process(gemma):
    """Failover between tensor-parallel replicas stays token-exact."""
    cfg, model, params = gemma
    prompts = _prompts(cfg, 4, seed=6)
    ref = _reference_outputs(model, params, prompts)
    cluster = ClusterRouter(model, params, ClusterConfig(
        engine=EngineConfig(n_slots=2, max_len=32, prefill_chunk=4, page_size=4),
        n_replicas=2, tp=2, router="round_robin"))
    sessions = [cluster.submit(p, 8) for p in prompts]
    for _ in range(2):
        cluster.step()
    cluster.fail_replica(1)
    cluster.run()
    for p, s in zip(prompts, sessions):
        assert s.out == ref[tuple(p)]
