"""Serving-engine behaviour: scheduler protocol (FCFS vs priority parity,
user-supplied policies), chunked batched prefill exactness, streaming
sessions (callbacks, cancellation), and edge cases (slot exhaustion, EOS
mid-stream, max_len truncation, quick-mode record determinism)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serve import (
    EngineConfig,
    FCFSScheduler,
    PriorityScheduler,
    ServeEngine,
    StaticBatchScheduler,
)


@pytest.fixture(scope="module")
def gemma():
    cfg = get_config("gemma-2b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _prompts(cfg, n, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [
        [int(t) for t in rng.integers(1, cfg.vocab_size, lens[i % len(lens)])]
        for i in range(n)
    ]


# ---------------------------------------------------------------------------
# chunked batched prefill is exact
# ---------------------------------------------------------------------------
def test_chunked_prefill_matches_per_token_decode(gemma):
    """decode_chunk over a ragged admitted batch == sequential decode_step
    per lane (the oracle the old per-token Python prefill implemented)."""
    cfg, model, params = gemma
    max_len, lens = 32, [7, 3, 5]
    toks = _prompts(cfg, 3, lens, seed=3)

    for b, prompt in enumerate(toks):
        cache = model.init_cache(1, max_len)
        for t, tok in enumerate(prompt):
            want, cache = model.decode_step(
                params, cache, jnp.asarray([tok], jnp.int32),
                jnp.full((1,), t, jnp.int32),
            )
        chunk = 4
        n_chunks = -(-max(lens) // chunk)
        tk = np.zeros((3, n_chunks * chunk), np.int32)
        ps = np.full((3, n_chunks * chunk), max_len, np.int32)
        for i, p in enumerate(toks):
            tk[i, : len(p)] = p
            ps[i, : len(p)] = np.arange(len(p))
        cache_c = model.init_cache(3, max_len)
        got = None
        for c in range(n_chunks):
            sl = slice(c * chunk, (c + 1) * chunk)
            lg, cache_c = model.decode_chunk(
                params, cache_c, jnp.asarray(tk[:, sl]), jnp.asarray(ps[:, sl])
            )
            if c * chunk < len(prompt) <= (c + 1) * chunk:
                got = lg[b, len(prompt) - 1 - c * chunk]
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want[0]), rtol=2e-4, atol=2e-4
        )


@pytest.mark.parametrize("chunk", [1, 4, 64])
def test_prefill_chunk_size_does_not_change_output(gemma, chunk):
    cfg, model, params = gemma

    def run(c):
        eng = ServeEngine(
            model, params, EngineConfig(n_slots=2, max_len=48, prefill_chunk=c)
        )
        ss = [eng.submit(p, 5) for p in _prompts(cfg, 3, [6, 11], seed=1)]
        eng.run(300)
        return [s.out for s in ss]

    assert run(chunk) == run(8)


# ---------------------------------------------------------------------------
# scheduler protocol
# ---------------------------------------------------------------------------
def test_fcfs_vs_priority_parity(gemma):
    """Admission order must not change any request's tokens — only its
    scheduling.  Priorities reverse the admission order here."""
    cfg, model, params = gemma
    prompts = _prompts(cfg, 6, [4, 6, 5], seed=2)

    def run(sched, priorities):
        eng = ServeEngine(
            model, params,
            EngineConfig(n_slots=2, max_len=48), scheduler=sched,
        )
        ss = [
            eng.submit(p, 4, priority=pr) for p, pr in zip(prompts, priorities)
        ]
        fin = eng.run(500)
        assert len(fin) == len(prompts)
        return {s.rid: s.out for s in ss}, [s.rid for s in fin]

    out_f, order_f = run(FCFSScheduler(), [0] * 6)
    out_p, order_p = run(PriorityScheduler(), list(range(6)))
    assert out_f == out_p  # token parity
    # highest priority (last submitted) admits first once slots free up
    assert order_p != order_f


def test_priority_scheduler_admits_high_priority_first(gemma):
    cfg, model, params = gemma
    eng = ServeEngine(
        model, params, EngineConfig(n_slots=1, max_len=32),
        scheduler=PriorityScheduler(),
    )
    low = eng.submit([3, 4], 2, priority=0)
    high = eng.submit([5, 6], 2, priority=9)
    fin = eng.run(100)
    assert [s.rid for s in fin] == [high.rid, low.rid]


def test_user_supplied_scheduler(gemma):
    """Any object with submit/select/pending plugs in: LIFO as a worked
    example of the protocol."""
    cfg, model, params = gemma

    class LIFOScheduler:
        def __init__(self):
            self.stack = []

        def submit(self, session):
            self.stack.append(session)

        def select(self, n_free, n_slots):
            out = []
            while self.stack and len(out) < n_free:
                s = self.stack.pop()
                if not s.done:
                    out.append(s)
            return out

        def pending(self):
            return sum(1 for s in self.stack if not s.done)

    eng = ServeEngine(
        model, params, EngineConfig(n_slots=1, max_len=32), scheduler=LIFOScheduler()
    )
    a = eng.submit([3, 4], 2)
    b = eng.submit([5, 6], 2)
    fin = eng.run(100)
    assert [s.rid for s in fin] == [b.rid, a.rid]


def test_static_batch_scheduler_admits_only_into_idle_engine(gemma):
    cfg, model, params = gemma
    eng = ServeEngine(
        model, params, EngineConfig(n_slots=2, max_len=32),
        scheduler=StaticBatchScheduler(),
    )
    ss = [eng.submit([2 + i, 7], 3) for i in range(3)]
    # first step admits the first full batch; the third stays queued until
    # BOTH slots drain (batch boundary), not the moment one slot frees
    eng.step()
    assert ss[0].status != "queued" and ss[1].status != "queued"
    assert ss[2].status == "queued"
    while ss[2].status == "queued" and eng.has_work():
        eng.step()
    # admission of the straggler only happened once the whole batch drained
    assert ss[0].done and ss[1].done
    fin = eng.run(200)
    assert len(fin) == 3


def test_recurrent_family_rejected_loudly():
    """Families without decode_chunk (recurrent per-lane state) must be
    refused up front — the old engine silently corrupted neighbour lanes."""
    cfg = get_config("xlstm-1.3b").reduced()
    model = build_model(cfg)
    assert model.decode_chunk is None
    with pytest.raises(NotImplementedError, match="decode_chunk"):
        ServeEngine(model, None, EngineConfig(n_slots=2, max_len=16))


def test_non_scheduler_rejected(gemma):
    cfg, model, params = gemma
    with pytest.raises(TypeError, match="Scheduler protocol"):
        ServeEngine(
            model, params, EngineConfig(n_slots=1, max_len=16), scheduler=object()
        )


# ---------------------------------------------------------------------------
# edge cases
# ---------------------------------------------------------------------------
def test_slot_exhaustion_queues_and_drains(gemma):
    """More requests than slots: the queue drains via continuous batching
    and at no point do more than n_slots sessions run."""
    cfg, model, params = gemma
    eng = ServeEngine(model, params, EngineConfig(n_slots=2, max_len=48))
    ss = [eng.submit(p, 3) for p in _prompts(cfg, 7, [4], seed=4)]
    assert eng.scheduler.pending() == 7
    seen_active = []
    while eng.has_work():
        eng.step()
        seen_active.append(sum(s is not None for s in eng.slots))
    assert max(seen_active) <= 2
    assert len(eng.finished) == 7
    assert all(len(s.out) == 3 for s in ss)
    assert eng.scheduler.pending() == 0


def test_eos_mid_stream_frees_slot(gemma):
    """A sampled EOS finishes the request early with reason "eos"."""
    cfg, model, params = gemma
    probe = ServeEngine(model, params, EngineConfig(n_slots=1, max_len=48))
    s0 = probe.submit([5, 6, 7], 8)
    probe.run(100)
    assert len(s0.out) == 8
    eos = s0.out[2]  # force EOS on the 3rd generated token
    eng = ServeEngine(model, params, EngineConfig(n_slots=1, max_len=48, eos_id=eos))
    s = eng.submit([5, 6, 7], 8)
    eng.run(100)
    assert s.finish_reason == "eos"
    assert len(s.out) == 3 and s.out[-1] == eos
    assert eng.slots[0] is None  # slot freed for the next request


def test_max_len_truncation(gemma):
    """Generation stops with reason "max_len" when the cache lane is full.
    The final token needs no KV write, so capacity is
    max_len - len(prompt) + 1 generated tokens."""
    cfg, model, params = gemma
    eng = ServeEngine(model, params, EngineConfig(n_slots=1, max_len=8))
    s = eng.submit([1, 2, 3, 4, 5], max_new_tokens=50)
    eng.run(100)
    assert s.finish_reason == "max_len"
    assert len(s.out) == 8 - 5 + 1


def test_prompt_validation(gemma):
    cfg, model, params = gemma
    eng = ServeEngine(model, params, EngineConfig(n_slots=1, max_len=8))
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit([], 4)
    with pytest.raises(ValueError, match="max_len"):
        eng.submit(list(range(1, 9)), 4)  # prompt fills the whole cache
    with pytest.raises(ValueError, match="max_new_tokens"):
        eng.submit([1, 2], 0)


def test_cancellation_queued_and_running(gemma):
    cfg, model, params = gemma
    eng = ServeEngine(model, params, EngineConfig(n_slots=1, max_len=32))
    running = eng.submit([3, 4, 5], 50)
    queued = eng.submit([6, 7], 4)
    eng.step()  # running admitted; queued waits
    assert running.status == "active" and queued.status == "queued"
    queued.cancel()
    assert queued.status == "cancelled" and queued.finish_reason == "cancelled"
    eng.step()
    n_before = len(running.out)
    running.cancel()
    fin = eng.run(100)
    assert running.finish_reason == "cancelled"
    assert len(running.out) == n_before  # no tokens after the cancel boundary
    # both cancellation paths land in finished and in the metrics
    assert [s.rid for s in fin] == [queued.rid, running.rid]
    assert eng.summary()["cancelled"] == 2
    assert not eng.has_work()


def test_streaming_callback_order_and_stats(gemma):
    cfg, model, params = gemma
    eng = ServeEngine(model, params, EngineConfig(n_slots=2, max_len=32))
    got = []
    s = eng.submit([4, 5, 6], 5, on_token=lambda sess, tok: got.append(tok))
    eng.run(100)
    assert got == s.out and len(got) == 5
    st = s.stats
    assert st.ttft_s is not None and st.ttft_s > 0
    assert st.finished_at >= st.first_token_at >= st.admitted_at >= st.submitted_at
    assert len(st.token_times) == 5
    assert len(st.token_latencies_s) == 4
    assert all(lat >= 0 for lat in st.token_latencies_s)


def test_engine_backend_policy_traced_per_engine(gemma):
    """Two engines over the SAME model with different backends must each
    trace under their own kernel policy: jax's trace cache is keyed on
    function identity, so jitting the shared model.decode_step directly
    would let the second engine silently reuse the first's trace."""
    import dataclasses

    from repro.kernels import api as kapi

    cfg, model, params = gemma
    seen = []
    orig_step, orig_chunk = model.decode_step, model.decode_chunk

    def spy_step(p, cache, toks, pos):
        seen.append(kapi.current_policy().backend)  # runs at trace time only
        return orig_step(p, cache, toks, pos)

    spy_model = dataclasses.replace(model, decode_step=spy_step)

    def run(backend):
        eng = ServeEngine(
            spy_model, params,
            EngineConfig(n_slots=1, max_len=16, backend=backend),
        )
        eng.submit([3, 4], 2)
        eng.run(50)

    run("xla")
    run("interpret")
    assert "xla" in seen and "interpret" in seen, seen
    assert orig_chunk is model.decode_chunk  # replace() didn't mutate the original


# ---------------------------------------------------------------------------
# bench-suite integration
# ---------------------------------------------------------------------------
def test_serving_quick_records_deterministic_names_and_schema():
    """Quick-mode serving records: stable names/shape across runs, schema
    valid, and the required metrics present for both backends."""
    from repro.bench import BenchResult, EnvFingerprint, runner, validate_result
    from repro.core import registry

    runner.load_suites()
    overrides = {"requests": 2, "out_lens": (3,), "prompt_lens": (4,)}

    def names_for(variant):
        recs = registry.get(variant).run("quick", overrides=overrides)
        res = BenchResult(mode="quick", env=EnvFingerprint.capture(), records=recs)
        validate_result(res.to_dict())
        return [r.name for r in recs], recs

    for variant in ("serving[pallas]", "serving[xla]"):
        names1, recs = names_for(variant)
        names2, _ = names_for(variant)
        assert names1 == names2  # deterministic record identity
        for metric in ("ttft", "tok_latency_p50", "tok_latency_p95",
                       "throughput", "occupancy"):
            assert any(metric in n for n in names1), (metric, names1)
        units = {r.unit for r in recs}
        assert {"ms", "tok/s"} <= units
