"""Chaos hardening: fault injection, health failover, deadlines, degradation.

The acceptance contract from docs/robustness.md drives these tests: a
scripted chaos run (replica crash + straggler slowdown + one pallas fault)
completes with zero lost sessions and token-exact output for every
non-deadline session, `finish_reason="deadline"` fires only for unmeetable
deadlines, and the whole thing — tokens and fault/retry/degradation
counters — is deterministic across two runs with the same seed.
"""
import warnings

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serve import (
    ClusterConfig,
    ClusterRouter,
    EngineConfig,
    Fault,
    FaultInjector,
    FaultPlan,
    HealthConfig,
    ReplicaCrashed,
    RetryBudgetExceeded,
    ServeEngine,
)
from repro.serve.cluster import BREAKER_CLOSED, BREAKER_OPEN


@pytest.fixture(scope="module")
def gemma():
    cfg = get_config("gemma-2b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _prompts(cfg, n, lens=(3, 5, 4), seed=0):
    rng = np.random.default_rng(seed)
    return [
        [int(t) for t in rng.integers(1, cfg.vocab_size, lens[i % len(lens)])]
        for i in range(n)
    ]


def _reference_outputs(model, params, prompts, max_new=8, **cfg_kw):
    engine = ServeEngine(
        model, params, EngineConfig(n_slots=2, max_len=32, prefill_chunk=4, **cfg_kw)
    )
    sessions = [engine.submit(p, max_new) for p in prompts]
    engine.run()
    return {tuple(p): s.out for p, s in zip(prompts, sessions)}


def _engine(model, params, **kw):
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_len", 32)
    kw.setdefault("prefill_chunk", 4)
    kw.setdefault("backend", "xla")
    return ServeEngine(model, params, EngineConfig(**kw))


# ---------------------------------------------------------------------------
# FaultPlan / Fault (unit, no engines)
# ---------------------------------------------------------------------------
def test_fault_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        Fault(tick=0, kind="meteor")
    with pytest.raises(ValueError, match="duration"):
        Fault(tick=0, kind="crash", duration=0)
    with pytest.raises(ValueError, match="factor"):
        Fault(tick=0, kind="straggler", factor=1.0)
    with pytest.raises(ValueError, match="tick"):
        Fault(tick=-1, kind="crash")


def test_fault_plan_random_deterministic():
    a = FaultPlan.random(7, n_ticks=16, n_faults=5, n_replicas=3)
    b = FaultPlan.random(7, n_ticks=16, n_faults=5, n_replicas=3)
    assert a == b and a.seed == 7
    assert len(a.faults) == 5
    assert all(1 <= f.tick < 16 and f.replica < 3 for f in a.faults)
    assert FaultPlan.random(8, n_ticks=16, n_faults=5) != a
    # sorted by tick; horizon covers the longest fault
    ticks = [f.tick for f in a.faults]
    assert ticks == sorted(ticks)
    assert a.horizon == max(f.tick + f.duration for f in a.faults)


def test_injector_rejects_out_of_range_replica(gemma):
    cfg, model, params = gemma
    engine = _engine(model, params)
    plan = FaultPlan(faults=(Fault(tick=1, kind="crash", replica=3),))
    with pytest.raises(ValueError, match="replica"):
        FaultInjector(plan, engine)


# ---------------------------------------------------------------------------
# the acceptance criterion: scripted chaos, deterministic, zero loss
# ---------------------------------------------------------------------------
def _chaos_cluster(model, params, page_size=4):
    return ClusterRouter(model, params, ClusterConfig(
        engine=EngineConfig(n_slots=2, max_len=32, prefill_chunk=4,
                            backend="xla", page_size=page_size),
        n_replicas=2,
        router="round_robin",
        health=HealthConfig(heartbeat_timeout=2, min_samples=3,
                            margin=0.25, cooldown=50),
    ))


_SCRIPTED_PLAN = FaultPlan(faults=(
    Fault(tick=2, kind="crash", replica=0, duration=4),  # replica crash @ N
    Fault(tick=3, kind="straggler", replica=1, duration=3, factor=4.0),
    Fault(tick=6, kind="kernel_fault", replica=1),  # one pallas-style fault
    Fault(tick=7, kind="nan_logits", replica=1, lanes=(0,), duration=1),
))


def _run_scripted(cfg, model, params, prompts):
    cluster = _chaos_cluster(model, params)
    sessions = [cluster.submit(p, 8) for p in prompts]
    injector = FaultInjector(_SCRIPTED_PLAN, cluster)
    injector.run()
    return cluster, sessions, injector


def test_scripted_chaos_zero_loss_token_exact_deterministic(gemma):
    cfg, model, params = gemma
    prompts = _prompts(cfg, 6, seed=3)
    ref = _reference_outputs(model, params, prompts, page_size=4)

    cluster, sessions, injector = _run_scripted(cfg, model, params, prompts)
    # zero lost sessions, token-exact for every (non-deadline) session
    assert len(cluster.finished) == len(prompts)
    for p, s in zip(prompts, sessions):
        assert s.done and s.finish_reason != "deadline"
        assert s.out == ref[tuple(p)], ("chaos divergence", p)
    summ = cluster.summary()
    assert summ["failovers"], "the crash must have driven a failover"
    assert summ["requeues"] >= 1 and summ["quarantines"] == 1
    assert 0 < summ["availability"] < 1
    assert injector.summary()["applied"]["crash"] == 1

    # the whole run replays exactly: tokens AND counters
    cluster2, sessions2, injector2 = _run_scripted(cfg, model, params, prompts)
    assert [s.out for s in sessions2] == [s.out for s in sessions]
    k = ("requeues", "quarantines", "nan_events", "degradations",
         "deadline_expired", "failovers", "requeued_sessions")
    summ2 = cluster2.summary()
    assert {x: summ2[x] for x in k} == {x: summ[x] for x in k}
    assert injector2.summary() == injector.summary()


# ---------------------------------------------------------------------------
# health-driven failover
# ---------------------------------------------------------------------------
def test_heartbeat_failover_on_crash(gemma):
    cfg, model, params = gemma
    prompts = _prompts(cfg, 4, seed=4)
    ref = _reference_outputs(model, params, prompts)
    cluster = ClusterRouter(model, params, ClusterConfig(
        engine=EngineConfig(n_slots=2, max_len=32, prefill_chunk=4,
                            backend="xla"),
        n_replicas=2, router="round_robin",
        health=HealthConfig(heartbeat_timeout=2, straggler=False, cooldown=50),
    ))
    sessions = [cluster.submit(p, 8) for p in prompts]
    plan = FaultPlan(faults=(Fault(tick=1, kind="crash", replica=0,
                                   duration=30),))
    FaultInjector(plan, cluster).run()
    assert cluster.summary()["failovers"] == {"heartbeat": 1}
    assert not cluster.replicas[0].alive
    assert cluster.replicas[0].breaker == BREAKER_OPEN  # cooldown > run length
    for p, s in zip(prompts, sessions):
        assert s.done and s.out == ref[tuple(p)]


def test_straggler_failover_breaker_reopens_and_revives(gemma):
    cfg, model, params = gemma
    cluster = ClusterRouter(model, params, ClusterConfig(
        engine=EngineConfig(n_slots=2, max_len=32, prefill_chunk=4,
                            backend="xla"),
        n_replicas=2, router="round_robin",
        health=HealthConfig(heartbeat_timeout=3, min_samples=3, margin=0.25,
                            cooldown=2, probe_ticks=2, warmup_ticks=2),
    ))
    prompts = _prompts(cfg, 8, lens=(5,), seed=5)
    sessions = [cluster.submit(p, 16) for p in prompts]
    plan = FaultPlan(faults=(Fault(tick=1, kind="straggler", replica=1,
                                   duration=8, factor=6.0),))
    FaultInjector(plan, cluster).run()
    summ = cluster.summary()
    assert summ["failovers"].get("straggler", 0) >= 1
    # cooldown elapsed mid-run: the breaker half-opened and, with the fault
    # expired, probed healthy back to CLOSED
    assert summ["half_opens"] >= 1 and summ["revivals"] >= 1
    assert cluster.replicas[1].alive
    assert cluster.replicas[1].breaker == BREAKER_CLOSED
    assert all(s.done for s in sessions)


def test_last_replica_failover_is_skipped_not_fatal(gemma):
    cfg, model, params = gemma
    cluster = ClusterRouter(model, params, ClusterConfig(
        engine=EngineConfig(n_slots=2, max_len=32, prefill_chunk=4,
                            backend="xla"),
        n_replicas=1,
        health=HealthConfig(heartbeat_timeout=1, straggler=False, cooldown=3),
    ))
    s = cluster.submit(_prompts(cfg, 1)[0], 6)
    plan = FaultPlan(faults=(Fault(tick=1, kind="crash", duration=4),))
    FaultInjector(plan, cluster).run()
    # the only replica is never auto-killed; it resumes after the outage
    assert cluster.summary()["failover_skipped"] >= 1
    assert cluster.replicas[0].alive and s.done


def test_cluster_without_health_propagates_crash(gemma):
    cfg, model, params = gemma
    cluster = ClusterRouter(model, params, ClusterConfig(
        engine=EngineConfig(n_slots=2, max_len=32, prefill_chunk=4,
                            backend="xla"),
        n_replicas=1))
    cluster.submit(_prompts(cfg, 1)[0], 4)
    cluster._ensure_replicas()
    cluster.replicas[0].engine.crashed = True
    with pytest.raises(ReplicaCrashed):
        cluster.step()


# ---------------------------------------------------------------------------
# deadlines
# ---------------------------------------------------------------------------
def test_deadline_unmeetable_vs_generous(gemma):
    cfg, model, params = gemma
    engine = _engine(model, params)
    p1, p2 = _prompts(cfg, 2, seed=6)
    tight = engine.submit(p1, 8, deadline_s=1e-9)  # expires before any token
    loose = engine.submit(p2, 8, deadline_s=3600.0)
    finished = engine.run()
    assert len(finished) == 2
    assert tight.finish_reason == "deadline"
    assert loose.finish_reason == "max_new_tokens" and len(loose.out) == 8
    summ = engine.summary()
    assert summ["deadline_expired"] == 1
    # goodput excludes the expired session's tokens
    assert summ["goodput_tokens"] == summ["generated_tokens"] - len(tight.out)
    with pytest.raises(ValueError, match="deadline_s"):
        engine.submit(p1, 4, deadline_s=0.0)


def test_deadline_expires_in_queue(gemma):
    cfg, model, params = gemma
    engine = _engine(model, params, n_slots=1)
    prompts = _prompts(cfg, 3, seed=7)
    head = engine.submit(prompts[0], 6)
    queued = [engine.submit(p, 6, deadline_s=1e-9) for p in prompts[1:]]
    engine.run()
    assert head.finish_reason == "max_new_tokens"
    for s in queued:  # picked by admission after expiry: never held a lane
        assert s.finish_reason == "deadline" and s.out == []
    assert engine.summary()["deadline_expired"] == 2


# ---------------------------------------------------------------------------
# retry budget / backoff
# ---------------------------------------------------------------------------
def test_retry_budget_exceeded_is_typed(gemma):
    cfg, model, params = gemma
    engine = _engine(model, params, retry_budget=1)
    s = engine.submit(_prompts(cfg, 1)[0], 4)
    engine.step()  # admit + prefill: the session holds a lane
    assert s in engine.drain()
    engine.requeue(s)  # within budget
    assert s in engine.drain()
    with pytest.raises(RetryBudgetExceeded) as ei:
        engine.requeue(s)
    assert ei.value.session is s and ei.value.budget == 1
    assert s.stats.requeues == 2
    assert engine.summary()["requeues"] == 2


def test_retry_backoff_delays_readmission(gemma):
    cfg, model, params = gemma
    ref = _reference_outputs(model, params, _prompts(cfg, 1, seed=10))
    engine = _engine(model, params, retry_backoff=4)
    s = engine.submit(_prompts(cfg, 1, seed=10)[0], 8)
    engine.step()  # admit + prefill
    assert s in engine.drain()
    tick0 = engine.tick
    engine.requeue(s)  # 1st requeue: backoff 4 * 2**0
    assert s._backoff_until == tick0 + 4
    engine.run()
    assert s.done and s.out == ref[tuple(s.prompt)]  # token-exact resume
    # no re-admission happened before the backoff horizon
    assert engine.tick > tick0 + 4


# ---------------------------------------------------------------------------
# graceful degradation / NaN quarantine
# ---------------------------------------------------------------------------
def test_kernel_fault_degrades_once_to_xla(gemma):
    cfg, model, params = gemma
    prompts = _prompts(cfg, 2, seed=8)
    ref = _reference_outputs(model, params, prompts)
    engine = ServeEngine(model, params, EngineConfig(
        n_slots=2, max_len=32, prefill_chunk=4))  # default (pallas) policy
    sessions = [engine.submit(p, 8) for p in prompts]
    engine._inject_step_error = RuntimeError("boom")
    with pytest.warns(RuntimeWarning, match="degraded to the xla backend"):
        engine.run()
    assert engine._degraded and engine._backend() == "xla"
    assert engine.summary()["degradations"] == 1
    for p, s in zip(prompts, sessions):
        assert s.done and s.out == ref[tuple(p)]


def test_degrade_disabled_raises(gemma):
    cfg, model, params = gemma
    engine = ServeEngine(model, params, EngineConfig(
        n_slots=2, max_len=32, prefill_chunk=4, degrade=False))
    engine.submit(_prompts(cfg, 1)[0], 4)
    engine._inject_step_error = RuntimeError("boom")
    with pytest.raises(RuntimeError, match="boom"):
        engine.run()


def test_xla_backend_ignores_injected_kernel_fault(gemma):
    cfg, model, params = gemma
    engine = _engine(model, params)  # backend="xla": nothing to degrade from
    s = engine.submit(_prompts(cfg, 1)[0], 4)
    engine._inject_step_error = RuntimeError("boom")
    engine.run()
    assert s.done and engine.summary()["degradations"] == 0


def test_nan_quarantine_retries_token_exact(gemma):
    cfg, model, params = gemma
    prompts = _prompts(cfg, 2, seed=9)
    ref = _reference_outputs(model, params, prompts)
    engine = _engine(model, params, quarantine_ticks=3)
    sessions = [engine.submit(p, 8) for p in prompts]
    inj = FaultInjector(
        FaultPlan(faults=(Fault(tick=2, kind="nan_logits", lanes=(0,)),)),
        engine)
    inj.run()
    summ = engine.summary()
    assert summ["quarantines"] == 1 and summ["nan_events"] == 1
    assert summ["requeues"] == 1
    for p, s in zip(prompts, sessions):  # poisoned token was never recorded
        assert s.done and s.out == ref[tuple(p)]


def test_nan_guard_off_records_poisoned_token(gemma):
    cfg, model, params = gemma
    engine = _engine(model, params, nan_guard=False)
    s = engine.submit(_prompts(cfg, 1)[0], 4)
    inj = FaultInjector(
        FaultPlan(faults=(Fault(tick=1, kind="nan_logits", lanes=(0,)),)),
        engine)
    inj.run()
    assert s.done and engine.summary()["quarantines"] == 0


def test_page_pressure_steals_and_returns_pages(gemma):
    cfg, model, params = gemma
    engine = _engine(model, params, page_size=4)
    free0 = engine.allocator.free_pages
    s = engine.submit(_prompts(cfg, 1)[0], 6)
    inj = FaultInjector(
        FaultPlan(faults=(Fault(tick=1, kind="page_pressure", pages=3,
                                duration=4),)),
        engine)
    inj.run()
    assert s.done
    assert inj.summary()["applied"]["page_pressure"] == 1
    assert engine.allocator.free_pages == free0  # stolen pages came back


def test_page_pressure_skipped_on_dense(gemma):
    cfg, model, params = gemma
    engine = _engine(model, params)  # dense KV: nothing to steal
    s = engine.submit(_prompts(cfg, 1)[0], 4)
    inj = FaultInjector(
        FaultPlan(faults=(Fault(tick=1, kind="page_pressure"),)), engine)
    inj.run()
    assert s.done and inj.summary()["skipped"] == 1


# ---------------------------------------------------------------------------
# run() exhaustion surfacing
# ---------------------------------------------------------------------------
def test_run_max_ticks_exhaustion_warns_and_counts(gemma):
    cfg, model, params = gemma
    engine = _engine(model, params)
    s = engine.submit(_prompts(cfg, 1)[0], 8)
    with pytest.warns(RuntimeWarning, match="work still pending"):
        engine.run(max_ticks=1)
    assert not s.done
    assert engine.summary()["tick_budget_exhausted"] == 1
    engine.run()  # finishes cleanly afterwards
    assert s.done
