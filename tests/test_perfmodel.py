"""Perf-model unit tests: HLO collective parsing (incl. loop awareness) and
roofline arithmetic."""
import numpy as np

from repro.perfmodel.costs import CompiledCosts
from repro.perfmodel.hlo import CollectiveStats, collective_bytes, _shape_bytes
from repro.perfmodel.roofline import model_flops, roofline


def test_shape_bytes():
    assert _shape_bytes("f32[128,256]") == 128 * 256 * 4
    assert _shape_bytes("bf16[2,2]{1,0}") == 8
    assert _shape_bytes("(f32[4], bf16[8])") == 16 + 16
    assert _shape_bytes("pred[]") == 1


HLO_FLAT = """
HloModule test

ENTRY %main (p0: f32[64]) -> f32[64] {
  %p0 = f32[64] parameter(0)
  %ar = f32[64] all-reduce(%p0), replica_groups=[2,8]<=[16], to_apply=%add
  ROOT %out = f32[64] copy(%ar)
}
"""


def test_flat_all_reduce_accounting():
    s = collective_bytes(HLO_FLAT)
    assert s.op_counts["all-reduce"] == 1
    # 64 f32 = 256B; ring: 2*B*(n-1)/n with n=8
    np.testing.assert_allclose(s.per_device_bytes, 2 * 256 * 7 / 8)


HLO_LOOP = """
HloModule test

%cond (arg: (s32[], f32[64])) -> pred[] {
  %arg = (s32[], f32[64]) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %k = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %k), direction=LT
}

%body (arg: (s32[], f32[64])) -> (s32[], f32[64]) {
  %arg = (s32[], f32[64]) parameter(0)
  %x = f32[64] get-tuple-element(%arg), index=1
  %ag = f32[64] all-gather(%x), replica_groups=[4,4]<=[16], dimensions={0}
  %i = s32[] get-tuple-element(%arg), index=0
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[64]) tuple(%ip, %ag)
}

ENTRY %main (p0: (s32[], f32[64])) -> (s32[], f32[64]) {
  %p0 = (s32[], f32[64]) parameter(0)
  ROOT %w = (s32[], f32[64]) while(%p0), condition=%cond, body=%body
}
"""


def test_loop_aware_collective_multiplication():
    s = collective_bytes(HLO_LOOP)
    # the all-gather inside the 12-trip loop counts 12 times
    assert s.op_counts["all-gather"] == 12
    np.testing.assert_allclose(s.per_device_bytes, 12 * 256 * 3 / 4)


def test_roofline_terms_and_dominance():
    costs = CompiledCosts(
        flops_per_device=197e12 * 0.5,  # 0.5 s of compute
        bytes_per_device=819e9 * 0.25,  # 0.25 s of HBM
        transcendentals=0,
        arg_bytes=0, out_bytes=0, temp_bytes=0, alias_bytes=0, code_bytes=0,
    )
    coll = CollectiveStats(per_device_bytes=50e9 * 1.0)  # 1.0 s of ICI
    rt = roofline(costs, coll, chips=256, kind="train",
                  n_params_active=1e9, tokens=1e6)
    assert rt.dominant == "collective"
    np.testing.assert_allclose(rt.compute_s, 0.5)
    np.testing.assert_allclose(rt.memory_s, 0.25)
    np.testing.assert_allclose(rt.collective_s, 1.0)
    # model flops: 6ND
    assert rt.model_flops == 6e15
    # fraction = (6e15 / (256*197e12)) / 1.0
    np.testing.assert_allclose(rt.roofline_fraction, 6e15 / (256 * 197e12))


def test_model_flops_kinds():
    assert model_flops("train", 1e9, 100) == 6e11
    assert model_flops("prefill", 1e9, 100) == 2e11
    assert model_flops("decode", 1e9, 1) == 2e9
