"""End-to-end behaviour tests: training reduces loss; the serving engine
completes batched requests with continuous batching; probes run for real."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import DataPipeline, SyntheticLM
from repro.models import build_model
from repro.optim import AdamW
from repro.optim.schedule import cosine_with_warmup
from repro.serve import EngineConfig, ServeEngine
from repro.train.loop import LoopConfig, train_loop
from repro.train.step import TrainState, make_train_step


def test_training_reduces_loss_e2e():
    cfg = get_config("qwen2.5-14b").reduced()
    model = build_model(cfg)
    opt = AdamW(weight_decay=0.0)
    step_fn = jax.jit(
        make_train_step(model.loss_fn, opt, cosine_with_warmup(3e-3, 5, 60))
    )
    params = model.init(jax.random.key(0))
    state = TrainState(params=params, opt=opt.init(params))
    src = SyntheticLM(cfg.vocab_size, seq_len=32, global_batch=8, seed=0)
    pipe = DataPipeline(lambda s: src.batch_at(s), prefetch=2)
    state, hist = train_loop(
        step_fn, state, pipe, ckpt=None, cfg=LoopConfig(total_steps=40)
    )
    pipe.close()
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first - 0.2, (first, last)  # the synthetic stream is learnable


def test_training_with_microbatching_matches_loss_scale():
    cfg = get_config("qwen2.5-14b").reduced()
    model = build_model(cfg)
    opt = AdamW(weight_decay=0.0)
    src = SyntheticLM(cfg.vocab_size, seq_len=16, global_batch=8, seed=1)
    batch = {k: jnp.asarray(v) for k, v in src.batch_at(0).items()}
    params = model.init(jax.random.key(0))

    s1 = TrainState(params=params, opt=opt.init(params))
    s2 = TrainState(params=params, opt=opt.init(params))
    lr = cosine_with_warmup(1e-3, 2, 10)
    f1 = jax.jit(make_train_step(model.loss_fn, opt, lr, microbatches=1))
    f4 = jax.jit(make_train_step(model.loss_fn, opt, lr, microbatches=4))
    s1, m1 = f1(s1, batch)
    s2, m4 = f4(s2, batch)
    # same data -> nearly the same loss & update (xent means differ only by
    # microbatch partitioning of the mean)
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 5e-3
    d = max(
        float(jnp.abs(a - b).max())
        for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params))
    )
    assert d < 5e-4, d


def test_serve_engine_continuous_batching():
    cfg = get_config("gemma-2b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    engine = ServeEngine(
        model, params, EngineConfig(n_slots=2, max_len=64, prefill_chunk=4)
    )
    rng = np.random.default_rng(0)
    sessions = [
        engine.submit(list(rng.integers(1, cfg.vocab_size, 4)), max_new_tokens=6)
        for _ in range(5)  # 5 requests > 2 slots -> continuous batching
    ]
    finished = engine.run(max_ticks=500)
    assert len(finished) == 5
    assert {s.rid for s in finished} == {s.rid for s in sessions}
    for s in finished:
        assert len(s.out) == 6
        assert s.finish_reason == "max_new_tokens"
        assert all(0 <= t < cfg.vocab_size for t in s.out)
    summ = engine.summary()
    assert summ["requests"] == 5 and summ["generated_tokens"] == 30
    assert summ["throughput_tok_s"] > 0 and summ["ttft_ms_mean"] > 0


def test_serve_greedy_deterministic():
    cfg = get_config("qwen2.5-14b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))

    def run_once():
        engine = ServeEngine(model, params, EngineConfig(n_slots=1, max_len=32))
        engine.submit([5, 6, 7], max_new_tokens=8)
        return engine.run(max_ticks=100)[0].out

    assert run_once() == run_once()


# ---------------------------------------------------------------------------
def test_probes_run_for_real():
    """Measure-mode probes execute on the live backend with sane outputs."""
    from repro.core import probes

    pc = probes.probe_pointer_chase([1 << 12, 1 << 16], steps=1 << 12)
    assert len(pc.y) == 2 and all(0 < v < 1e4 for v in pc.y)

    bw = probes.probe_stream_bandwidth([1 << 18])
    assert bw.y[0] > 0.1  # > 0.1 GB/s on any real machine

    ops_lat = probes.probe_op_latency(chain=256)
    assert len(ops_lat.y) == len(ops_lat.x)
    assert all(v >= 0 for v in ops_lat.y)

    sc = probes.probe_scatter_contention(n_updates=1 << 10, collisions=(1, 4))
    assert len(sc.y) == 2 and all(v > 0 for v in sc.y)


def test_dissect_measure_quick(tmp_path):
    from repro.core.dissect import dissect_measure

    rep = dissect_measure(quick=True, out_path=str(tmp_path / "host.json"))
    assert rep.mode == "measure"
    assert rep.hardware.main_memory_Bps > 0
    assert len(rep.detected_levels) >= 1
    assert (tmp_path / "host.json").exists()
