"""Unified kernel dispatch API: backend parity, policy semantics, autotune
cache, and registry backend variants."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import tuning
from repro.core.pchase import single_cycle_permutation
from repro.kernels import api, ref

RNG = np.random.default_rng(7)


def _arr(shape, dtype=np.float32, scale=1.0):
    return jnp.asarray((RNG.normal(size=shape) * scale).astype(dtype))


def _flat(x):
    b, s, h, hd = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b * h, s, hd)


# ---------------------------------------------------------------------------
# backend parity: every backend of every registered op matches the oracle
# ---------------------------------------------------------------------------
def _axpy_case():
    x, y = _arr((16, 256)), _arr((16, 256))
    return (x, y, 2.5), {"block_cols": 128}, ref.axpy_ref(x, y, 2.5)


def _stream_copy_case():
    x = _arr((16, 512))
    return (x,), {}, ref.copy_ref(x)


def _stream_reduce_case():
    x = _arr((16, 512))
    return (x,), {}, ref.reduce_ref(x)


def _strided_reduce_case():
    x = _arr((128, 128))
    return (x,), {"stride": 4}, ref.strided_reduce_ref(x, 4)


def _pchase_case():
    perm = single_cycle_permutation(96, seed=3)
    want = jnp.asarray([[ref.pchase_ref(perm, 55)]], jnp.int32)
    return (jnp.asarray(perm), 55), {}, want


def _matmul_case():
    a, b = _arr((96, 160), scale=0.3), _arr((160, 64), scale=0.3)
    return (a, b), {"bm": 32, "bk": 64, "bn": 32}, ref.matmul_ref(a, b)


def _flash_attention_case():
    q, k, v = (_arr((2, 48, 2, 32), scale=0.5) for _ in range(3))
    want = ref.flash_attention_ref(_flat(q), _flat(k), _flat(v), causal=True)
    want = want.reshape(2, 2, 48, 32).transpose(0, 2, 1, 3)
    return (q, k, v), {"causal": True, "bq": 16, "bk": 16}, want


def _ssm_scan_case():
    bsz, s, h, p, n = 1, 40, 2, 8, 4
    u = _arr((bsz, s, h, p))
    a = -jnp.abs(_arr((bsz, s, h))) * 0.2
    b_, c_ = _arr((bsz, s, n)), _arr((bsz, s, n))
    want = ref.ssm_scan_ref(
        _flat(u), a.transpose(0, 2, 1).reshape(bsz * h, s),
        jnp.repeat(b_[:, None], h, 1).reshape(bsz * h, s, n),
        jnp.repeat(c_[:, None], h, 1).reshape(bsz * h, s, n),
    ).reshape(bsz, h, s, p).transpose(0, 2, 1, 3)
    return (u, a, b_, c_), {"chunk": 16}, want


_PARITY_CASES = {
    "axpy": _axpy_case,
    "stream_copy": _stream_copy_case,
    "stream_reduce": _stream_reduce_case,
    "strided_reduce": _strided_reduce_case,
    "pchase": _pchase_case,
    "matmul": _matmul_case,
    "flash_attention": _flash_attention_case,
    "ssm_scan": _ssm_scan_case,
}


def test_every_registered_op_has_a_parity_case():
    assert set(api.op_names()) == set(_PARITY_CASES)


@pytest.mark.parametrize("op_name", sorted(_PARITY_CASES))
@pytest.mark.parametrize("backend", api.BACKENDS)
def test_backend_parity(op_name, backend):
    args, kwargs, want = _PARITY_CASES[op_name]()
    got = api.get_op(op_name)(*args, backend=backend, **kwargs)
    if np.asarray(want).dtype == np.int32:
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    else:
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            rtol=3e-4, atol=3e-4,
        )


def test_unknown_backend_and_op_raise():
    with pytest.raises(ValueError, match="unknown backend"):
        api.matmul(_arr((8, 8)), _arr((8, 8)), backend="cuda")
    with pytest.raises(KeyError, match="unknown kernel op"):
        api.get_op("conv3d")


def test_unknown_kwarg_raises_not_swallowed():
    q = _arr((1, 8, 1, 8))
    with pytest.raises(TypeError, match="casual"):
        api.flash_attention(q, q, q, casual=False)  # typo for causal
    with pytest.raises(TypeError, match="block_colss"):
        api.axpy(_arr((8, 128)), _arr((8, 128)), 1.0, block_colss=64)


# ---------------------------------------------------------------------------
# kernel_policy semantics
# ---------------------------------------------------------------------------
def test_policy_nesting_inherits_and_restores():
    assert api.resolve_backend() == api.default_backend()
    with api.kernel_policy(backend="xla"):
        assert api.resolve_backend() == "xla"
        with api.kernel_policy(autotune=True):  # backend inherited
            pol = api.current_policy()
            assert pol.backend == "xla" and pol.autotune
            with api.kernel_policy(backend="interpret", autotune=False):
                assert api.resolve_backend() == "interpret"
                assert not api.current_policy().autotune
            assert api.resolve_backend() == "xla"
            assert api.current_policy().autotune
        assert not api.current_policy().autotune
    assert api.resolve_backend() == api.default_backend()
    assert not api.current_policy().autotune


def test_policy_restored_on_exception():
    with pytest.raises(RuntimeError):
        with api.kernel_policy(backend="interpret"):
            raise RuntimeError("boom")
    assert api.resolve_backend() == api.default_backend()


def test_policy_rejects_unknown_backend():
    with pytest.raises(ValueError, match="unknown backend"):
        with api.kernel_policy(backend="cuda"):
            pass


def test_policy_tiles_merge_per_op():
    with api.kernel_policy(tiles={"matmul": {"bm": 32}}):
        with api.kernel_policy(tiles={"matmul": {"bn": 16}, "axpy": {"block_cols": 64}}):
            tiles = api.current_policy().tiles
            assert tiles["matmul"] == {"bm": 32, "bn": 16}
            assert tiles["axpy"] == {"block_cols": 64}
        assert api.current_policy().tiles == {"matmul": {"bm": 32}}


def test_policy_tiles_overrides_are_validated():
    with pytest.raises(ValueError, match="bM"):
        with api.kernel_policy(tiles={"matmul": {"bM": 256}}):  # typo for bm
            pass
    with pytest.raises(ValueError, match="unknown op"):
        with api.kernel_policy(tiles={"matmule": {"bm": 256}}):
            pass


def test_bound_matches_call_and_prebinds_dispatch():
    a, b = _arr((32, 48), scale=0.3), _arr((48, 16), scale=0.3)
    f = api.matmul.bound(a, b, backend="interpret", bm=16, bk=16, bn=16)
    np.testing.assert_allclose(
        np.asarray(f(a, b)),
        np.asarray(api.matmul(a, b, backend="interpret", bm=16, bk=16, bn=16)),
        rtol=1e-5, atol=1e-5,
    )
    # the bound callable pinned its backend at bind time: an outer policy
    # change no longer affects it
    with api.kernel_policy(backend="xla"):
        np.testing.assert_allclose(
            np.asarray(f(a, b)), np.asarray(ref.matmul_ref(a, b)), rtol=1e-4, atol=1e-4
        )


def test_probes_honor_ambient_policy_backend():
    from repro.core import probes

    with api.kernel_policy(backend="interpret"):
        res = probes.probe_matmul_throughput(sizes=(16,))
    assert res.meta["backend"] == "interpret"
    res = probes.probe_matmul_throughput(sizes=(16,))
    assert res.meta["backend"] == "xla"  # probe default without a policy


def test_policy_backend_drives_dispatch_and_drops_tile_kwargs():
    a, b = _arr((32, 32)), _arr((32, 32))
    with api.kernel_policy(backend="xla"):
        # tile kwargs are meaningless for the xla impl and must be dropped
        got = api.matmul(a, b, bm=8, bk=8, bn=8)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref.matmul_ref(a, b)), rtol=1e-5, atol=1e-5
    )


# ---------------------------------------------------------------------------
# autotune cache
# ---------------------------------------------------------------------------
@pytest.fixture
def fresh_cache(tmp_path):
    path = tmp_path / "tuning.json"
    cache = tuning.configure(str(path))
    yield cache, path
    tuning.configure()  # reset to in-memory for other tests


def test_autotune_cache_miss_then_hit_and_persistence(fresh_cache):
    cache, path = fresh_cache
    a, b = jnp.ones((256, 256)), jnp.ones((256, 256))
    with api.kernel_policy(backend="interpret", autotune=True):
        api.matmul(a, b)
    assert (cache.hits, cache.misses) == (0, 1)
    assert len(cache) == 1 and path.exists()
    with api.kernel_policy(backend="interpret", autotune=True):
        api.matmul(a, b)
    assert (cache.hits, cache.misses) == (1, 1)
    # a different shape is a different key
    with api.kernel_policy(backend="interpret", autotune=True):
        api.matmul(jnp.ones((128, 256)), b)
    assert cache.misses == 2 and len(cache) == 2

    reloaded = tuning.TuningCache(path=str(path))
    assert reloaded.entries == cache.entries
    key = tuning.make_key("matmul", (a, b), "interpret")
    tiles = reloaded.lookup(key)
    assert set(tiles) == {"bm", "bk", "bn"}
    assert all(v >= 1 for v in tiles.values())


def test_autotune_not_consulted_without_policy(fresh_cache):
    cache, _ = fresh_cache
    api.matmul(jnp.ones((64, 64)), jnp.ones((64, 64)), backend="interpret")
    assert (cache.hits, cache.misses) == (0, 0)


def test_explicit_tiles_beat_autotune(fresh_cache):
    cache, _ = fresh_cache
    a, b = jnp.ones((256, 256)), jnp.ones((256, 256))
    with api.kernel_policy(backend="interpret", autotune=True):
        api.matmul(a, b, bm=64, bk=64, bn=64)  # fully pinned: no lookup
    assert (cache.hits, cache.misses) == (0, 0)


def test_cache_save_merges_entries_from_other_writers(tmp_path):
    path = str(tmp_path / "shared.json")
    a = tuning.TuningCache(path=path)
    a.store("matmul|pallas|f32[1,1]", {"bm": 128})
    b = tuning.TuningCache(path=path)  # picks up a's entry
    b.store("matmul|pallas|f32[2,2]", {"bm": 256})
    a.store("matmul|pallas|f32[3,3]", {"bm": 512})  # must not erase b's write
    merged = tuning.TuningCache(path=path)
    assert set(merged.entries) == {
        "matmul|pallas|f32[1,1]", "matmul|pallas|f32[2,2]", "matmul|pallas|f32[3,3]"
    }


def test_register_variant_collision_leaves_no_partial_registration():
    from repro.core import registry

    @registry.register("t_collide", backends=("xla",), quick={})
    def bench_a():
        return []

    try:
        with pytest.raises(ValueError, match="already registered"):
            @registry.register("t_collide", backends=("pallas", "xla"), quick={})
            def bench_b():
                return []

        assert "t_collide[pallas]" not in registry.names()  # no orphan variant
    finally:
        registry.unregister("t_collide[xla]")
        registry.unregister("t_collide[pallas]")


def test_shape_key_stable():
    a = jnp.ones((8, 16), jnp.float32)
    key = tuning.make_key("matmul", (a, a, 3.5), "pallas")
    assert key == "matmul|pallas|float32[8,16];float32[8,16]"


# ---------------------------------------------------------------------------
# registry backend variants
# ---------------------------------------------------------------------------
def test_registry_backend_variants_run_under_policy():
    from repro.bench.schema import BenchRecord
    from repro.core import registry

    seen = {}

    @registry.register("t_apivar", backends=("pallas", "xla"), quick={"n": 4})
    def bench_t_apivar(n=4, backend=""):
        seen[backend] = api.resolve_backend()
        return [
            BenchRecord(name=f"t_apivar_row{n}", benchmark="t_apivar", x=n,
                        value=1.0, unit="GB/s")
        ]

    try:
        assert "t_apivar" not in registry.names()
        assert {"t_apivar[pallas]", "t_apivar[xla]"} <= set(registry.names())
        for be in ("pallas", "xla"):
            spec = registry.get(f"t_apivar[{be}]")
            assert spec.backend == be
            recs = spec.run("quick")
            assert seen[be] == be  # policy active while the fn ran
            assert recs[0].name == f"t_apivar_row4[{be}]"
            assert recs[0].benchmark == f"t_apivar[{be}]"
    finally:
        registry.unregister("t_apivar[pallas]")
        registry.unregister("t_apivar[xla]")


def test_builtin_backend_variants_registered():
    from repro.bench import runner

    names = runner.select(["gemm", "axpy"])
    assert {"gemm[pallas]", "gemm[xla]", "axpy[pallas]", "axpy[xla]"} <= set(names)


# ---------------------------------------------------------------------------
# removed deprecation surface
# ---------------------------------------------------------------------------
def test_ops_shims_removed():
    """`kernels.ops` and the probes' `use_pallas=` completed their
    deprecation cycle in PR 3: both must be gone, not quietly resurrected."""
    import inspect

    with pytest.raises(ImportError):
        from repro.kernels import ops  # noqa: F401

    from repro.core import probes

    for fn in (probes.probe_matmul_throughput, probes.probe_pointer_chase,
               probes.probe_stream_bandwidth):
        assert "use_pallas" not in inspect.signature(fn).parameters
        assert "backend" in inspect.signature(fn).parameters


# ---------------------------------------------------------------------------
# model integration
# ---------------------------------------------------------------------------
def test_mamba_pallas_impl_matches_xla():
    import jax

    from repro.configs import get_config
    from repro.models.mamba import mamba_forward, mamba_init

    cfg = get_config("zamba2-7b").reduced()
    p = mamba_init(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (1, 24, cfg.d_model), jnp.float32)
    y_xla = mamba_forward(p, x, cfg.replace(ssm_impl="xla"))
    y_pal = mamba_forward(p, x, cfg.replace(ssm_impl="pallas"))
    np.testing.assert_allclose(np.asarray(y_xla), np.asarray(y_pal), rtol=2e-3, atol=2e-3)


def test_attention_pallas_impl_matches_blockwise():
    import jax

    from repro.configs import get_config
    from repro.models.attention import attn_init, qkv_proj
    from repro.models import attention as attn

    cfg = get_config("zamba2-7b").reduced()
    p = attn_init(jax.random.key(2), cfg)
    x = jax.random.normal(jax.random.key(3), (1, 32, cfg.d_model), jnp.float32)
    q, k, v = qkv_proj(p, x, cfg)
    y_block = attn.blockwise_attention(q, k, v, causal=True, chunk=16)
    y_pal = attn.pallas_attention(q, k, v, causal=True, chunk=16)
    np.testing.assert_allclose(np.asarray(y_block), np.asarray(y_pal), rtol=2e-3, atol=2e-3)
