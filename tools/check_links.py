"""Markdown link checker for the repo's docs (no network).

Walks the given markdown files, extracts inline links/images, and verifies:

- relative file targets exist (resolved against the containing file),
- ``#anchor`` fragments — same-file or cross-file — match a heading in the
  target document (GitHub-style slugs),
- external links (http/https/mailto) are *not* fetched; they are only
  reported with ``--list-external``.

Exit code 1 on any broken link, with one ``file:line`` diagnostic per issue.

    python tools/check_links.py README.md ROADMAP.md docs/*.md
"""
from __future__ import annotations

import argparse
import re
import sys
from pathlib import Path

# inline [text](target) — tolerates an optional "title"; ignores images' "!"
# (same target rules), skips fenced code blocks and inline code spans.
LINK_RE = re.compile(r"!?\[[^\]]*\]\(\s*<?([^)\s>]+)>?(?:\s+\"[^\"]*\")?\s*\)")
HEADING_RE = re.compile(r"^(#{1,6})\s+(.*?)\s*#*\s*$")
EXTERNAL = ("http://", "https://", "mailto:", "ftp://")


def github_slug(heading: str) -> str:
    """GitHub's anchor algorithm: strip markup, lowercase, drop everything
    but word chars/spaces/hyphens, spaces -> hyphens."""
    text = re.sub(r"`([^`]*)`", r"\1", heading)  # unwrap code spans
    text = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", text)  # unwrap links
    text = text.strip().lower()
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


def strip_code(markdown: str, *, unwrap_inline: bool = False) -> str:
    """Blank out fenced code blocks, and either remove inline code spans
    (link scanning: example links in snippets aren't real links) or unwrap
    them (heading scanning: ``repro.bench`` contributes to the slug)."""
    out, fence = [], None
    inline = r"\1" if unwrap_inline else ""
    for line in markdown.splitlines():
        stripped = line.lstrip()
        if fence is None and stripped[:3] in ("```", "~~~"):
            fence = stripped[:3]
            out.append("")
            continue
        if fence is not None:
            if stripped[:3] == fence:
                fence = None
            out.append("")
            continue
        out.append(re.sub(r"`([^`]*)`", inline, line))
    return "\n".join(out)


def anchors_of(path: Path, cache: dict) -> set:
    if path not in cache:
        slugs: dict[str, int] = {}
        names = set()
        text = strip_code(path.read_text(encoding="utf-8"), unwrap_inline=True)
        for line in text.splitlines():
            m = HEADING_RE.match(line)
            if not m:
                continue
            slug = github_slug(m.group(2))
            n = slugs.get(slug, 0)
            slugs[slug] = n + 1
            names.add(slug if n == 0 else f"{slug}-{n}")  # GitHub dedup rule
        cache[path] = names
    return cache[path]


def check_file(path: Path, cache: dict, external: list) -> list:
    problems = []
    text = strip_code(path.read_text(encoding="utf-8"))
    for lineno, line in enumerate(text.splitlines(), 1):
        for m in LINK_RE.finditer(line):
            target = m.group(1)
            if target.startswith(EXTERNAL):
                external.append((path, lineno, target))
                continue
            file_part, _, anchor = target.partition("#")
            dest = path if not file_part else (path.parent / file_part).resolve()
            if file_part and not dest.exists():
                problems.append(f"{path}:{lineno}: missing target {target!r}")
                continue
            if anchor:
                if dest.suffix.lower() not in (".md", ".markdown"):
                    continue  # anchors into non-markdown are out of scope
                if anchor not in anchors_of(dest, cache):
                    problems.append(
                        f"{path}:{lineno}: no heading for anchor "
                        f"{'#' + anchor!r} in {dest.name}"
                    )
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("files", nargs="+", type=Path, help="markdown files to check")
    ap.add_argument("--list-external", action="store_true",
                    help="print external URLs (never fetched)")
    args = ap.parse_args(argv)

    cache: dict = {}
    external: list = []
    problems: list = []
    for path in args.files:
        if not path.exists():
            problems.append(f"{path}: file not found")
            continue
        problems.extend(check_file(path, cache, external))

    for p in problems:
        print(p, file=sys.stderr)
    if args.list_external:
        for path, lineno, url in external:
            print(f"{path}:{lineno}: external {url}")
    n_files = sum(1 for p in args.files if p.exists())
    print(f"checked {n_files} files: {len(problems)} broken link(s), "
          f"{len(external)} external link(s) skipped")
    return 1 if problems else 0


if __name__ == "__main__":
    raise SystemExit(main())
