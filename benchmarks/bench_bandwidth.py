"""Tab 3.2 / Tab 3.4 / Fig 3.12 / Fig 3.13 analogue — per-level streaming
bandwidth + block-shape (access-width) sweep."""
from __future__ import annotations

from repro.core import probes
from repro.core.hwmodel import TPU_V5E


def run(quick: bool = True) -> list[dict]:
    rows = []
    res = probes.probe_stream_bandwidth([1 << p for p in range(18, 24 if quick else 28)])
    for f, bw in zip(res.x, res.y):
        rows.append(
            {
                "name": f"streambw_host_{f >> 20}MiB",
                "us_per_call": f / (bw * 1e9) * 1e6,
                "derived": f"{bw:.2f} GB/s",
            }
        )
    blk = probes.probe_block_shape_bandwidth(footprint=1 << 22)
    for w, bw in zip(blk.x, blk.y):
        rows.append(
            {
                "name": f"axpybw_host_width{w}",
                "us_per_call": (1 << 22) * 12 / (bw * 1e9) * 1e6,
                "derived": f"{bw:.2f} GB/s",
            }
        )
    for lvl in TPU_V5E.levels:
        if lvl.bandwidth_Bps:
            rows.append(
                {
                    "name": f"streambw_tpu_model_{lvl.name}",
                    "us_per_call": 0.0,
                    "derived": f"{lvl.bandwidth_Bps / 1e9:.0f} GB/s",
                }
            )
    return rows
