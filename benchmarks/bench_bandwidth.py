"""Deprecated shim — ported to ``repro.bench.suites.bandwidth`` (Tab 3.2/3.4, Fig 3.12/3.13).

Kept so ``from benchmarks import bench_bandwidth; bench_bandwidth.run()`` keeps returning
the old CSV-row dicts; new callers should use the registry path:

    python -m repro.bench run --only bandwidth
"""
from repro.bench.compat import legacy_rows


def run(quick: bool = True, **overrides) -> list:
    return legacy_rows("bandwidth", quick=quick, **overrides)
