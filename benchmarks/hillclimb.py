"""§Perf hillclimb tool: lower one cell under config overrides and print the
three roofline terms (the hypothesis -> change -> re-lower -> measure loop).

    PYTHONPATH=src python -m benchmarks.hillclimb --arch olmoe-1b-7b \
        --shape train_4k --set moe_shard=ffn --set train_microbatches=2 \
        [--hw h100]

Each variant is a full dry-run lower+compile with collective/memory/compute
extraction; results print as a comparison row against the no-override
baseline artifact (if present in --baseline-dir).  ``--hw`` names any part
in the ``repro.hw`` spec database (default the TPU v5e target), so the same
climb can be costed against another generation's roofline.

This is a thin entry point over ``repro.launch.cell``/``repro.launch.dryrun``
(it imports them, not the other way round); the modeled tile scoring it
exercises lives in ``repro.core.autotune``.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path


def parse_override(s: str):
    k, v = s.split("=", 1)
    for cast in (int, float):
        try:
            return k, cast(v)
        except ValueError:
            continue
    if v in ("true", "True"):
        return k, True
    if v in ("false", "False"):
        return k, False
    return k, v


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--set", action="append", default=[], help="cfg overrides k=v")
    ap.add_argument("--baseline-dir", default="artifacts/dryrun")
    ap.add_argument("--tag", default=None)
    ap.add_argument("--out", default="artifacts/hillclimb")
    ap.add_argument("--hw", default="tpu-v5e",
                    help="repro.hw spec-DB part to roofline against (name or alias)")
    args = ap.parse_args(argv)

    import os

    os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

    from repro.configs import get_config, get_shape
    from repro.launch.cell import build_cell
    from repro.launch.dryrun import run_cell
    from repro.launch.mesh import make_production_mesh

    overrides = dict(parse_override(s) for s in args.set)
    cfg = get_config(args.arch).replace(**overrides)
    shape = get_shape(args.shape)
    mesh = make_production_mesh(multi_pod=args.mesh == "multi")

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    t0 = time.time()
    cell = build_cell(cfg, shape, mesh)
    rec = run_cell(cell, out_dir, hw=args.hw)
    tag = args.tag or "+".join(f"{k}={v}" for k, v in overrides.items()) or "baseline"
    rec["overrides"] = overrides
    path = out_dir / f"{cell.name}__{tag.replace('/', '_')}.json"
    path.write_text(json.dumps(rec, indent=2))

    rt = rec["roofline"]
    print(f"\n=== {cell.name} [{tag}] vs {rt.get('hw', args.hw)} "
          f"({time.time() - t0:.0f}s) ===")
    print(f"compute    {rt['compute_s'] * 1e3:10.3f} ms")
    print(f"memory     {rt['memory_s'] * 1e3:10.3f} ms")
    print(f"collective {rt['collective_s'] * 1e3:10.3f} ms   <- dominant: {rt['dominant']}")
    print(f"roofline fraction {rt['roofline_fraction']:.4f}  useful {rt['useful_ratio']:.2f}")
    print(f"collective ops: {json.dumps(rec['collectives']['op_counts'])}")
    mem = rec["memory"]["peak_hbm_bytes"] / 2**30
    amem = rec["analytic_memory"]["analytic_peak_bytes"] / 2**30
    print(f"mem/dev xla {mem:.2f} GiB, analytic {amem:.2f} GiB")

    base_path = Path(args.baseline_dir) / f"{cell.name}.json"
    if base_path.exists():
        base = json.loads(base_path.read_text())
        if base.get("ok"):
            brt = base["roofline"]
            dom = brt["dominant"]
            print(f"\nvs baseline dominant ({dom}): "
                  f"{brt[dom + '_s'] * 1e3:.3f} -> {rt[dom + '_s'] * 1e3:.3f} ms "
                  f"({(rt[dom + '_s'] - brt[dom + '_s']) / brt[dom + '_s'] * 100:+.1f}%)")


if __name__ == "__main__":
    main()
