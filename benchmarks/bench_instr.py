"""Deprecated shim — ported to ``repro.bench.suites.instr`` (Tab 4.1).

Kept so ``from benchmarks import bench_instr; bench_instr.run()`` keeps returning
the old CSV-row dicts; new callers should use the registry path:

    python -m repro.bench run --only instr
"""
from repro.bench.compat import legacy_rows


def run(quick: bool = True, **overrides) -> list:
    return legacy_rows("instr", quick=quick, **overrides)
