"""Tab 4.1 analogue — dependent-issue op latency table.

The paper measures SASS instruction latencies with control-word stall
tuning; the TPU/JAX analogue is a dependent-chain per-primitive latency
(chain of fori_loop iterations, loop overhead subtracted)."""
from __future__ import annotations

from repro.core import probes


def run(quick: bool = True) -> list[dict]:
    res = probes.probe_op_latency(chain=1024 if quick else 8192)
    rows = [
        {
            "name": f"oplat_{name}",
            "us_per_call": lat * 1e-3,
            "derived": f"{lat:.2f} ns dependent-issue",
        }
        for name, lat in zip(res.x, res.y)
    ]
    rows.append(
        {
            "name": "oplat_loop_overhead",
            "us_per_call": res.meta["base_ns"] * 1e-3,
            "derived": f"{res.meta['base_ns']:.2f} ns baseline",
        }
    )
    return rows
