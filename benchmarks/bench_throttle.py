"""Fig 4.3 / 4.4 / 4.5 analogue — clock throttling under sustained load.

Runs the fitted power/thermal governor model for the paper's T4
parameterization (validating the published curve shape: brief full clock ->
power-limit plateau -> thermal step at 85 C) and for the TPU v5e envelope
used by the straggler detector."""
from __future__ import annotations

import numpy as np

from repro.core.throttle import T4_THROTTLE, V5E_THROTTLE, simulate, steady_state_clock


def run(quick: bool = True) -> list[dict]:
    rows = []
    for name, p in (("t4", T4_THROTTLE), ("v5e", V5E_THROTTLE)):
        out = simulate(p, utilization=1.0, duration_s=300, dt=0.5)
        clock, temp, power = out["clock_hz"], out["temp_c"], out["power_w"]
        # time to first 5% derate (paper: "only a few seconds at full clock")
        idx = np.argmax(clock < 0.95 * p.f_max_hz)
        t_derate = out["t"][idx] if clock.min() < 0.95 * p.f_max_hz else float("inf")
        rows += [
            {
                "name": f"throttle_{name}_time_to_derate",
                "us_per_call": t_derate * 1e6,
                "derived": f"{t_derate:.1f}s at full clock",
            },
            {
                "name": f"throttle_{name}_steady_clock",
                "us_per_call": 0.0,
                "derived": f"{clock[-1] / 1e6:.0f} MHz (max {p.f_max_hz / 1e6:.0f})",
            },
            {
                "name": f"throttle_{name}_steady_power",
                "us_per_call": 0.0,
                "derived": f"{power[-40:].mean():.1f} W (limit {p.power_limit_w:.0f})",
            },
            {
                "name": f"throttle_{name}_max_temp",
                "us_per_call": 0.0,
                "derived": f"{temp.max():.1f} C (cap {p.max_temp_c:.0f})",
            },
        ]
        for u in (0.6, 0.8, 1.0):
            f = steady_state_clock(p, u)
            rows.append(
                {
                    "name": f"throttle_{name}_clock_u{int(u * 100)}",
                    "us_per_call": 0.0,
                    "derived": f"{f / 1e6:.0f} MHz sustained at {u:.0%} util",
                }
            )
    return rows
