"""Deprecated shim — ported to ``repro.bench.suites.throttle`` (Fig 4.3-4.5).

Kept so ``from benchmarks import bench_throttle; bench_throttle.run()`` keeps returning
the old CSV-row dicts; new callers should use the registry path:

    python -m repro.bench run --only throttle
"""
from repro.bench.compat import legacy_rows


def run(quick: bool = True, **overrides) -> list:
    return legacy_rows("throttle", quick=quick, **overrides)
