"""Tab 2.1 analogue — work-unit <-> execution-unit mapping.

The paper shows warps colliding on a Turing scheduler (same index mod 4)
halve throughput.  TPU grid cells execute sequentially on the core, so
throughput/program must stay FLAT — this probe demonstrates that contrast
(and catches any surprise serialization cliffs)."""
from __future__ import annotations

from repro.core import probes


def run(quick: bool = True) -> list[dict]:
    res = probes.probe_grid_occupancy(
        rows_per_program=64 if quick else 256, programs=(1, 2, 3, 4, 6, 8)
    )
    base = res.y[0] or 1.0
    return [
        {
            "name": f"grid_occupancy_p{p}",
            "us_per_call": 0.0,
            "derived": f"{bw:.2f} GB/s ({bw / base:.2f}x of 1-program)",
        }
        for p, bw in zip(res.x, res.y)
    ]
