"""Deprecated shim — ported to ``repro.bench.suites.scheduler`` (Tab 2.1).

Kept so ``from benchmarks import bench_scheduler; bench_scheduler.run()`` keeps returning
the old CSV-row dicts; new callers should use the registry path:

    python -m repro.bench run --only scheduler
"""
from repro.bench.compat import legacy_rows


def run(quick: bool = True, **overrides) -> list:
    return legacy_rows("scheduler", quick=quick, **overrides)
