"""Deprecated shim — ported to ``repro.bench.suites.memhier`` (Fig 3.5 / Tab 3.1).

Kept so ``from benchmarks import bench_memhier; bench_memhier.run()`` keeps returning
the old CSV-row dicts; new callers should use the registry path:

    python -m repro.bench run --only memhier
"""
from repro.bench.compat import legacy_rows


def run(quick: bool = True, **overrides) -> list:
    return legacy_rows("memhier", quick=quick, **overrides)
