"""Fig 3.5 / Tab 3.1 / Fig 3.6 analogue — memory-hierarchy dissection via
fine-grained pointer chase.

Measured on the live backend (recovers the HOST's L1/L2/L3/DRAM — the
end-to-end validation of the Mei&Chu methodology), plus the modeled TPU v5e
hierarchy (VMEM/HBM) from the HardwareModel.
"""
from __future__ import annotations

import numpy as np

from repro.core import probes
from repro.core.dissect import _predict_pchase
from repro.core.hwmodel import TPU_V5E


def run(quick: bool = True) -> list[dict]:
    sizes = [1 << p for p in range(12, 25 if quick else 28)]
    res = probes.probe_pointer_chase(sizes, steps=1 << 14)
    plats, caps = probes.analyze_pointer_chase(res)
    rows = [
        {
            "name": f"pchase_host_{s >> 10}KiB",
            "us_per_call": lat * 1e-3,  # ns -> us per load
            "derived": f"{lat:.2f} ns/load",
        }
        for s, lat in zip(res.x, res.y)
    ]
    for i, p in enumerate(plats):
        rows.append(
            {
                "name": f"pchase_host_level{i}",
                "us_per_call": p.latency * 1e-3,
                "derived": f"capacity~{p.end_size >> 10}KiB latency {p.latency:.2f}ns",
            }
        )
    # modeled TPU hierarchy
    tpu_lat = _predict_pchase(TPU_V5E, sizes)
    for lvl in TPU_V5E.levels:
        rows.append(
            {
                "name": f"pchase_tpu_model_{lvl.name}",
                "us_per_call": lvl.latency_ns * 1e-3,
                "derived": f"size {lvl.size_bytes >> 20}MiB lat {lvl.latency_ns:.0f}ns",
            }
        )
    return rows
