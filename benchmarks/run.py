"""Deprecated CSV harness — superseded by ``python -m repro.bench``.

The benchmarks now live in ``repro.bench.suites`` behind the registry and
emit schema-versioned JSON (see ``python -m repro.bench run --help``).  This
entry point keeps the old ``name,us_per_call,derived`` CSV contract (plus
``--full`` / ``--only``) for existing consumers by delegating to the runner.
"""
from __future__ import annotations

import argparse
import sys

from repro.bench.compat import legacy_row
from repro.bench.runner import run_benchmarks

# the eight modules the old harness ran, in its order of appearance; newer
# registrations (e.g. `dissect`) are NOT part of the legacy CSV contract
LEGACY_NAMES = [
    "axpy", "memhier", "bandwidth", "instr",
    "atomics", "gemm", "throttle", "scheduler",
]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args(argv)

    result = run_benchmarks(
        only=[args.only] if args.only else LEGACY_NAMES,
        mode="full" if args.full else "quick",
    )
    print("name,us_per_call,derived")
    for r in result.records:
        row = legacy_row(r)
        print(f"{row['name']},{row['us_per_call']:.3f},{row['derived'].replace(',', ';')}")
    for name, err in sorted(result.errors.items()):
        print(f"{name}_ERROR,0,{err.replace(',', ';')}")
    return 1 if result.errors else 0


if __name__ == "__main__":
    sys.exit(main())
