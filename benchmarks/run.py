"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  ``--full`` widens sweeps;
``--only <prefix>`` filters.

Table map (paper -> module):
    Fig 1.1          bench_axpy        access-width sweep on bandwidth-bound axpy
    Tab 2.1          bench_scheduler   work-unit/execution-unit occupancy
    Fig 3.5/Tab 3.1  bench_memhier     pointer-chase hierarchy dissection
    Tab 3.2/3.4,
    Fig 3.12/3.13    bench_bandwidth   per-level streaming bandwidth
    Tab 4.1          bench_instr       dependent-issue op latency
    Tab 4.2/Fig 4.1  bench_atomics     scatter contention
    Fig 4.2/Tab 4.3  bench_gemm        matmul throughput across dtypes
    Fig 4.3-4.5      bench_throttle    power/thermal clock governor
"""
from __future__ import annotations

import argparse
import sys

from . import (
    bench_atomics,
    bench_axpy,
    bench_bandwidth,
    bench_gemm,
    bench_instr,
    bench_memhier,
    bench_scheduler,
    bench_throttle,
)

MODULES = [
    ("axpy", bench_axpy),
    ("memhier", bench_memhier),
    ("bandwidth", bench_bandwidth),
    ("instr", bench_instr),
    ("atomics", bench_atomics),
    ("gemm", bench_gemm),
    ("throttle", bench_throttle),
    ("scheduler", bench_scheduler),
]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args(argv)

    print("name,us_per_call,derived")
    for name, mod in MODULES:
        if args.only and not name.startswith(args.only):
            continue
        try:
            if name == "axpy":
                rows = mod.run()
            else:
                rows = mod.run(quick=not args.full)
        except Exception as e:  # noqa: BLE001
            print(f"{name}_ERROR,0,{type(e).__name__}: {e}", file=sys.stdout)
            continue
        for r in rows:
            derived = str(r["derived"]).replace(",", ";")
            print(f"{r['name']},{r['us_per_call']:.3f},{derived}")


if __name__ == "__main__":
    main()
