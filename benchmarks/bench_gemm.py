"""Deprecated shim — ported to ``repro.bench.suites.gemm`` (Fig 4.2 / Tab 4.3).

Kept so ``from benchmarks import bench_gemm; bench_gemm.run()`` keeps returning
the old CSV-row dicts; new callers should use the registry path:

    python -m repro.bench run --only gemm
"""
from repro.bench.compat import legacy_rows


def run(quick: bool = True, **overrides) -> list:
    return legacy_rows("gemm", quick=quick, **overrides)
