"""Fig 4.2 / Tab 4.3 analogue — matmul arithmetic throughput across dtypes
and sizes (Tensor Core study -> MXU study).

Host-measured XLA + Pallas-interpret numbers validate the harness; the
modeled TPU columns report the roofline-bounded MXU throughput from the
HardwareModel, including the paper-table comparison (T4 measured peaks from
Tab 4.3 encoded in T4_PAPER)."""
from __future__ import annotations

from repro.core import probes
from repro.core.autotune import choose_matmul_tiles
from repro.core.hwmodel import T4_PAPER, TPU_V5E


def run(quick: bool = True) -> list[dict]:
    sizes = (256, 512) if quick else (256, 512, 1024, 2048)
    res = probes.probe_matmul_throughput(sizes=sizes, dtypes=("float32",))
    rows = [
        {
            "name": f"gemm_host_{key}",
            "us_per_call": 2 * int(key.split(":")[1]) ** 3 / (g * 1e9) * 1e6,
            "derived": f"{g:.1f} GFLOP/s",
        }
        for key, g in zip(res.x, res.y)
    ]
    # modeled TPU v5e MXU roofline per dtype/size
    for dt in ("bfloat16", "int8"):
        peak = TPU_V5E.peak(dt)
        for n in (1024, 4096, 8192):
            flops = 2 * n**3
            eb = 2 if dt == "bfloat16" else 1
            t = max(flops / peak, 3 * n * n * eb / TPU_V5E.main_memory_Bps)
            tile = choose_matmul_tiles(n, n, n, dt if dt != "int8" else "int8")
            rows.append(
                {
                    "name": f"gemm_tpu_model_{dt}_{n}",
                    "us_per_call": t * 1e6,
                    "derived": f"{flops / t / 1e12:.1f} TFLOP/s tiles=({tile.bm},{tile.bk},{tile.bn})",
                }
            )
    # paper cross-check rows (T4 Tab 4.3 measured values)
    for dt, v in T4_PAPER.peak_flops.items():
        rows.append(
            {
                "name": f"gemm_t4_paper_{dt}",
                "us_per_call": 0.0,
                "derived": f"{v / 1e12:.2f} TFLOP/s (paper Tab 4.3)",
            }
        )
    return rows
