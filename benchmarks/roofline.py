"""§Roofline report generator: reads dry-run artifacts and emits the
per-(arch x shape x mesh) table (markdown + CSV).

    PYTHONPATH=src python -m benchmarks.roofline --dir artifacts/dryrun
    PYTHONPATH=src python -m benchmarks.roofline --compare before/ after/
    PYTHONPATH=src python -m benchmarks.roofline --hw t4 a100 h100

``--hw`` re-rooflines every artifact against the named parts from the
``repro.hw`` spec database (via ``perfmodel.roofline.roofline_across``) and
prints a cross-generation table — the paper's T4-vs-P4-vs-V100 comparison
applied to whole compiled programs.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path


def load_records(d: Path) -> list[dict]:
    recs = []
    for f in sorted(d.glob("*.json")):
        r = json.loads(f.read_text())
        recs.append(r)
    return recs


def fmt_row(r: dict) -> str:
    if r.get("skipped"):
        return (
            f"| {r['arch']} | {r['shape']} | — | — | — | — | — | — | — | skipped: "
            f"sub-quadratic-only cell |"
        )
    if not r.get("ok"):
        return f"| {r['arch']} | {r['shape']} | FAIL | | | | | | | {r.get('error', '')[:60]} |"
    rt = r["roofline"]
    mesh = "x".join(str(v) for v in r.get("mesh", {}).values())
    mem = r["memory"]["peak_hbm_bytes"] / 2**30
    amem = r.get("analytic_memory", {}).get("analytic_peak_bytes", 0) / 2**30
    return (
        f"| {r['arch']} | {r['shape']} | {mesh} | {rt['compute_s'] * 1e3:.2f} | "
        f"{rt['memory_s'] * 1e3:.2f} | {rt['collective_s'] * 1e3:.2f} | "
        f"**{rt['dominant']}** | {rt['useful_ratio']:.2f} | "
        f"{rt['roofline_fraction']:.3f} | mem {mem:.1f}/{amem:.1f} GiB |"
    )


HEADER = (
    "| arch | shape | mesh | compute (ms) | memory (ms) | collective (ms) | "
    "dominant | 6ND/HLO | roofline frac | notes (xla/analytic mem per dev) |\n"
    "|---|---|---|---|---|---|---|---|---|---|"
)


def cross_hw_rows(recs: list[dict], hw_names: list[str]) -> list[str]:
    """Re-roofline each artifact's stored costs against spec-DB parts."""
    from repro.perfmodel.costs import CompiledCosts
    from repro.perfmodel.hlo import CollectiveStats
    from repro.perfmodel.roofline import roofline_across

    lines = [
        "| cell | " + " | ".join(f"{h} (dominant, bound ms)" for h in hw_names) + " |",
        "|---|" + "---|" * len(hw_names),
    ]
    for r in recs:
        mem = r["memory"]
        costs = CompiledCosts(
            flops_per_device=mem["flops_per_device"],
            bytes_per_device=mem["bytes_per_device"],
            transcendentals=mem.get("transcendentals", 0.0),
            arg_bytes=0, out_bytes=0, temp_bytes=0, alias_bytes=0, code_bytes=0,
        )
        coll = CollectiveStats(per_device_bytes=r["collectives"]["per_device_bytes"])
        # invert stored model_flops back to tokens so the fraction is exact
        factor = 6.0 if r["kind"] == "train" else 2.0
        tokens = r["roofline"]["model_flops"] / (factor * r["n_params_active"])
        across = roofline_across(
            costs, coll, chips=r["chips"], kind=r["kind"],
            n_params_active=r["n_params_active"], tokens=tokens, hws=hw_names,
        )
        cells = [
            f"{rt.dominant} {max(rt.compute_s, rt.memory_s, rt.collective_s) * 1e3:.2f}"
            for rt in across.values()
        ]
        lines.append(f"| {r['cell']} | " + " | ".join(cells) + " |")
    return lines


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    ap.add_argument("--compare", nargs=2, default=None, metavar=("BEFORE", "AFTER"))
    ap.add_argument("--csv", default=None)
    ap.add_argument("--hw", nargs="+", default=None, metavar="PART",
                    help="cross-generation mode: re-roofline artifacts against "
                         "these repro.hw spec-DB parts")
    args = ap.parse_args(argv)

    if args.hw:
        recs = [
            r for r in load_records(Path(args.dir))
            if r.get("ok") and not r.get("skipped")
        ]
        for line in cross_hw_rows(recs, args.hw):
            print(line)
        return

    if args.compare:
        before = {r["cell"]: r for r in load_records(Path(args.compare[0])) if r.get("ok") and not r.get("skipped")}
        after = {r["cell"]: r for r in load_records(Path(args.compare[1])) if r.get("ok") and not r.get("skipped")}
        print("| cell | dominant | before (ms) | after (ms) | delta |")
        print("|---|---|---|---|---|")
        for cell in sorted(set(before) & set(after)):
            b, a = before[cell]["roofline"], after[cell]["roofline"]
            dom = b["dominant"]
            bv = b[f"{dom}_s"] * 1e3
            av = a[f"{dom}_s"] * 1e3
            print(f"| {cell} | {dom} | {bv:.2f} | {av:.2f} | {(av - bv) / bv * 100:+.1f}% |")
        return

    recs = load_records(Path(args.dir))
    print(HEADER)
    for r in recs:
        print(fmt_row(r))

    ok = [r for r in recs if r.get("ok") and not r.get("skipped")]
    if ok:
        worst = min(ok, key=lambda r: r["roofline"]["roofline_fraction"])
        coll = max(ok, key=lambda r: r["roofline"]["collective_s"])
        print()
        print(f"cells: {len(ok)} ok, {sum(1 for r in recs if r.get('skipped'))} skipped")
        print(f"worst roofline fraction: {worst['cell']} ({worst['roofline']['roofline_fraction']:.3f})")
        print(f"most collective-bound: {coll['cell']} ({coll['roofline']['collective_s'] * 1e3:.2f} ms)")

    if args.csv:
        import csv

        with open(args.csv, "w", newline="") as f:
            w = csv.writer(f)
            w.writerow(
                ["cell", "arch", "shape", "chips", "compute_s", "memory_s",
                 "collective_s", "dominant", "useful_ratio", "roofline_fraction",
                 "peak_hbm_gib", "analytic_gib"]
            )
            for r in ok:
                rt = r["roofline"]
                w.writerow(
                    [r["cell"], r["arch"], r["shape"], r["chips"], rt["compute_s"],
                     rt["memory_s"], rt["collective_s"], rt["dominant"],
                     rt["useful_ratio"], rt["roofline_fraction"],
                     r["memory"]["peak_hbm_bytes"] / 2**30,
                     r.get("analytic_memory", {}).get("analytic_peak_bytes", 0) / 2**30]
                )


if __name__ == "__main__":
    main()
