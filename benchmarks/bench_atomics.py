"""Tab 4.2 / Fig 4.1 analogue — update throughput under contention.

TPU has no hardware atomics; colliding scatter-adds serialize inside the
XLA scatter, so throughput vs. collision multiplicity plays the role of the
paper's atomicAdd contention scenarios."""
from __future__ import annotations

from repro.core import probes


def run(quick: bool = True) -> list[dict]:
    res = probes.probe_scatter_contention(
        n_updates=1 << (14 if quick else 18), collisions=(1, 2, 4, 8, 16, 32)
    )
    return [
        {
            "name": f"scatter_contention_x{c}",
            "us_per_call": res.meta["n_updates"] / (r * 1e6) if r else 0.0,
            "derived": f"{r:.2f} Mupdates/s",
        }
        for c, r in zip(res.x, res.y)
    ]
