"""Deprecated shim — ported to ``repro.bench.suites.atomics`` (Tab 4.2 / Fig 4.1).

Kept so ``from benchmarks import bench_atomics; bench_atomics.run()`` keeps returning
the old CSV-row dicts; new callers should use the registry path:

    python -m repro.bench run --only atomics
"""
from repro.bench.compat import legacy_rows


def run(quick: bool = True, **overrides) -> list:
    return legacy_rows("atomics", quick=quick, **overrides)
