"""Fig 1.1 analogue — `?axpy` access-width sweep.

The paper: cublasSaxpy's 64-bit loads vs. hand-vectorized 128-bit loads ->
~2x on large arrays.  TPU restatement: the bandwidth-bound axpy kernel swept
over VMEM tile widths (narrow tiles under-utilize the HBM streaming path the
way narrow loads under-utilized Turing's LSUs), plus the XLA-fused baseline
(the "library" implementation).

Measured for real on the host backend; the modeled TPU columns come from the
HardwareModel bandwidth term.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.hwmodel import TPU_V5E
from repro.core.timing import time_fn
from repro.kernels import ops


def run(sizes=(1 << 18, 1 << 20), widths=(128, 256, 512, 1024)) -> list[dict]:
    rows = []
    for n in sizes:
        cols_base = 512
        r = n // cols_base
        x = jnp.ones((r, cols_base), jnp.float32)
        y = jnp.ones((r, cols_base), jnp.float32)
        import jax

        t_lib = time_fn(jax.jit(lambda a, b: 2.5 * a + b), x, y, warmup=2, reps=5)
        bytes_moved = 3 * n * 4
        rows.append(
            {
                "name": f"axpy_xla_baseline_n{n}",
                "us_per_call": t_lib.min_s * 1e6,
                "derived": f"{bytes_moved / t_lib.min_s / 1e9:.2f} GB/s",
            }
        )
        for w in widths:
            r2 = n // w
            xv = jnp.ones((r2, w), jnp.float32)
            yv = jnp.ones((r2, w), jnp.float32)
            t = time_fn(
                ops.axpy, xv, yv, 2.5, block_rows=8, block_cols=w, warmup=2, reps=5
            )
            rows.append(
                {
                    "name": f"axpy_pallas_n{n}_w{w}",
                    "us_per_call": t.min_s * 1e6,
                    "derived": f"{bytes_moved / t.min_s / 1e9:.2f} GB/s",
                }
            )
        # modeled TPU: bandwidth-bound time at 819 GB/s
        rows.append(
            {
                "name": f"axpy_tpu_modeled_n{n}",
                "us_per_call": bytes_moved / TPU_V5E.main_memory_Bps * 1e6,
                "derived": f"{TPU_V5E.main_memory_Bps / 1e9:.0f} GB/s bound",
            }
        )
    return rows
