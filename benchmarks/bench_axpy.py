"""Deprecated shim — ported to ``repro.bench.suites.axpy`` (Fig 1.1).

Kept so ``from benchmarks import bench_axpy; bench_axpy.run()`` keeps returning
the old CSV-row dicts; new callers should use the registry path:

    python -m repro.bench run --only axpy
"""
from repro.bench.compat import legacy_rows


def run(quick: bool = True, **overrides) -> list:
    return legacy_rows("axpy", quick=quick, **overrides)
