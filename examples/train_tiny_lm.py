"""End-to-end training driver: a ~100M-parameter qwen-family model trained
for a few hundred steps on the synthetic Zipf+structure stream, with
checkpointing and an injected failure + automatic resume mid-run (the
fault-tolerance path exercised for real).

    PYTHONPATH=src python examples/train_tiny_lm.py [--steps 300] [--d-model 512]

On this CPU container expect ~ a few minutes with the default reduced size;
pass --d-model 768 --layers 12 for the full ~100M configuration.
"""
import argparse
import tempfile

import jax

from repro.ckpt import CheckpointManager
from repro.configs import get_config
from repro.data import DataPipeline, SyntheticLM
from repro.models import build_model
from repro.optim import AdamW
from repro.optim.schedule import cosine_with_warmup
from repro.train.loop import FailureInjector, LoopConfig, train_loop
from repro.train.step import TrainState, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=6)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--vocab", type=int, default=8192)
    ap.add_argument("--fail-at", type=int, default=None)
    args = ap.parse_args(argv)

    cfg = get_config("qwen2.5-14b").replace(
        name="tiny-lm",
        n_layers=args.layers,
        d_model=args.d_model,
        n_heads=max(args.d_model // 64, 2),
        n_kv_heads=max(args.d_model // 128, 2),
        head_dim=64,
        d_ff=args.d_model * 4,
        vocab_size=args.vocab,
        dtype="float32",
        param_dtype="float32",
        remat=False,
        attn_chunk=128,
    )
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    n = sum(int(x.size) for x in jax.tree.leaves(params))
    print(f"{cfg.name}: {n / 1e6:.1f}M params")

    opt = AdamW(weight_decay=0.01)
    lr_fn = cosine_with_warmup(1e-3, warmup=args.steps // 10, total=args.steps)
    step_fn = jax.jit(make_train_step(model.loss_fn, opt, lr_fn), donate_argnums=(0,))
    state = TrainState(params=params, opt=opt.init(params))

    src = SyntheticLM(cfg.vocab_size, args.seq, args.batch, seed=0)
    pipeline = DataPipeline(lambda s: src.batch_at(s), prefetch=2)

    with tempfile.TemporaryDirectory() as d:
        ckpt = CheckpointManager(d, keep=2)
        injector = FailureInjector(args.fail_at or args.steps // 2)
        loop_cfg = LoopConfig(total_steps=args.steps, ckpt_every=max(args.steps // 4, 10))
        try:
            train_loop(step_fn, state, pipeline, ckpt=ckpt, cfg=loop_cfg,
                       injector=injector,
                       on_metrics=lambda r: print(
                           f"step {r['step']:4d} loss {r['loss']:.4f} "
                           f"({r['step_time_s'] * 1e3:.0f} ms)"))
        except RuntimeError as e:
            print(f"!! {e} — resuming from last checkpoint")
        pipeline.seek(0)
        state, hist = train_loop(step_fn, state, pipeline, ckpt=ckpt, cfg=loop_cfg,
                                 on_metrics=lambda r: print(
                                     f"step {r['step']:4d} loss {r['loss']:.4f}"))
    first, last = hist[0]["loss"], hist[-1]["loss"]
    print(f"done: loss {first:.4f} -> {last:.4f} over {args.steps} steps")
    assert last < first, "training must reduce loss"
    pipeline.close()


if __name__ == "__main__":
    main()
