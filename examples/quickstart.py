"""Quickstart: the paper's workflow end to end in ~60 seconds on CPU.

1. Dissect the hardware you are on (pointer-chase + bandwidth + GEMM probes
   -> fitted HardwareModel; the paper's Ch. 3/4 in one call).
2. Use the model to pick MXU tiles for a matmul (the paper's Ch. 1 lesson).
3. Spin up a reduced assigned architecture, take two training steps, decode.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core.autotune import choose_matmul_tiles
from repro.core.dissect import dissect_measure
from repro.hw import TPU_V5E
from repro.configs import get_config
from repro.models import build_model


def main():
    # --- 1. dissect ---------------------------------------------------
    print("== dissecting host (quick probes) ==")
    rep = dissect_measure(quick=True)
    for lat, cap in rep.detected_levels:
        cap_s = f"{cap >> 10} KiB" if cap else "(last level)"
        print(f"  level: {lat:7.2f} ns/load  capacity {cap_s}")
    print(f"  stream bandwidth: {rep.hardware.main_memory_Bps / 1e9:.1f} GB/s")

    print("== TPU v5e model (dry-run target) ==")
    for lvl in TPU_V5E.levels:
        print(f"  {lvl.name}: {lvl.size_bytes >> 20} MiB, {lvl.latency_ns:.0f} ns, "
              f"{lvl.bandwidth_Bps / 1e9:.0f} GB/s")

    # --- 2. knowledge -> optimization ---------------------------------
    tile = choose_matmul_tiles(4096, 4096, 4096, "bfloat16")
    print(f"== autotuned MXU tiles for 4096^3 bf16 matmul: "
          f"({tile.bm},{tile.bk},{tile.bn}), predicted {tile.predicted_s * 1e6:.0f} us ==")

    # --- 3. a reduced assigned arch -----------------------------------
    cfg = get_config("qwen2.5-14b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = {
        "tokens": jax.random.randint(jax.random.key(1), (2, 64), 0, cfg.vocab_size),
        "targets": jax.random.randint(jax.random.key(2), (2, 64), 0, cfg.vocab_size),
    }
    loss, grads = jax.jit(jax.value_and_grad(model.loss_fn))(params, batch)
    params = jax.tree.map(lambda p, g: p - 0.05 * g, params, grads)
    loss2 = jax.jit(model.loss_fn)(params, batch)
    print(f"== {cfg.name}: loss {float(loss):.4f} -> {float(loss2):.4f} after one step ==")

    logits, cache = model.prefill(params, {"tokens": batch["tokens"]}, 96)
    tok = jnp.argmax(logits, -1)
    logits, cache = model.decode_step(params, cache, tok, jnp.full((2,), 64, jnp.int32))
    print(f"== decoded one token per sequence: {jnp.argmax(logits, -1)} ==")


if __name__ == "__main__":
    main()
