"""The paper, as a program: dissect the hardware you are running on and
print a Table-3.1-style report, then show what the knowledge buys you
(autotuned tiles vs naive).

    PYTHONPATH=src python examples/dissect_hardware.py [--full]
"""
import argparse

import repro.hw as hw
from repro.core.autotune import choose_matmul_tiles, matmul_time_model
from repro.core.dissect import dissect_measure, dissect_model
from repro.hw import T4_PAPER, TPU_V5E


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    print("=== measured: this host ===")
    rep = dissect_measure(quick=not args.full)
    print(f"{'level':>8} | {'latency':>10} | {'capacity':>12}")
    for i, (lat, cap) in enumerate(rep.detected_levels):
        cap_s = f"{cap >> 10} KiB" if cap else "—"
        print(f"{i:>8} | {lat:8.2f} ns | {cap_s:>12}")
    mm = rep.probe_results["matmul_throughput"]
    print(f"matmul peak: {max(mm['y']):.1f} GFLOP/s; "
          f"stream bw: {rep.hardware.main_memory_Bps / 1e9:.1f} GB/s")

    print("\n=== modeled: TPU v5e (dry-run target) ===")
    mrep = dissect_model(TPU_V5E)
    for name, pr in mrep.probe_results.items():
        ys = pr["y"]
        print(f"  {name}: {min(ys):.1f} .. {max(ys):.1f} {pr['unit']}")

    print("\n=== paper cross-check: T4 Table 3.1 constants ===")
    for lvl in T4_PAPER.levels:
        print(f"  {lvl.name}: {lvl.size_bytes >> 10} KiB, {lvl.latency_ns:.1f} ns "
              f"({lvl.latency_ns * 1.59:.0f} cycles @1.59GHz)")

    # dissect_measure registered the fitted host into the spec DB, so the
    # cross-generation comparison the paper tabulates is one call away
    print("\n=== spec DB: this host vs the paper's T4 ===")
    c = hw.compare("measured-host", "T4")
    print(f"  fp32 peak ratio: {c['peak_ratio'].get('float32', 0):.4f}x; "
          f"memory bw ratio: {c['main_memory_Bps_ratio']:.3f}x")
    c = hw.compare("T4", "P4")
    print("=== spec DB: T4 vs P4 (the paper's own columns) ===")
    for dt, r in c["peak_ratio"].items():
        print(f"  {dt:>8}: {r:8.2f}x")

    print("\n=== knowledge -> optimization (Ch.1) ===")
    t_naive, _ = matmul_time_model(8192, 8192, 8192, 128, 128, 128, "bfloat16", TPU_V5E)
    best = choose_matmul_tiles(8192, 8192, 8192, "bfloat16")
    print(f"  8192^3 bf16: naive 128-tiles {t_naive * 1e3:.2f} ms -> "
          f"autotuned ({best.bm},{best.bk},{best.bn}) {best.predicted_s * 1e3:.2f} ms "
          f"({t_naive / best.predicted_s:.2f}x)")

    if args.out:
        with open(args.out, "w") as f:
            f.write(rep.to_json())
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
