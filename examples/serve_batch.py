"""Batched serving example: continuous batching over more requests than
slots, on a reduced gemma config.

    PYTHONPATH=src python examples/serve_batch.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serve import ServeEngine


def main():
    cfg = get_config("gemma-2b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    engine = ServeEngine(model, params, n_slots=4, max_len=96)

    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for i in range(10):
        prompt = list(rng.integers(1, cfg.vocab_size, 4 + i % 5))
        engine.submit(prompt, max_new_tokens=8 + i % 7)
    finished = engine.run()
    dt = time.perf_counter() - t0
    tokens = sum(len(r.out) for r in finished)
    print(f"served {len(finished)} requests / {tokens} tokens in {dt:.2f}s "
          f"({tokens / dt:.1f} tok/s on CPU interpret path)")
    for r in finished:
        print(f"  req {r.rid}: prompt[{len(r.prompt)}] -> {r.out}")


if __name__ == "__main__":
    main()
