"""Batched serving example: continuous batching over more requests than
slots on a reduced gemma config, with a streamed (per-token callback)
request, a priority scheduler, paged KV with a shared system prefix, and
the engine's serving metrics.

    PYTHONPATH=src python examples/serve_batch.py
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serve import EngineConfig, PriorityScheduler, ServeEngine


def main():
    cfg = get_config("gemma-2b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    engine = ServeEngine(
        model,
        params,
        # paged KV: lanes draw 16-token pages from a shared pool instead of
        # reserving max_len each; drop page_size for the dense layout
        EngineConfig(n_slots=4, max_len=96, prefill_chunk=8, page_size=16),
        scheduler=PriorityScheduler(),
    )

    rng = np.random.default_rng(0)
    # a "system prompt" stored once: every request below starts with it and
    # shares its KV pages copy-on-write instead of re-prefilling them
    system = list(rng.integers(1, cfg.vocab_size, 12))
    engine.register_prefix(system)
    for i in range(10):
        prompt = system + list(rng.integers(1, cfg.vocab_size, 4 + i % 5))
        engine.submit(prompt, max_new_tokens=8 + i % 7, priority=i % 3)

    # a streamed request: tokens arrive through the callback as they decode
    streamed = []
    engine.submit(
        list(rng.integers(1, cfg.vocab_size, 6)),
        max_new_tokens=10,
        priority=5,  # jumps the queue under PriorityScheduler (no shared prefix)
        on_token=lambda sess, tok: streamed.append(tok),
    )

    finished = engine.run()
    s = engine.summary()
    print(
        f"served {len(finished)} requests / {s['generated_tokens']} tokens "
        f"in {s['total_s']:.2f}s ({s['throughput_tok_s']:.1f} tok/s, "
        f"ttft {s['ttft_ms_mean']:.0f}ms, occupancy {s['occupancy']:.0%})"
    )
    print(f"streamed request got {len(streamed)} tokens via callback: {streamed}")
    print(
        f"paged KV: peak {s['pages_peak']}/{engine.n_pages} pages, "
        f"{s['prefix_tokens_reused']} system-prompt tokens reused across "
        f"{s['prefix_hits']} requests"
    )
    for sess in finished:
        print(
            f"  req {sess.rid} prio {sess.priority} [{sess.finish_reason}]: "
            f"prompt[{len(sess.prompt)}] -> {sess.out}"
        )


if __name__ == "__main__":
    main()
