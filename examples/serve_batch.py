"""Batched serving example: continuous batching over more requests than
slots on a reduced gemma config, with a streamed (per-token callback)
request, a priority scheduler, paged KV with a shared system prefix, and
the engine's serving metrics.

    PYTHONPATH=src python examples/serve_batch.py

Scale the same workload out with data-parallel replicas (and, given more
than one device, tensor-parallel decode per replica — see docs/scaling.md);
the prefix-affinity router keeps prompts that share the system prefix on
the replica that holds its pages, and a mid-run replica failure drains and
resumes its sessions on the survivor:

    PYTHONPATH=src python examples/serve_batch.py --replicas 2 --fail-one
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/serve_batch.py --replicas 2 --tp 2
"""
import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serve import (
    ROUTERS,
    ClusterConfig,
    ClusterRouter,
    EngineConfig,
    PriorityScheduler,
    ServeEngine,
)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--replicas", type=int, default=1,
                    help=">1 serves through a ClusterRouter instead of one engine")
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel devices per replica (needs a multi-"
                         "device jax; force with --xla_force_host_platform_device_count)")
    ap.add_argument("--router", choices=sorted(ROUTERS), default="prefix_affinity")
    ap.add_argument("--fail-one", action="store_true",
                    help="kill replica 0 mid-run to demo drain/requeue "
                         "(requires --replicas >= 2)")
    args = ap.parse_args(argv)

    cfg = get_config("gemma-2b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    # paged KV: lanes draw 16-token pages from a shared pool instead of
    # reserving max_len each; drop page_size for the dense layout
    engine_cfg = EngineConfig(n_slots=4, max_len=96, prefill_chunk=8, page_size=16)
    clustered = args.replicas > 1 or args.tp > 1
    if clustered:
        engine = ClusterRouter(model, params, ClusterConfig(
            engine=engine_cfg, n_replicas=args.replicas, tp=args.tp,
            router=args.router))
    else:
        engine = ServeEngine(model, params, engine_cfg,
                             scheduler=PriorityScheduler())

    rng = np.random.default_rng(0)
    # a "system prompt" stored once: every request below starts with it and
    # shares its KV pages copy-on-write instead of re-prefilling them (on a
    # cluster, the prefix lives on one replica and the prefix_affinity
    # router sends matching prompts there)
    system = list(rng.integers(1, cfg.vocab_size, 12))
    engine.register_prefix(system)
    for i in range(10):
        prompt = system + list(rng.integers(1, cfg.vocab_size, 4 + i % 5))
        engine.submit(prompt, max_new_tokens=8 + i % 7, priority=i % 3)

    # a streamed request: tokens arrive through the callback as they decode
    streamed = []
    engine.submit(
        list(rng.integers(1, cfg.vocab_size, 6)),
        max_new_tokens=10,
        priority=5,  # jumps the queue under PriorityScheduler (no shared prefix)
        on_token=lambda sess, tok: streamed.append(tok),
    )

    if args.fail_one:
        if args.replicas < 2:
            raise SystemExit("--fail-one requires --replicas >= 2")
        for _ in range(3):  # let some sessions get mid-decode first
            engine.step()
        requeued = engine.fail_replica(0)
        print(f"failed replica 0: {len(requeued)} session(s) requeued "
              f"with output intact")

    finished = engine.run()
    s = engine.summary()
    if clustered:
        print(f"cluster: {s['replicas']} replica(s) x tp={s['tp']} "
              f"({args.router}), {s['failures']} failure(s)")
    print(
        f"served {len(finished)} requests / {s['generated_tokens']} tokens "
        f"in {s['total_s']:.2f}s ({s['throughput_tok_s']:.1f} tok/s, "
        f"ttft {s['ttft_ms_mean']:.0f}ms, occupancy {s['occupancy']:.0%})"
    )
    print(f"streamed request got {len(streamed)} tokens via callback: {streamed}")
    n_pages = (sum(r.engine.n_pages for r in engine.replicas) if clustered
               else engine.n_pages)
    print(
        f"paged KV: peak {s['pages_peak']}/{n_pages} pages, "
        f"{s['prefix_tokens_reused']} system-prompt tokens reused across "
        f"{s['prefix_hits']} requests"
    )
    for sess in sorted(finished, key=lambda x: x.rid):
        print(
            f"  req {sess.rid} prio {sess.priority} [{sess.finish_reason}]: "
            f"prompt[{len(sess.prompt)}] -> {sess.out}"
        )


if __name__ == "__main__":
    main()
